"""Beyond-paper: fabric-batched mapping events (µs/event and events/sec).

The paper amortizes per-decision scheduling cost by moving HEFT_RT into the
FPGA fabric; this benchmark measures the TPU-side analogue: B independent
mapping events (ready queues of depth D over P PEs) dispatched

  * one-by-one through the host oracle ``heft_rt_numpy``,
  * batched through the jitted ``MappingFabric`` (vmapped ``heft_rt``,
    bucketed shapes, donated T_avail registers),
  * batched through the Pallas fused-overlay backend (interpret mode off-TPU,
    so off-TPU numbers bound the dispatch pipeline, not the kernel).

Steady-state timings (compilation excluded by warmup).

Also measures the *batch-1 steady-state* regime the continuous-serving loop
lives in — one mapping event at a time against the resident T_avail
registers — reporting per-decision p50/p99 (µs) for every backend, with the
path that actually ran (``backend_effective``, e.g. ``pallas-interpret``
off-accelerator) stamped into the derived column.  The in-tick fused
decision (zero host round-trips) is benchmarked separately in
``bench_fused_decision.py``.
"""

import time

import numpy as np

import jax

from benchmarks.common import time_call
from repro.core import heft_rt_numpy
from repro.sched_integration import MappingFabric

D, P = 64, 8
BATCHES = (1, 64, 256)
STEADY_EVENTS = 30          # batch-1 steady-state samples per backend
STEADY_EVENTS_SLOW = 5      # interpret-mode pallas: same rows, fewer samples


def _steady_rows(rng, rows):
    """Batch-1 steady state: repeated single events on resident registers."""
    for backend in ("numpy", "jit", "pallas", "fused"):
        fab = MappingFabric(P, backend=backend)
        reps = (STEADY_EVENTS_SLOW if fab.backend_effective
                == "pallas-interpret" else STEADY_EVENTS)
        events = [( rng.integers(0, 6, D).astype(np.float32),
                    rng.integers(1, 16, (D, P)).astype(np.float32))
                  for _ in range(reps)]
        for avg, ex in events[:2]:      # compile + warm the dispatch
            fab.map_event(avg, ex)
        samples = []
        for avg, ex in events:
            t0 = time.perf_counter()
            fab.map_event(avg, ex)
            samples.append((time.perf_counter() - t0) * 1e6 / D)
        tag = f"per_decision;D={D};P={P};effective={fab.backend_effective}"
        rows.append((f"fabric_{backend}_batch1_decision_p50",
                     float(np.percentile(samples, 50)), "us", tag))
        rows.append((f"fabric_{backend}_batch1_decision_p99",
                     float(np.percentile(samples, 99)), "us", tag))


def _events(rng, B):
    avg = rng.integers(0, 6, (B, D)).astype(np.float32)
    ex = rng.integers(1, 16, (B, D, P)).astype(np.float32)
    avail = rng.integers(0, 8, (B, P)).astype(np.float32)
    return avg, ex, avail


def run():
    rng = np.random.default_rng(0)
    rows = []
    per_event = {}
    for B in BATCHES:
        avg, ex, avail = _events(rng, B)

        def numpy_events():
            for i in range(B):
                heft_rt_numpy(avg[i], ex[i], avail[i])

        us = time_call(numpy_events, repeats=5, warmup=2)
        per_event[("numpy", B)] = us / B
        rows.append((f"fabric_numpy_batch{B}", us / B,
                     f"events_per_s={B / (us * 1e-6):.0f};D={D};P={P}"))

        for backend in ("jit", "pallas"):
            fab = MappingFabric(P, backend=backend)

            def fabric_events():
                jax.block_until_ready(fab.map_batch(avg, ex, avail))

            us = time_call(fabric_events, repeats=5, warmup=2)
            per_event[(backend, B)] = us / B
            rows.append((f"fabric_{backend}_batch{B}", us / B,
                         f"events_per_s={B / (us * 1e-6):.0f};D={D};P={P}"))

    speedup = per_event[("numpy", 256)] / per_event[("jit", 256)]
    rows.append(("fabric_jit_speedup_vs_numpy_batch256", speedup, "x",
                 "events_per_s_ratio;acceptance>=10"))
    _steady_rows(rng, rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
