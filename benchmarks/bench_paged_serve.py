"""Beyond-paper: continuous batching through the block-paged KV pool.

A mixed-length open-loop workload is admitted into one paged ``ServeEngine``
(requests arrive over decode ticks, join the running batch at the admission
tick, queue — never drop — when the pool is exhausted).  Rows:

* ``paged_tok_s`` — generated tokens/sec over the open-loop run (wall
  clock: gate for the catastrophic class of regression, not jitter).
* ``paged_p50_ms`` / ``paged_p99_ms`` — per-request arrival→retire latency
  (wall clock, same caveat).
* ``paged_requests_served`` — deterministic (exact-gated unit): every
  admitted request retires.
* Simulator-twin rows: ``Replica.slots`` occupancy (the analytic twin of
  ``max_batch``) p99 at slots=1 vs slots=4, deterministic, plus the derived
  speedup ratio (exempt ``x`` unit).

The per-request token streams are bit-identical to the dense oracle — that
contract is *tested* (tests/test_paged_serve.py), not benchmarked here.
"""

import dataclasses
import time

import numpy as np

from benchmarks.common import emit


def _engine():
    import jax

    from repro.models.config import ModelConfig
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = ModelConfig(name="bench-paged", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    params = init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_len=64)
    eng.start_paged(max_batch=8, page_size=8)
    return eng


def _workload(n, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, 64, size=int(rng.integers(4, 40))).astype(np.int32),
             int(rng.integers(4, 16)))
            for _ in range(n)], [i // 2 for i in range(n)]   # 2 arrivals/tick


def _open_loop(eng, requests, arrivals):
    """Drive the admission/decode/retire loop; per-request wall latency."""
    t_arrive = {}
    latency = []
    queued = []
    tokens = 0
    nxt = 0
    tick = 0
    in_flight = {}
    t0 = time.perf_counter()
    while len(latency) < len(requests):
        now = time.perf_counter()
        while nxt < len(requests) and arrivals[nxt] <= tick:
            t_arrive[nxt] = now
            queued.append(nxt)
            nxt += 1
        while queued:                       # exhaustion queues, never drops
            slot = eng.admit(*requests[queued[0]])
            if slot is None:
                break
            in_flight[slot] = queued.pop(0)
        eng.decode_tick()
        for slot in eng.finished_slots():
            idx = in_flight.pop(slot)
            seq = eng.retire(slot)
            tokens += len(seq) - len(requests[idx][0])
            latency.append(time.perf_counter() - t_arrive[idx])
        tick += 1
    return tokens, time.perf_counter() - t0, np.asarray(latency)


def run():
    rows = []
    eng = _engine()
    # Warm-up pass compiles the prefill + every pow2 lane bucket the
    # measured run will hit, so the timed rows measure steady-state decode.
    w_reqs, w_arr = _workload(12, seed=1)
    _open_loop(eng, w_reqs, w_arr)
    reqs, arr = _workload(24, seed=0)
    tokens, wall, lat = _open_loop(eng, reqs, arr)
    rows.append(("paged_tok_s", tokens / wall, "tok/s",
                 f"open_loop;n={len(reqs)};max_batch=8;page_size=8"))
    rows.append(("paged_p50_ms", float(np.percentile(lat, 50)) * 1e3, "ms",
                 "arrival->retire"))
    rows.append(("paged_p99_ms", float(np.percentile(lat, 99)) * 1e3, "ms",
                 "arrival->retire"))
    rows.append(("paged_requests_served", float(len(lat)), "requests",
                 "queue-never-drop"))
    pool = eng.paged.pool
    rows.append(("_paged_pages_allocated", float(pool.allocated), "pages",
                 f"freed={pool.freed}"))

    # Simulator twin: slot occupancy (Replica.slots) on the analytic fleet.
    from repro.sched_integration import (POLICIES, default_fleet,
                                         make_requests, simulate_serving)

    twin = {}
    for s in (1, 4):
        fleet = [dataclasses.replace(r, slots=s) for r in default_fleet()]
        twin[s] = simulate_serving(fleet, make_requests(30.0, 10.0, seed=0),
                                   POLICIES["heft_rt"](), active_params=7e9)
        rows.append((f"paged_twin_slots{s}_p99_ms",
                     twin[s].p99_latency * 1e3, "ms",
                     "deterministic simulator twin"))
    rows.append(("paged_twin_slots_speedup_x",
                 twin[1].p99_latency / twin[4].p99_latency, "x",
                 "slots=4 vs slots=1 p99"))
    return rows


if __name__ == "__main__":
    emit(run())
