"""Shared benchmark utilities: timing + CSV emission.

Every benchmark module exposes ``run() -> list[row]`` where a row is
``(name, us_per_call, derived)`` — printed as CSV by benchmarks/run.py.
"""

from __future__ import annotations

import time


def time_call(fn, *args, repeats: int = 5, warmup: int = 1, **kw) -> float:
    """Median wall-clock microseconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: list) -> None:
    for name, us, derived in rows:
        us_s = f"{us:.3f}" if isinstance(us, (int, float)) else str(us)
        print(f"{name},{us_s},{derived}")
