"""Shared benchmark utilities: timing + CSV emission.

Every benchmark module exposes ``run() -> list[row]`` where a row is either
``(name, value, derived)`` — value implicitly in microseconds — or the
explicit-unit form ``(name, value, unit, derived)``.  benchmarks/run.py
prints the normalized ``name,value,unit,derived`` CSV and mirrors it into
the JSON artifacts.
"""

from __future__ import annotations

import time

DEFAULT_UNIT = "us"


def time_call(fn, *args, repeats: int = 5, warmup: int = 1, **kw) -> float:
    """Median wall-clock microseconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def normalize_row(row) -> tuple:
    """(name, value[, unit], derived) → (name, value, unit, derived)."""
    if len(row) == 3:
        name, value, derived = row
        unit = DEFAULT_UNIT
    elif len(row) == 4:
        name, value, unit, derived = row
    else:
        raise ValueError(f"benchmark row must have 3 or 4 fields, got {row!r}")
    return name, value, unit, derived


def emit(rows: list) -> None:
    for row in rows:
        name, value, unit, derived = normalize_row(row)
        vs = f"{value:.3f}" if isinstance(value, (int, float)) else str(value)
        print(f"{name},{vs},{unit},{derived}")
