"""Fig 5: average app execution time vs injection rate (high-latency)."""

import numpy as np

from benchmarks.common import emit
from repro.runtime import HW_MODEL, SW_MODEL, CedrSimulator, paper_soc_pe_types
from repro.runtime.workload import frames_per_second, high_latency_arrivals


def run():
    rows = []
    pes = paper_soc_pe_types()
    sat_sw, sat_hw = [], []
    for mbps in [52, 104, 156, 208, 260, 312, 415, 519, 622, 700]:
        rate = frames_per_second(mbps, 1037.0)
        sw_v, hw_v = [], []
        for seed in range(3):
            arr = high_latency_arrivals(rate, seed=seed)
            sw_v.append(CedrSimulator(pes, overhead=SW_MODEL, seed=7 + seed)
                        .run(arr).avg_app_exec_time)
            hw_v.append(CedrSimulator(pes, overhead=HW_MODEL, seed=7 + seed)
                        .run(arr).avg_app_exec_time)
        sw, hw = np.mean(sw_v) * 1e3, np.mean(hw_v) * 1e3
        if mbps >= 312:      # saturated region (>250 Mbps per paper)
            sat_sw.append(sw)
            sat_hw.append(hw)
        rows.append((f"fig5_appexec_ms_{mbps}mbps", sw, "ms",
                     f"hw={hw:.2f}ms;rate={rate:.0f}fps"))
    red = (1 - np.mean(sat_hw) / np.mean(sat_sw)) * 100
    rows.append(("fig5_saturated_sw_ms", float(np.mean(sat_sw)), "ms", "paper=131.37"))
    rows.append(("fig5_saturated_hw_ms", float(np.mean(sat_hw)), "ms", "paper=89.79"))
    rows.append(("fig5_hw_reduction_pct", red, "pct", "paper=31.7%"))
    return rows


if __name__ == "__main__":
    emit(run())
