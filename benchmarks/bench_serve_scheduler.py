"""Beyond-paper: HEFT_RT as an LLM-serving request scheduler (heterogeneous
replica fleet, oversubscription sweep — the paper's experiment transplanted).

Row values are the **mean request latency in milliseconds** (explicit-unit
rows; an earlier revision mislabeled them under the implicit-µs field)."""

from benchmarks.common import emit
from repro.sched_integration import POLICIES, default_fleet, make_requests, simulate_serving


def run():
    rows = []
    fleet = default_fleet()
    active = 7e9     # deepseek-7b-class serving
    for rate in [100, 400, 800, 1600]:
        reqs = make_requests(rate_rps=rate, duration_s=3.0, seed=0)
        for name, factory in POLICIES.items():
            r = simulate_serving(fleet, reqs, factory(), active_params=active)
            rows.append((f"serve_{name}_rate{rate}", r.mean_latency * 1e3, "ms",
                         f"achieved={r.achieved_rps:.0f}rps;"
                         f"p99={r.p99_latency*1e3:.0f}ms"))
    # headline: heft vs round-robin at heavy oversubscription
    reqs = make_requests(rate_rps=1600, duration_s=3.0, seed=0)
    h = simulate_serving(fleet, reqs, POLICIES["heft_rt"](), active_params=active)
    rr = simulate_serving(fleet, reqs, POLICIES["round_robin"](), active_params=active)
    rows.append(("serve_heft_latency_gain_pct",
                 (1 - h.mean_latency / rr.mean_latency) * 100, "pct",
                 "vs_round_robin_oversubscribed"))
    return rows


if __name__ == "__main__":
    emit(run())
