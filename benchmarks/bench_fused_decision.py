"""Batch-1 steady-state scheduler-decision latency, decision fused in-tick.

The paper's headline is 9.144 ns/decision once HEFT_RT lives in the FPGA
fabric next to the PEs — 183× below the software path, because the decision
stops round-tripping a host.  This benchmark measures the repo's analogue:
the HEFT_RT admission decision running *inside* the paged decode tick's
compiled program (``PagedRuntime.decode_tick(sched=...)`` with a
``MappingFabric(backend="fused")`` — see docs/scheduling.md), where its
marginal cost is device compute riding a dispatch the serving loop already
pays for, versus the host path (one ``map_event`` round trip per event).

Method: a single long-lived request keeps one decode lane busy (batch-1
steady state); plain and fused-scheduler ticks are timed individually in a
drift-cancelling ``plain, fused, fused, plain`` pattern (first-order clock
/ frequency drift subtracts out of the paired difference), and the
per-decision latency is the pair's marginal time amortized over the
``N_SCHED`` decisions each fused tick maps.  The median over ``PAIRS``
differences is robust to scheduler spikes; the floor guards the
subtraction against noise going negative.

Acceptance (self-enforcing, the bench_chaos pattern): fused per-decision
p50 must be ≤ 10 µs — the "~100 µs toward single-digit µs" success metric —
and the rows gate against the tracked artifact via ``run.py --check`` in CI.
"""

import time

import numpy as np

import jax

from benchmarks.common import time_call
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.sched_integration.fabric import MappingFabric
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="bench-fused", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=4, d_ff=64, vocab_size=64)
MAX_LEN = 1024          # long-lived slot: hundreds of steady-state ticks
N_SCHED = 32            # admission-batch size each fused decision maps
P_FLEET = 4             # PE/replica lanes in the fabric
PAIRS = 60              # drift-cancelled (plain, fused, fused, plain) sets
ACCEPT_US = 10.0        # single-digit-µs acceptance for the fused path
FLOOR_US = 0.05         # noise floor for the marginal subtraction


def _setup():
    params = init_params(jax.random.key(0), CFG)
    eng = ServeEngine(CFG, params, max_len=MAX_LEN)
    eng.start_paged(max_batch=2, page_size=16)
    prompt = np.arange(1, 17, dtype=np.int32)
    slot = eng.admit(prompt, MAX_LEN - len(prompt))
    assert slot is not None
    rng = np.random.default_rng(0)
    avg = rng.integers(0, 6, N_SCHED).astype(np.float64)
    ex = rng.integers(1, 16, (N_SCHED, P_FLEET)).astype(np.float64)
    fab = MappingFabric(P_FLEET, backend="fused")
    return eng.paged, fab, avg, ex


def run():
    rt, fab, avg, ex = _setup()
    sched = (avg, ex, fab)
    for _ in range(5):                      # compile + warm both variants
        rt.decode_tick()
        rt.decode_tick(sched)

    def one(fused):
        t0 = time.perf_counter()
        rt.decode_tick(sched) if fused else rt.decode_tick()
        return time.perf_counter() - t0

    marginals, plain_us, fused_us = [], [], []
    for _ in range(PAIRS):
        p1, f1, f2, p2 = one(False), one(True), one(True), one(False)
        plain_us.append((p1 + p2) / 2 * 1e6)
        fused_us.append((f1 + f2) / 2 * 1e6)
        marginals.append(max(FLOOR_US, ((f1 + f2) - (p1 + p2)) / 2
                             * 1e6 / N_SCHED))
    assert rt.active_slots(), "slot token budget exhausted mid-measurement"
    p50 = float(np.percentile(marginals, 50))
    p99 = float(np.percentile(marginals, 99))

    # The host path the fusion replaces: a *dedicated* map_event dispatch
    # per mapping event on the same fused fabric (run_continuous' cold-start
    # fallback makes exactly this call).  Off-accelerator the dispatch has
    # no PCIe/sync round trip to save, so this bounds the pipeline — the
    # speedup row reads ~1x here and grows with real device round trips.
    host_fab = MappingFabric(P_FLEET, backend="fused")
    host_us = time_call(lambda: host_fab.map_event(avg, ex),
                        repeats=9, warmup=3) / N_SCHED
    # The pure software scheduler (the oracle itself), for reference.
    oracle_fab = MappingFabric(P_FLEET, backend="numpy")
    oracle_us = time_call(lambda: oracle_fab.map_event(avg, ex),
                          repeats=9, warmup=3) / N_SCHED

    if p50 > ACCEPT_US:
        raise RuntimeError(
            f"fused in-tick per-decision p50 {p50:.2f}us exceeds the "
            f"{ACCEPT_US}us acceptance bound (paper target: single-digit "
            f"us; host dispatch path: {host_us:.2f}us)")

    tag = (f"in_tick_marginal;n={N_SCHED};P={P_FLEET};"
           f"effective={fab.backend_effective}")
    return [
        ("fused_decision_batch1_p50", p50, "us", tag + f";accept<={ACCEPT_US}"),
        ("fused_decision_batch1_p99", p99, "us", tag),
        ("host_decision_batch1_us", host_us, "us",
         f"dedicated map_event dispatch/n;n={N_SCHED};backend=fused"),
        ("host_oracle_decision_batch1_us", oracle_us, "us",
         f"map_event/n;n={N_SCHED};backend=numpy (software scheduler)"),
        ("fused_vs_host_decision_speedup", host_us / max(p50, FLOOR_US), "x",
         "host_decision_batch1_us / fused_decision_batch1_p50; "
         "off-accelerator this bounds the dispatch pipeline"),
        ("_plain_tick_us", float(np.percentile(plain_us, 50)), "us",
         "decode tick without the fused decision (bookkeeping)"),
        ("_fused_tick_us", float(np.percentile(fused_us, 50)), "us",
         "decode tick carrying the fused decision (bookkeeping)"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
