"""Fig 3: HW vs SW cumulative execution time on the low-latency workload.

The paper's functional-verification argument: if the hardware scheduler made
different task→PE mapping decisions, cumulative execution time would differ.
Ours are bit-identical by construction (validated against the Pallas overlay
in tests); the benchmark reports the sim delta across injection rates plus a
direct decision-equality count on harvested mapping events.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import heft_rt_numpy
from repro.kernels import heft_rt_hw
from repro.runtime import HW_MODEL, SW_MODEL, CedrSimulator, paper_soc_pe_types
from repro.runtime.workload import frames_per_second, low_latency_arrivals


def run():
    rows = []
    pes = paper_soc_pe_types()
    deltas = []
    for mbps in [50, 100, 200, 300, 400]:
        rate = frames_per_second(mbps, 1280.0)
        arr = low_latency_arrivals(rate, seed=1)
        r_sw = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(arr)
        r_hw = CedrSimulator(pes, overhead=HW_MODEL, seed=7).run(arr)
        d = abs(r_sw.avg_cumulative_exec_time - r_hw.avg_cumulative_exec_time)
        deltas.append(d / r_sw.avg_cumulative_exec_time * 100)
        rows.append((f"fig3_cum_exec_ms_{mbps}mbps",
                     r_sw.avg_cumulative_exec_time * 1e3, "ms",
                     f"hw={r_hw.avg_cumulative_exec_time*1e3:.4f}ms;"
                     f"delta={deltas[-1]:.4f}%"))
    rows.append(("fig3_avg_delta_pct", float(np.mean(deltas)), "pct",
                 "paper=0.32%;ours=bit-identical"))
    # direct decision equality: pallas overlay vs numpy software scheduler
    rng = np.random.default_rng(0)
    agree = 0
    total = 0
    for _ in range(50):
        n = int(rng.integers(1, 64))
        avg = rng.uniform(0.1, 5, n).astype(np.float32)
        ex = rng.uniform(0.1, 5, (n, 4)).astype(np.float32)
        av = rng.uniform(0, 2, 4).astype(np.float32)
        _, a_hw, _, _, _ = heft_rt_hw(jnp.array(avg), jnp.array(ex), jnp.array(av))
        _, a_sw, _, _, _ = heft_rt_numpy(avg, ex, av)
        agree += int((np.asarray(a_hw) == a_sw).all())
        total += 1
    rows.append(("fig3_decision_agreement", 100.0 * agree / total,
                 f"{agree}/{total} mapping events bit-identical"))
    return rows


if __name__ == "__main__":
    emit(run())
