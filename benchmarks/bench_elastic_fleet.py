"""Beyond-paper: elastic fleet — resize-event latency + spike throughput.

Two claims tracked across PRs:

* **Resize is cheap.**  ``MappingFabric.grow/shrink`` carry the committed
  T_avail registers across a PE-pool resize in microseconds, and a mapping
  event dispatched right after a resize inside one P bucket reuses the
  compiled pipeline (no per-event re-trace).
* **Elastic tracks the static best case.**  A scripted load spike served by
  a base fleet that grows two replicas for the spike and merges them back
  achieves tokens/sec close to a fleet that (wastefully) holds the maximum
  size for the whole run — and far better tail latency than the static base
  fleet.  The closed-loop controller reproduces the scripted trace's
  behaviour from load signals alone.

The simulation rows are deterministic (seeded arrivals, analytic roofline)
and carry the tight CI gate; the resize-latency rows are wall clock and
``_``-prefixed — informational bookkeeping, exempt from the gate, so the
headline throughput/latency claims aren't stuck behind a runner-variance
tolerance.
"""

import numpy as np

from benchmarks.common import time_call
from repro.sched_integration import (
    FleetController,
    FleetControllerConfig,
    MappingFabric,
    POLICIES,
    ResizeEvent,
    grown_replica_factory,
    make_spike_requests,
    mesh_fleet,
    simulate_serving,
)

ACTIVE = 7e9


def _tok_per_s(result, requests) -> float:
    """Exact served tokens/sec: Σ tokens of served requests over the span
    (achieved_rps = served/span, so span = served / achieved_rps)."""
    served = result.served_mask
    n = int(served.sum())
    if n == 0:
        return 0.0
    toks = sum(requests[i].prefill_tokens + requests[i].decode_tokens
               for i in np.flatnonzero(served))
    return toks * result.achieved_rps / n


def run():
    rows = []

    # --- scripted spike: elastic vs static base vs static best-case ------
    base = mesh_fleet("a", ((4, 4), (4, 4)))
    grown = mesh_fleet("a", ((4, 4), (4, 4), (4, 4), (4, 4)))
    reqs = make_spike_requests(2.0, 30.0, spike_start=1.0, spike_end=2.0,
                               duration_s=8.0, seed=1)
    events = [ResizeEvent(1.2, add=(grown[2],)),
              ResizeEvent(1.7, add=(grown[3],)),
              ResizeEvent(5.0, remove=(grown[2].name,)),
              ResizeEvent(5.5, remove=(grown[3].name,))]
    elastic = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                               active_params=ACTIVE, fleet_events=events)
    s_base = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                              active_params=ACTIVE)
    s_best = simulate_serving(grown, reqs, POLICIES["heft_rt"](),
                              active_params=ACTIVE)
    e_tok, b_tok, best_tok = (_tok_per_s(r, reqs)
                              for r in (elastic, s_base, s_best))
    rows += [
        ("elastic_spike_tok_per_s", e_tok, "tok/s",
         f"grow2@spike/merge-back;N={len(reqs)}"),
        ("static_base_tok_per_s", b_tok, "tok/s", "2x 4x4 whole run"),
        ("static_best_tok_per_s", best_tok, "tok/s", "4x 4x4 whole run"),
        ("elastic_vs_best_pct", 100.0 * e_tok / best_tok, "pct",
         "derived;elastic tokens/sec vs always-max fleet"),
        ("elastic_p99_ms", elastic.p99_latency * 1e3, "ms", "-"),
        ("static_base_p99_ms", s_base.p99_latency * 1e3, "ms", "-"),
    ]

    # --- closed loop: controller reproduces the trace from load signals --
    ctl = FleetController(
        FleetControllerConfig(grow_backlog_s=1.0, shrink_backlog_s=0.3,
                              cooldown_s=0.5, max_grown=2),
        grown_replica_factory("a", (4, 4)))
    c_res = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                             active_params=ACTIVE, controller=ctl)
    rows += [
        ("controller_tok_per_s", _tok_per_s(c_res, reqs), "tok/s",
         f"decisions={len(ctl.trace)}"),
        ("_controller_resizes", float(len(ctl.trace)), "count",
         ";".join(k for _, k, _ in ctl.trace)),
    ]

    # --- resize-event latency on the persistent jitted fabric ------------
    # P=5 and P=7 share the p_bucket=8 compiled variant: the whole
    # grow/shrink cycle moves registers, never the compiled pipeline.
    fab = MappingFabric(5, backend="jit")
    rng = np.random.default_rng(0)
    avg = rng.integers(0, 6, 16).astype(np.float32)

    def ev(p):
        fab.map_event(avg, rng.integers(1, 16, (16, p)).astype(np.float32))

    ev(5)
    fab.grow(7)
    ev(7)                                        # warm both bucket residents
    fab.shrink(np.arange(5))
    steady_us = time_call(lambda: ev(fab.num_pes), repeats=20, warmup=2)

    def grow_shrink():
        fab.grow(7)
        fab.shrink(np.arange(5))

    cycle_us = time_call(grow_shrink, repeats=20, warmup=2)

    def resize_then_event():
        fab.grow(7)
        ev(7)
        fab.shrink(np.arange(5))
        ev(5)

    resize_ev_us = time_call(resize_then_event, repeats=20, warmup=2) / 2
    rows += [
        ("_fabric_resize_us", cycle_us / 2, "us",
         "grow(5->7)+shrink(7->5) halved;registers carried;wall clock"),
        ("_fabric_event_steady_us", steady_us, "us", "D=16;P=5;wall clock"),
        ("_fabric_event_post_resize_us", resize_ev_us, "us",
         "resize+event inside one P bucket (no re-trace);wall clock"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
