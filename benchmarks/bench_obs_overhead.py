"""Observability overhead: instrumentation must be (near-)free.

The tentpole claim of ``repro.obs`` is that the instrumented scheduler is
the production scheduler — the paper's hardware counters update in the same
cycle as the decision, and our software analogue has to stay cheap enough
that nobody is tempted to benchmark with it off.  Measured here:

  * ``MappingFabric.map_event`` (numpy and jit backends), bare vs fully
    instrumented (tracer + metrics + device counters),
  * the primitive costs: disabled-tracer span, enabled span, histogram
    record — per-op nanoseconds.

The time-like rows are CI-gated (``--check`` against the tracked
``BENCH_obs_overhead.json``): an instrumentation-cost regression fails the
build just like a scheduler-latency regression.
"""

import numpy as np

import jax

from benchmarks.common import time_call
from repro.obs import Histogram, MetricsRegistry, Tracer
from repro.sched_integration import MappingFabric

D, P = 64, 8
EVENTS = 32


def _events(rng):
    avg = rng.integers(0, 6, (EVENTS, D)).astype(np.float32)
    ex = rng.integers(1, 16, (EVENTS, D, P)).astype(np.float32)
    avail = rng.integers(0, 8, P).astype(np.float32)
    return avg, ex, avail


def _fabric_us(backend, instrumented, avg, ex, avail):
    kw = (dict(tracer=Tracer(), metrics=MetricsRegistry(),
               device_counters=True) if instrumented else {})
    fab = MappingFabric(P, backend=backend, **kw)

    def events():
        for i in range(EVENTS):
            out = fab.map_event(avg[i], ex[i], avail, update=False)
        if backend != "numpy":
            jax.block_until_ready(out[1])

    us = time_call(events, repeats=5, warmup=2)
    return us / EVENTS


def run():
    rng = np.random.default_rng(0)
    avg, ex, avail = _events(rng)
    rows = []
    for backend in ("numpy", "jit"):
        off = _fabric_us(backend, False, avg, ex, avail)
        on = _fabric_us(backend, True, avg, ex, avail)
        rows.append((f"obs_fabric_{backend}_off", off, f"D={D};P={P}"))
        rows.append((f"obs_fabric_{backend}_on", on,
                     f"tracer+metrics+device_counters;D={D};P={P}"))
        rows.append((f"obs_fabric_{backend}_overhead", on / off, "x",
                     "instrumented/bare map_event; acceptance: near 1"))

    # primitive costs, per-op ns (batched loops so the clock resolves them)
    N = 10_000
    null = Tracer(capacity=4, enabled=False)
    live = Tracer(capacity=1 << 16)
    hist = Histogram()

    def disabled_spans():
        for _ in range(N):
            with null.span("x"):
                pass

    def enabled_completes():
        for _ in range(N):
            live.complete("x", 0.0, 1e-6)

    def hist_records():
        for _ in range(N):
            hist.record(1e-6)

    for name, fn in (("obs_span_disabled", disabled_spans),
                     ("obs_complete_enabled", enabled_completes),
                     ("obs_hist_record", hist_records)):
        us = time_call(fn, repeats=5, warmup=1)
        rows.append((name, us / N * 1e3, "ns", f"per-op;batch={N}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
