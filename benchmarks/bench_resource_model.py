"""Tables II, III, IV: FPGA resource/path-delay model vs published values."""

from benchmarks.common import emit
from repro.core.resource_model import (
    PAPER_TABLE_II,
    PAPER_TABLE_III,
    PAPER_TABLE_IV,
    SchedulerDesign,
    critical_path_ns,
    eft_selector_luts,
    lutram,
    pe_handler_luts,
    queue_luts,
    total_luts,
    total_registers,
    utilization,
)


def run():
    rows = []
    # Table IV sweep
    for (P, D, luts, lr, regs, bram, delay) in PAPER_TABLE_IV:
        d = SchedulerDesign(P=P, D=D)
        err = abs(total_luts(d) - luts) / luts * 100
        rows.append((f"tableIV_P{P}_D{D}_luts", total_luts(d), "luts",
                     f"paper={luts};err={err:.1f}%"))
        derr = abs(critical_path_ns(d) - delay) / delay * 100
        rows.append((f"tableIV_P{P}_D{D}_delay_ns", critical_path_ns(d), "ns",
                     f"paper={delay};err={derr:.1f}%"))
    # Table II module split (P=4, D=512)
    d = SchedulerDesign(P=4, D=512)
    rows.append(("tableII_queue_luts", queue_luts(d), "luts",
                 f"paper={PAPER_TABLE_II['priority_queue']['luts']}"))
    rows.append(("tableII_pe_handler_luts", pe_handler_luts(d), "luts",
                 f"paper={PAPER_TABLE_II['pe_handlers']['luts']}"))
    rows.append(("tableII_eft_selector_luts", eft_selector_luts(d), "luts",
                 f"paper={PAPER_TABLE_II['eft_selector']['luts']}"))
    rows.append(("tableII_total_utilization_pct",
                 utilization(d)["luts"] * 100, "pct", "paper=7.15%"))
    # Table III comparison points
    for key, ref in PAPER_TABLE_III.items():
        d = SchedulerDesign(P=ref["P"], D=ref["D"], W_avg=ref["W"],
                            W_exec=ref["W"])
        rows.append((f"tableIII_{key}_luts", total_luts(d), "luts",
                     f"paper={ref['luts']}"))
        rows.append((f"tableIII_{key}_delay_ns", critical_path_ns(d), "ns",
                     f"paper={ref['delay_ns']}"))
    return rows


if __name__ == "__main__":
    emit(run())
