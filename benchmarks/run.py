"""Benchmark harness — one module per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

  bench_cycle_model              Section VI-A complexity / 9.144 ns claim
  bench_resource_model           Tables II, III, IV
  bench_latency_vs_queue         Fig 4 (+183x, +2.6x, crossover)
  bench_functional_verification  Fig 3
  bench_exec_vs_injection        Fig 5 (31.7% claim)
  bench_frame_rate               Fig 6 (26.7% claim)
  bench_serve_scheduler          beyond-paper: LLM serving fleet
  bench_expert_placement         beyond-paper: MoE expert rebalancing
  bench_energy                   paper future-work: energy-aware HEFT_RT
  bench_roofline                 deliverable (g): per-cell roofline terms
"""

import importlib
import sys
import time

MODULES = [
    "bench_cycle_model",
    "bench_resource_model",
    "bench_latency_vs_queue",
    "bench_functional_verification",
    "bench_exec_vs_injection",
    "bench_frame_rate",
    "bench_serve_scheduler",
    "bench_expert_placement",
    "bench_energy",
    "bench_roofline",
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name in MODULES:
        if only and only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        rows = mod.run()
        for r in rows:
            n, us, derived = r
            us_s = f"{us:.3f}" if isinstance(us, (int, float)) else str(us)
            print(f"{n},{us_s},{derived}")
        print(f"_bench_wall_s_{name},{time.time()-t0:.1f},-")


if __name__ == "__main__":
    main()
