"""Benchmark harness — one module per paper table/figure + beyond-paper.

Prints ``name,value,unit,derived`` CSV rows (benchmarks/common.py; modules
emit 3-tuples for the implicit-µs legacy form or 4-tuples with an explicit
unit per row).

  bench_cycle_model              Section VI-A complexity / 9.144 ns claim
  bench_resource_model           Tables II, III, IV
  bench_latency_vs_queue         Fig 4 (+183x, +2.6x, crossover)
  bench_functional_verification  Fig 3
  bench_exec_vs_injection        Fig 5 (31.7% claim)
  bench_frame_rate               Fig 6 (26.7% claim)
  bench_serve_scheduler          beyond-paper: LLM serving fleet
  bench_serve_sharded            beyond-paper: mesh-backed fleet + cost model
  bench_paged_serve              beyond-paper: continuous batching / paged KV
  bench_mapping_fabric           beyond-paper: fabric-batched mapping events
  bench_fused_decision           beyond-paper: in-tick fused HEFT_RT decision
  bench_train_compress           beyond-paper: int8 pod-compressed train step
  bench_elastic_fleet            beyond-paper: elastic fleet resize events
  bench_chaos                    beyond-paper: failure-trace goodput + recovery
  bench_expert_placement         beyond-paper: MoE expert rebalancing
  bench_energy                   paper future-work: energy-aware HEFT_RT
  bench_roofline                 deliverable (g): per-cell roofline terms
  bench_obs_overhead             beyond-paper: repro.obs instrumentation cost
  bench_analysis                 infra: repro.analysis lint gate wall clock

``--json`` additionally writes one ``BENCH_<module>.json`` artifact per
module (``--outdir DIR``, default ``benchmarks/artifacts``) —
machine-readable rows plus wall time and environment stamps, the unit the
perf trajectory tracks across PRs.  A module-name substring as the first
positional arg still filters which modules run:

  PYTHONPATH=src:. python -m benchmarks.run serve_scheduler --json

``--check BASELINE.json [--tolerance 0.25]`` is the CI regression gate: the
freshly generated rows are compared against a tracked artifact (rows matched
on name+unit; directional by unit — a >tolerance rise in a time-like unit or
drop in a throughput-like unit is a regression).  Ratio rows derived from
other rows (``x``/``pct`` units) and ``_``-prefixed bookkeeping rows are
exempt.  Exit status 1 on any regression, so CI fails instead of silently
uploading worse artifacts:

  PYTHONPATH=src:. python -m benchmarks.run serve_scheduler \\
      --check benchmarks/artifacts/BENCH_serve_scheduler.json
"""

import argparse
import importlib
import json
import os
import platform
import subprocess
import sys
import time

from benchmarks import common

MODULES = [
    "bench_cycle_model",
    "bench_resource_model",
    "bench_latency_vs_queue",
    "bench_functional_verification",
    "bench_exec_vs_injection",
    "bench_frame_rate",
    "bench_serve_scheduler",
    "bench_serve_sharded",
    "bench_paged_serve",
    "bench_mapping_fabric",
    "bench_fused_decision",
    "bench_train_compress",
    "bench_elastic_fleet",
    "bench_chaos",
    "bench_expert_placement",
    "bench_energy",
    "bench_roofline",
    "bench_obs_overhead",
    "bench_analysis",
]

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "artifacts")

# Regression-gate direction by unit: -1 → lower is better (a rise beyond
# tolerance regresses), +1 → higher is better (a drop regresses).  Units not
# listed here — ratio/derived rows ("x", "pct"), counts, free-form — are
# informational and exempt from the gate.
CHECK_DIRECTION = {
    "ns": -1, "us": -1, "ms": -1, "s": -1, "B": -1, "requests": -1,
    "events/s": 1, "rps": 1, "tok/s": 1, "frames/s": 1, "GB/s": 1,
    "files/s": 1,
}

# Units whose rows are bit-deterministic (analytic models, not wall clock):
# they gate on ANY change, in either direction and regardless of
# --tolerance — a silent 4x wire-byte rise cannot hide inside a wall-clock
# module's loose gate, and a silent drop cannot quietly rewrite the
# baseline either (re-seed the artifact consciously when the model
# legitimately changes).
CHECK_EXACT_UNITS = {"B", "requests"}


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _json_rows(rows) -> list[dict]:
    out = []
    for row in rows:
        name, value, unit, derived = common.normalize_row(row)
        out.append({"name": name,
                    "value": value if isinstance(value, (int, float)) else str(value),
                    "unit": unit,
                    "derived": str(derived)})
    return out


def write_artifact(outdir: str, module: str, rows, wall_s: float) -> str:
    os.makedirs(outdir, exist_ok=True)
    short = module[len("bench_"):] if module.startswith("bench_") else module
    path = os.path.join(outdir, f"BENCH_{short}.json")
    try:
        import jax
        jax_ver = jax.__version__
    except Exception:
        jax_ver = None
    payload = {
        "module": module,
        "git_rev": _git_rev(),
        "time": time.time(),
        "wall_s": round(wall_s, 2),
        "python": platform.python_version(),
        "jax": jax_ver,
        "rows": _json_rows(rows),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def check_rows(rows, baseline: dict, tolerance: float) -> list[str]:
    """Compare fresh rows to a tracked artifact's rows.

    Matching is on (name, unit); the unit picks the regression direction
    (see CHECK_DIRECTION).  Derived ratio rows (unlisted units such as
    ``x``/``pct``), ``_``-prefixed bookkeeping rows, non-numeric values, and
    rows absent from the baseline are exempt.  CHECK_EXACT_UNITS rows are
    deterministic and fail on ANY change, in either direction, regardless
    of ``tolerance``.  Returns human-readable regression descriptions
    (empty → gate passes).
    """
    base = {(r["name"], r["unit"]): r["value"] for r in baseline.get("rows", [])
            if isinstance(r.get("value"), (int, float))}
    problems = []
    for row in rows:
        name, value, unit, _ = common.normalize_row(row)
        direction = CHECK_DIRECTION.get(unit)
        if (direction is None or name.startswith("_")
                or not isinstance(value, (int, float))):
            continue
        old = base.get((name, unit))
        if old is None:
            continue
        # Multiplicative in both directions so tolerance >= 1 stays
        # meaningful (an additive 1-tolerance drop-floor would go negative
        # and silently disable the throughput gate).
        if unit in CHECK_EXACT_UNITS:   # deterministic: any change fails
            bad = abs(value - old) > 1e-9 * max(1.0, abs(old))
        elif direction < 0:  # time-like: a rise beyond tolerance regresses
            bad = value > old * (1.0 + tolerance) and value - old > 1e-12
        else:                # throughput-like: a drop beyond tolerance
            bad = value < old / (1.0 + tolerance)
        if bad:
            pct = (value / old - 1.0) * 100 if old else float("inf")
            shown = 0.0 if unit in CHECK_EXACT_UNITS else tolerance
            problems.append(
                f"{name} [{unit}]: {old:.4g} -> {value:.4g} ({pct:+.1f}%, "
                f"tolerance ±{shown * 100:.0f}%)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(
        description="paper/beyond-paper benchmark harness")
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<module>.json artifacts")
    ap.add_argument("--outdir", default=DEFAULT_OUT, metavar="DIR",
                    help="artifact directory for --json "
                         "(default: benchmarks/artifacts)")
    ap.add_argument("--check", metavar="BASELINE.json", default=None,
                    help="benchmark-regression gate: compare generated rows "
                         "against this tracked artifact and exit 1 on a "
                         ">tolerance regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression tolerance for --check "
                         "(default 0.25)")
    args = ap.parse_args()

    baseline = None
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)

    regressions = []
    checked = 0
    print("name,value,unit,derived")
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        rows = mod.run()
        wall = time.time() - t0
        common.emit(rows)
        print(f"_bench_wall_s_{name},{wall:.1f},s,-")
        module_regs: list[str] = []
        if baseline is not None and baseline.get("module") in (name, None):
            checked += 1
            module_regs = check_rows(rows, baseline, args.tolerance)
            regressions += module_regs
        if args.json:
            if module_regs:
                # Never let a regressed run overwrite its own baseline: a
                # rerun of the gate would then silently pass.
                print(f"_bench_artifact_{name},-,skipped (regression gate)",
                      file=sys.stderr)
            else:
                path = write_artifact(args.outdir, name, rows, wall)
                print(f"_bench_artifact_{name},-,{path}", file=sys.stderr)

    if baseline is not None:
        if checked == 0:
            # A baseline that matched no module that ran must be loud: a
            # typo'd path/filter would otherwise turn the gate into a no-op.
            print(f"[check] baseline module "
                  f"{baseline.get('module')!r} did not match any module "
                  f"that ran — wrong --check path or filter?",
                  file=sys.stderr)
            sys.exit(2)
        if regressions:
            print(f"[check] {len(regressions)} benchmark regression(s) vs "
                  f"{args.check}:", file=sys.stderr)
            for p in regressions:
                print(f"[check]   {p}", file=sys.stderr)
            sys.exit(1)
        print(f"[check] OK — no regressions vs {args.check} "
              f"(tolerance ±{args.tolerance * 100:.0f}%)", file=sys.stderr)


if __name__ == "__main__":
    main()
