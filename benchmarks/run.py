"""Benchmark harness — one module per paper table/figure + beyond-paper.

Prints ``name,value,unit,derived`` CSV rows (benchmarks/common.py; modules
emit 3-tuples for the implicit-µs legacy form or 4-tuples with an explicit
unit per row).

  bench_cycle_model              Section VI-A complexity / 9.144 ns claim
  bench_resource_model           Tables II, III, IV
  bench_latency_vs_queue         Fig 4 (+183x, +2.6x, crossover)
  bench_functional_verification  Fig 3
  bench_exec_vs_injection        Fig 5 (31.7% claim)
  bench_frame_rate               Fig 6 (26.7% claim)
  bench_serve_scheduler          beyond-paper: LLM serving fleet
  bench_mapping_fabric           beyond-paper: fabric-batched mapping events
  bench_expert_placement         beyond-paper: MoE expert rebalancing
  bench_energy                   paper future-work: energy-aware HEFT_RT
  bench_roofline                 deliverable (g): per-cell roofline terms

``--json`` additionally writes one ``BENCH_<module>.json`` artifact per
module (``--outdir DIR``, default ``benchmarks/artifacts``) —
machine-readable rows plus wall time and environment stamps, the unit the
perf trajectory tracks across PRs.  A module-name substring as the first
positional arg still filters which modules run:

  PYTHONPATH=src:. python -m benchmarks.run serve_scheduler --json
"""

import argparse
import importlib
import json
import os
import platform
import subprocess
import sys
import time

from benchmarks import common

MODULES = [
    "bench_cycle_model",
    "bench_resource_model",
    "bench_latency_vs_queue",
    "bench_functional_verification",
    "bench_exec_vs_injection",
    "bench_frame_rate",
    "bench_serve_scheduler",
    "bench_mapping_fabric",
    "bench_expert_placement",
    "bench_energy",
    "bench_roofline",
]

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "artifacts")


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _json_rows(rows) -> list[dict]:
    out = []
    for row in rows:
        name, value, unit, derived = common.normalize_row(row)
        out.append({"name": name,
                    "value": value if isinstance(value, (int, float)) else str(value),
                    "unit": unit,
                    "derived": str(derived)})
    return out


def write_artifact(outdir: str, module: str, rows, wall_s: float) -> str:
    os.makedirs(outdir, exist_ok=True)
    short = module[len("bench_"):] if module.startswith("bench_") else module
    path = os.path.join(outdir, f"BENCH_{short}.json")
    try:
        import jax
        jax_ver = jax.__version__
    except Exception:
        jax_ver = None
    payload = {
        "module": module,
        "git_rev": _git_rev(),
        "time": time.time(),
        "wall_s": round(wall_s, 2),
        "python": platform.python_version(),
        "jax": jax_ver,
        "rows": _json_rows(rows),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(
        description="paper/beyond-paper benchmark harness")
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<module>.json artifacts")
    ap.add_argument("--outdir", default=DEFAULT_OUT, metavar="DIR",
                    help="artifact directory for --json "
                         "(default: benchmarks/artifacts)")
    args = ap.parse_args()

    print("name,value,unit,derived")
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        rows = mod.run()
        wall = time.time() - t0
        common.emit(rows)
        print(f"_bench_wall_s_{name},{wall:.1f},s,-")
        if args.json:
            path = write_artifact(args.outdir, name, rows, wall)
            print(f"_bench_artifact_{name},-,{path}", file=sys.stderr)


if __name__ == "__main__":
    main()
