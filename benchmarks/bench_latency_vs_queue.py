"""Fig 4: scheduling overhead vs ready-queue size; crossover; 183x / 2.6x."""

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import heft_rt_numpy
from repro.runtime import hw_compute_s, hw_overhead_s, hw_transfer_s, sw_overhead_s


def run():
    rows = []
    for n in [1, 2, 5, 10, 25, 50, 100, 250, 500, 1000, 1330]:
        rows.append((f"fig4_sw_n{n}", sw_overhead_s(n) * 1e6, "modeled_sw"))
        rows.append((f"fig4_hw_n{n}", hw_overhead_s(n) * 1e6,
                     f"compute={hw_compute_s(n)*1e6:.3f}us;"
                     f"xfer={hw_transfer_s(n)*1e6:.3f}us"))
    # crossover point
    cross = next(n for n in range(1, 100)
                 if sw_overhead_s(n) > hw_overhead_s(n))
    rows.append(("fig4_crossover_queue_size", cross, "n", "paper=5..6"))
    rows.append(("fig4_speedup_compute_only_n1330",
                 sw_overhead_s(1330) / hw_compute_s(1330), "x", "paper=183x"))
    rows.append(("fig4_speedup_end_to_end_n1330",
                 sw_overhead_s(1330) / hw_overhead_s(1330), "x", "paper=2.6x"))
    # measured software scheduler on this host for scale reference
    rng = np.random.default_rng(0)
    for n in [100, 1330]:
        us = time_call(heft_rt_numpy, rng.uniform(0.1, 5, n),
                       rng.uniform(0.1, 5, (n, 4)), np.zeros(4), repeats=3)
        rows.append((f"fig4_measured_numpy_sw_n{n}", us, "this_host"))
    return rows


if __name__ == "__main__":
    emit(run())
