"""Fig 6: target vs achieved frame rate; saturation levels; 26.7% claim."""

import numpy as np

from benchmarks.common import emit
from repro.runtime import HW_MODEL, SW_MODEL, CedrSimulator, paper_soc_pe_types
from repro.runtime.workload import high_latency_arrivals


def run():
    rows = []
    pes = paper_soc_pe_types()
    sat_sw, sat_hw = [], []
    for rate in [50, 100, 150, 200, 250, 300, 400, 500, 600, 675]:
        sw_v, hw_v = [], []
        for seed in range(3):
            arr = high_latency_arrivals(rate, seed=seed)
            sw_v.append(CedrSimulator(pes, overhead=SW_MODEL, seed=7 + seed)
                        .run(arr).achieved_frame_rate)
            hw_v.append(CedrSimulator(pes, overhead=HW_MODEL, seed=7 + seed)
                        .run(arr).achieved_frame_rate)
        sw, hw = float(np.mean(sw_v)), float(np.mean(hw_v))
        if rate >= 400:
            sat_sw.append(sw)
            sat_hw.append(hw)
        rows.append((f"fig6_achieved_at_target{rate}", sw, "fps", f"hw={hw:.1f}fps"))
    gain = (np.mean(sat_hw) / np.mean(sat_sw) - 1) * 100
    rows.append(("fig6_saturated_sw_fps", float(np.mean(sat_sw)), "fps", "paper=161.51"))
    rows.append(("fig6_saturated_hw_fps", float(np.mean(sat_hw)), "fps", "paper=204.62"))
    rows.append(("fig6_hw_gain_pct", float(gain), "pct", "paper=26.7%"))
    return rows


if __name__ == "__main__":
    emit(run())
