"""Paper complexity claims (Section VI-A): 3n+3 cycles, 9.144 ns/decision.

Also times the actual schedulers on THIS host: the Pallas overlay kernel in
interpret mode (correctness-path, not a TPU timing) and the numpy software
scheduler (a real software-HEFT_RT measurement).
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import (
    PAPER_CRITICAL_PATH_NS,
    heft_rt_numpy,
    per_decision_latency_ns,
    simulate_mapping_event,
    worst_case_cycles,
)
from repro.kernels import heft_rt_hw


def run():
    rows = []
    rng = np.random.default_rng(0)
    for n in [1, 5, 16, 64, 256, 512, 1330]:
        rep = simulate_mapping_event(rng.uniform(0, 1, n))
        hw_ns = worst_case_cycles(n) * PAPER_CRITICAL_PATH_NS
        rows.append((f"cycles_n{n}", hw_ns / 1e3,
                     f"sim={rep.total_cycles};bound={worst_case_cycles(n)};"
                     f"first_decision={rep.first_decision_cycle}"))
    rows.append(("per_decision_ns_D512_P4",
                 per_decision_latency_ns(512, PAPER_CRITICAL_PATH_NS,
                                         asymptotic=True) / 1e3, "us",
                 "paper=9.144ns"))
    # real wall-clock of software scheduler (numpy, this host)
    for n in [16, 128, 512, 1330]:
        avg = rng.uniform(0.1, 5, n)
        ex = rng.uniform(0.1, 5, (n, 4))
        us = time_call(heft_rt_numpy, avg, ex, np.zeros(4), repeats=5)
        rows.append((f"sw_numpy_mapping_event_n{n}", us, "measured_on_host"))
    # Pallas overlay (interpret mode on CPU — correctness path)
    avg = jnp.array(rng.uniform(0.1, 5, 256).astype(np.float32))
    ex = jnp.array(rng.uniform(0.1, 5, (256, 4)).astype(np.float32))
    av = jnp.zeros(4)
    us = time_call(lambda: heft_rt_hw(avg, ex, av)[1].block_until_ready(),
                   repeats=3)
    rows.append(("pallas_overlay_interpret_n256", us,
                 "interpret-mode;TPU target validated by lowering"))
    return rows


if __name__ == "__main__":
    emit(run())
