"""Beyond-paper: chaos tier — goodput under failures + recovery accounting.

The acceptance row for the chaos tier: a pod-class fleet at ~60% utilization
(the N+1 headroom a production fleet carries) replays a 2-failure trace —
one replica lost outright mid-run, one straggling ×4 for a window — and the
requests served inside the SLO must stay at ≥90% of the failure-free run
with ZERO silently-dropped requests.  The module enforces its own floor by
raising (``pct`` rows are exempt from the harness's directional gate, so a
quiet goodput collapse cannot hide behind the ratio-row exemption), and the
re-queue/unserved counts ride the exact gate (unit ``requests``,
CHECK_EXACT_UNITS): any drift in the recovery books fails CI bit-for-bit.

Rows are fully deterministic (seeded arrivals, analytic roofline, pure
event-driven topology) — no wall clock anywhere near the gate.
"""

import numpy as np

from repro.sched_integration import (
    FailureEvent,
    POLICIES,
    Replica,
    goodput,
    make_requests,
    simulate_serving,
    spine_topology,
)

ACTIVE = 7e9
SLO_S = 2.0
GOODPUT_FLOOR_PCT = 90.0


def _fleet():
    """Four pod-class replicas (a speed-1.0 pod ≈ a 256-chip v5e slice at
    50% MFU) — the launcher's simulator-twin rate model."""
    return [Replica(f"pod{i}", 25000.0 * s, 126000.0 * s)
            for i, s in enumerate((1.0, 1.0, 0.7, 1.4))]


def _trace():
    """The 2-failure acceptance trace: one loss, one straggler window."""
    return [
        FailureEvent(0.4, "replica_loss", "pod1", reason="host down"),
        FailureEvent(0.8, "straggler", "pod0", duration_s=0.5, factor=4.0,
                     reason="thermal throttle"),
    ]


def run():
    rows = []

    # ~60% of fleet capacity offered for 2s of arrivals.
    fleet = _fleet()
    rate = 24.0 * sum(r.compute_tflops / 25000.0 for r in fleet)
    reqs = make_requests(rate, 2.0, seed=0)
    clean = simulate_serving(_fleet(), reqs, POLICIES["heft_rt"](),
                             active_params=ACTIVE)
    chaos = simulate_serving(_fleet(), reqs, POLICIES["heft_rt"](),
                             active_params=ACTIVE, failure_events=_trace())

    g_clean = goodput(clean, reqs, SLO_S)
    g_chaos = goodput(chaos, reqs, SLO_S)
    pct = 100.0 * g_chaos / max(g_clean, 1)
    requeued = int((chaos.requeued > 0).sum())
    unserved = int((~chaos.served_mask).sum())
    # Recovery latency: the loss instant → the last request it displaced
    # lands on a survivor.  Pure simulator arithmetic, deterministic.
    displaced = chaos.finish_times[chaos.requeued > 0]
    recovery_ms = (float(displaced.max()) - 0.4) * 1e3 if len(displaced) else 0.0

    if pct < GOODPUT_FLOOR_PCT:
        # pct rows are exempt from the directional gate by design (derived
        # ratios), so the chaos floor is enforced here, loudly.
        raise RuntimeError(
            f"chaos goodput {pct:.1f}% under the 2-failure trace fell below "
            f"the {GOODPUT_FLOOR_PCT}% acceptance floor "
            f"({g_chaos}/{g_clean} in-SLO)")
    if unserved:
        raise RuntimeError(
            f"{unserved} requests silently dropped under the 2-failure "
            f"trace — the recovery contract requires zero")

    rows += [
        ("chaos_goodput_pct", pct, "pct",
         f"derived;2-failure trace vs failure-free;SLO={SLO_S}s;"
         f"floor {GOODPUT_FLOOR_PCT}% enforced in-module"),
        ("chaos_goodput_clean", float(g_clean), "count",
         f"in-SLO serves, failure-free;N={len(reqs)}"),
        ("chaos_recovery_ms", recovery_ms, "ms",
         "replica_loss@0.4s -> last displaced request served"),
        ("chaos_requeued", float(requeued), "requests",
         "exact;requests re-queued by the trace (never dropped)"),
        ("chaos_unserved", float(unserved), "requests",
         "exact;must be 0 — silently dropped requests crash the simulator"),
    ]

    # Topology contention: two concurrent pod migrations over one spine
    # serialize instead of magically overlapping — the serialization factor
    # is an analytic invariant of the FIFO reservation model.
    topo = spine_topology(["gw", "podA", "podB"], 100.0)
    _, f1 = topo.transfer_s(2.0 * ACTIVE, "gw", "podA", at=0.0)
    _, f2 = topo.transfer_s(2.0 * ACTIVE, "gw", "podB", at=0.0)
    rows.append(("_spine_migration_serialization_x", f2 / f1, "x",
                 "2nd concurrent migration queues behind the 1st on gw:spine"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
