"""Lint gate cost: ``repro.analysis`` full-repo wall clock + throughput.

The lint pass runs in CI *before* the tier-1 suite on every push, so its
cost is paid on every iteration of every PR — it has to stay cheap enough
that nobody is tempted to carve it out of the loop.  Tracked here:

  * ``analysis_full_repo`` — one cold run over ``src/`` with all rules
    (context rebuilt per repeat: parse + file rules + repo rules), ms;
  * ``analysis_files_per_s`` — the same run as throughput, so the gate
    scales honestly when the file count grows;
  * ``_analysis_*`` bookkeeping — files/rules/findings counts (exempt
    from the gate; they move whenever the repo or catalogue grows).

The wall-clock row is CI-gated against ``BENCH_analysis.json`` with the
loose shared-runner tolerance (``--tolerance 5.0``): the target class of
regression is an accidentally quadratic rule (10-100x), not jitter.
"""

import time
from pathlib import Path

from repro.analysis import (apply_baseline, default_context, load_baseline,
                            run_analysis)

ROOT = Path(__file__).resolve().parent.parent
REPEATS = 5


def _one_run():
    """One cold lint pass; returns (elapsed_s, result)."""
    t0 = time.perf_counter()
    ctx = default_context(ROOT)                 # fresh source cache each time
    result = run_analysis(ctx)
    return time.perf_counter() - t0, result


def run():
    _one_run()                                  # warm imports / FS cache
    times, result = [], None
    for _ in range(REPEATS):
        dt, result = _one_run()
        times.append(dt)
    times.sort()
    median_s = times[len(times) // 2]

    baseline = load_baseline(ROOT / "tools" / "analysis_baseline.json")
    fresh, absorbed = apply_baseline(result.findings, baseline)

    files = len(default_context(ROOT).files)
    rules = len(result.rules)
    detail = f"files={files};rules={rules}"
    return [
        ("analysis_full_repo", median_s * 1e3, "ms", detail),
        ("analysis_files_per_s", files / median_s, "files/s", detail),
        ("_analysis_files", files, "count", "scanned under src/"),
        ("_analysis_rules", rules, "count", "registered rules"),
        ("_analysis_findings_fresh", len(fresh), "count",
         "must be 0 — the CI lint step gates on it"),
        ("_analysis_findings_baselined", absorbed, "count",
         "grandfathered via tools/analysis_baseline.json"),
        ("_analysis_findings_noqa", len(result.suppressed), "count",
         "per-line repro: noqa[...] suppressions"),
    ]
