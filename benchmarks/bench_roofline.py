"""Roofline analysis — deliverable (g).

Per (arch × shape × mesh) cell, from the dry-run compiled artifact:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_device / HBM_bw              [s]
  collective term = wire_bytes_per_device / ICI_link_bw        [s]

(Cost analysis on the partitioned module is per-device, so the formula's
"/ chips" is already applied; a single effective ICI link per device is a
conservative lower bound on fabric bandwidth.)

Also reported: dominant term, MODEL_FLOPS = {6,2}·N_active·tokens, the
useful-flops ratio MODEL_FLOPS / (HLO_FLOPs·chips) (remat/padding waste
shows up here), and the roofline fraction = compute / max(all terms) —
the fraction of ideal compute throughput achievable at perfect overlap.

Writes experiments/roofline.csv for EXPERIMENTS.md §Roofline.
"""

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import get_config
from repro.models.config import SHAPES
from repro.models.model import model_flops

PEAK_FLOPS = 197e12      # bf16 / chip (v5e-class)
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link

ART = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "artifacts", "dryrun")


def analyze(d: dict) -> dict | None:
    if "error" in d or "weighted" not in d:
        return None
    shape = SHAPES[d["shape"]]
    cfg = get_config(d["arch"])
    chips = d["num_devices"]
    w = d["weighted"]                        # trip-count-weighted per-device
    t_c = w["dot_flops_per_device"] / PEAK_FLOPS
    # memory term: matmul operand/result streams (+ params resident reads are
    # included — weights are dot operands); elementwise fusions add ~O(1)×
    # activation traffic on top, documented in EXPERIMENTS.md §Roofline.
    t_m = w["dot_bytes_per_device"] / HBM_BW
    t_x = w["total_wire_bytes_per_device"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(cfg, tokens, train=shape.kind == "train")
    useful = mf / max(w["dot_flops_per_device"] * chips, 1.0)
    frac = t_c / max(t_c, t_m, t_x)
    return {
        "cell": f"{d['arch']}×{d['shape']}×{d['mesh']}"
                + (f"[{d['variant']}]" if d.get("variant") else ""),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0], "roofline_fraction": frac,
        "useful_flops_ratio": useful,
        "temp_gb": (d["memory"]["temp_size_in_bytes"] or 0) / 1e9,
        "arg_gb": (d["memory"]["argument_size_in_bytes"] or 0) / 1e9,
    }


def run():
    rows = []
    table = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        # hillclimb variant artifacts carry a suffix beyond _single/_multi
        stem = os.path.basename(path)[:-5]
        for mesh_tag in ("_single", "_multi"):
            if mesh_tag in stem:
                suffix = stem.split(mesh_tag, 1)[1].lstrip("_")
                if suffix:
                    d["variant"] = suffix
        a = analyze(d)
        if a is None:
            rows.append((f"roofline_{os.path.basename(path)[:-5]}", "ERROR",
                         d.get("error", "")[:80]))
            continue
        table.append(a)
        rows.append((f"roofline_{a['cell']}", a["compute_s"] * 1e3, "ms",
                     f"mem={a['memory_s']*1e3:.2f}ms;"
                     f"coll={a['collective_s']*1e3:.2f}ms;"
                     f"dom={a['dominant']};"
                     f"frac={a['roofline_fraction']:.3f};"
                     f"useful={a['useful_flops_ratio']:.3f}"))
    # CSV for EXPERIMENTS.md
    out = os.path.join(ART, "..", "..", "roofline.csv")
    with open(out, "w") as f:
        f.write("cell,compute_ms,memory_ms,collective_ms,dominant,"
                "roofline_fraction,useful_flops_ratio,temp_gb,arg_gb\n")
        for a in table:
            f.write(f"{a['cell']},{a['compute_s']*1e3:.3f},"
                    f"{a['memory_s']*1e3:.3f},{a['collective_s']*1e3:.3f},"
                    f"{a['dominant']},{a['roofline_fraction']:.4f},"
                    f"{a['useful_flops_ratio']:.4f},{a['temp_gb']:.2f},"
                    f"{a['arg_gb']:.2f}\n")
    rows.append(("roofline_cells_analyzed", len(table), "count", f"csv={out}"))
    return rows


if __name__ == "__main__":
    emit(run())
