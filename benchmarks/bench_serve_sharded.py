"""Beyond-paper: mesh-backed heterogeneous serve fleet with dry-run cost
models (the sharded-serve tentpole).

The fleet is ``mesh_fleet`` — mixed-size mesh slices of one chip generation
(two 16×16 pods, a 4×16, a 4×4) — and the HEFT_RT Exec_TID matrix is derived
two ways: the analytic roofline, and the cost-model registry seeded with a
"measured" (16×16) dry-run cell projected onto the smaller slices at 92%
scaling efficiency.  The measured cells carry what the analytic 2·N·tokens
roofline misses (quadratic attention FLOPs in prefill, the KV-cache stream
in decode), so the cost-model rows are the honest numbers.

Simulation rows are **deterministic** (seeded workload, exact simulated
milliseconds) — the CI regression gate compares them at tight tolerance.
The one wall-clock row (`exec_tid_matrix_build`) measures the registry's
matrix materialization.
"""

import numpy as np

from benchmarks.common import emit, time_call
from repro.sched_integration import (
    CostCell,
    CostModelRegistry,
    POLICIES,
    make_requests,
    mesh_fleet,
    scaled_cell,
    simulate_serving,
)

ACTIVE = 7e9                 # deepseek-7b-class serving
MESH_SHAPES = ((16, 16), (16, 16), (4, 16), (4, 4))


def build_registry(arch: str = "deepseek-7b") -> CostModelRegistry:
    """Measured (16×16) prefill/decode cells, projected onto smaller slices."""
    measured = [
        CostCell(arch, "prefill", (16, 16), tokens_per_step=32 * 32768,
                 flops_per_device=1.15 * 2.0 * ACTIVE * 32 * 32768 / 256,
                 bytes_per_device=6.1e10),
        CostCell(arch, "decode", (16, 16), tokens_per_step=128,
                 flops_per_device=2.0 * ACTIVE * 128 / 256,
                 bytes_per_device=1.30 * 2.0 * ACTIVE * 128 / 256),
    ]
    reg = CostModelRegistry(measured)
    for cell in measured:
        for shape in ((4, 16), (4, 4)):
            reg.register(scaled_cell(cell, shape, efficiency=0.92))
    return reg


def run():
    rows = []
    fleet = mesh_fleet("deepseek-7b", MESH_SHAPES)
    reg = build_registry()

    results = {}
    for rate in (400, 1600):
        reqs = make_requests(rate_rps=rate, duration_s=3.0, seed=0)
        for src, kw in (("roofline", {}), ("costmodel", {"cost_registry": reg})):
            r = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                                 active_params=ACTIVE, **kw)
            results[(src, rate)] = r
            rows.append((f"serve_sharded_{src}_rate{rate}",
                         r.mean_latency * 1e3, "ms",
                         f"achieved={r.achieved_rps:.0f}rps;"
                         f"p99={r.p99_latency*1e3:.0f}ms"))
        rr = simulate_serving(fleet, reqs, POLICIES["round_robin"](),
                              active_params=ACTIVE, cost_registry=reg)
        rows.append((f"serve_sharded_rr_costmodel_rate{rate}",
                     rr.mean_latency * 1e3, "ms",
                     f"achieved={rr.achieved_rps:.0f}rps"))

    # derived (exempt from the gate): how much latency the analytic roofline
    # underestimates by hiding attention/KV overheads, at oversubscription
    h, c = results[("roofline", 1600)], results[("costmodel", 1600)]
    rows.append(("serve_sharded_costmodel_vs_roofline_latency_pct",
                 (c.mean_latency / h.mean_latency - 1) * 100, "pct",
                 "costmodel_exec_tid_minus_roofline"))

    # registry throughput: Exec_TID materialization for one big mapping
    # event.  Wall-clock, so emitted as a `_`-bookkeeping row — informational
    # in the artifact, exempt from the regression gate (the module's ms rows
    # are deterministic and gate at tight tolerance).
    reqs = make_requests(rate_rps=1600, duration_s=3.0, seed=0)
    us = time_call(lambda: reg.exec_tid_matrix(reqs, fleet,
                                               active_params=ACTIVE),
                   repeats=5, warmup=1)
    rows.append(("_exec_tid_matrix_build", us, "us",
                 f"N={len(reqs)};P={len(fleet)};cells={len(reg)}"))
    return rows


if __name__ == "__main__":
    emit(run())
