"""Beyond-paper: HEFT_RT expert→device placement vs default round-robin."""

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.sched_integration import (
    makespan,
    plan_expert_placement,
    round_robin_assignment,
)


def run():
    rows = []
    rng = np.random.default_rng(0)
    for arch in ["deepseek_v2_236b", "arctic_480b", "jamba_v0_1_52b"]:
        cfg = get_config(arch)
        E = cfg.moe.num_experts
        P = 16  # EP group = model axis
        for skew in [0.5, 1.1]:
            load = rng.permutation(np.arange(1, E + 1) ** -skew)
            speed = np.ones(P)
            h = plan_expert_placement(load, speed)
            rr = round_robin_assignment(E, P)
            ms_h, ms_rr = makespan(load, speed, h), makespan(load, speed, rr)
            lower = max(load.max(), load.sum() / P)
            rows.append((f"ep_{arch}_skew{skew}", ms_h / lower, "x",
                         f"rr={ms_rr/lower:.3f}x_lower_bound;"
                         f"gain={(1-ms_h/ms_rr)*100:.1f}%"))
    # heterogeneous device speeds (mixed-generation pods)
    load = rng.permutation(np.arange(1, 161) ** -1.0)
    speed = np.concatenate([np.ones(8), np.full(8, 0.6)])
    h = plan_expert_placement(load, speed)
    rr = round_robin_assignment(160, 16)
    rows.append(("ep_hetero_fleet_gain_pct",
                 (1 - makespan(load, speed, h) / makespan(load, speed, rr)) * 100,
                 "pct", "16dev_mixed_speed"))
    return rows


if __name__ == "__main__":
    emit(run())
