"""Beyond-paper: pod-compressed training step — wire bytes + step time.

Exact f32 vs int8 error-feedback cross-pod gradient reduction on a (2, 2)
``(pod, data)`` mesh (fake CPU devices, spawned in a subprocess so the fixed
device count of this process is untouched).  Two row families:

* ``..._wire_*`` [B] — deterministic per-step cross-pod payload model:
  f32 sends 4 bytes/element; the int8 collective sends 1 byte/element plus
  one f32 absmax per leaf (the shared-grid ``pmax``).  This is the *logical*
  wire format — the CPU emulation in ``dist/compression.py`` materializes the
  int32 accumulator, a real multi-pod deployment sums int8 payloads with
  int32 accumulation on the wire.
* ``..._step_*`` [ms] — measured steady-state train-step wall time through
  the full residual-carrying ``make_train_step`` pod path (vmap-over-pods
  gradients + shard_map manual reduce), compilation excluded by warmup.

The CI gate (run.py --check) tracks both: a wire-bytes rise means the
compression silently widened; a step-time blowup means the pod path started
recompiling or falling off the fast path.
"""

import json

from tests._subproc import run_sub

_SUB = """
import json, time
import jax, jax.numpy as jnp
from repro.models import ModelConfig
from repro.models.model import init_params
from repro.optim import AdamWConfig, init_opt_state
from repro.data import DataConfig, TokenPipeline
from repro.train import make_train_step

cfg = ModelConfig(name='bench', num_layers=4, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=256, vocab_size=256,
                  param_dtype='float32', compute_dtype='float32')
ocfg = AdamWConfig(learning_rate=1e-3)
params = init_params(jax.random.key(0), cfg)
opt = init_opt_state(params, ocfg)
batch = {k: jnp.asarray(v) for k, v in TokenPipeline(
    DataConfig(vocab_size=256, seq_len=64, global_batch=8)
).batch_at(0).items()}

from repro.dist.compression import (EXACT_BYTES_PER_ELEM, WIRE_BYTES_PER_ELEM,
                                    WIRE_SCALE_BYTES_PER_LEAF)

leaves = jax.tree.leaves(params)
n_elems = sum(l.size for l in leaves)
n_leaves = len(leaves)

mesh = jax.make_mesh((2, 2), ('pod', 'data'))
out = {'n_elems': int(n_elems), 'n_leaves': int(n_leaves),
       'params_m': float(n_elems / 1e6),
       'wire_exact': int(EXACT_BYTES_PER_ELEM * n_elems),
       'wire_int8': int(WIRE_BYTES_PER_ELEM * n_elems
                        + WIRE_SCALE_BYTES_PER_LEAF * n_leaves)}

def timed(step, state):
    p, o, r = state
    p, o, r, _ = step(p, o, r, batch)          # warmup/compile
    p, o, r, m = step(p, o, r, batch)
    jax.block_until_ready(m['loss'])
    times = []
    for _ in range(7):
        t0 = time.perf_counter()
        p, o, r, m = step(p, o, r, batch)
        jax.block_until_ready(m['loss'])
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3        # median ms

with jax.set_mesh(mesh):
    for name, compress in (('exact', False), ('int8', True)):
        step = jax.jit(make_train_step(cfg, ocfg, pod_axis='pod',
                                       compress_pods=compress, mesh=mesh),
                       donate_argnums=(0, 1, 2))
        state = (init_params(jax.random.key(0), cfg),
                 init_opt_state(params, ocfg), None)
        out[f'step_{name}_ms'] = timed(step, state)

print(json.dumps(out))
"""


def _measure() -> dict:
    # same fake-device subprocess runner the multi-device tests use
    out = run_sub(_SUB, devices=4)
    return json.loads(out.strip().splitlines()[-1])


def run():
    m = _measure()
    # per-pod per-step cross-pod payload (the slow-link traffic), derived
    # from dist.compression's wire-format constants inside the subprocess
    wire_exact = m["wire_exact"]
    wire_int8 = m["wire_int8"]
    rows = [
        ("train_compress_wire_exact", float(wire_exact), "B",
         f"f32 all-reduce payload;elems={m['n_elems']}"),
        ("train_compress_wire_int8", float(wire_int8), "B",
         f"int8 payload + f32 amax/leaf;leaves={m['n_leaves']}"),
        ("train_compress_wire_ratio", wire_exact / wire_int8, "x",
         "exact/int8 wire bytes;acceptance>=3.5"),
        ("train_compress_step_exact", m["step_exact_ms"], "ms",
         f"(2,2) mesh pod step;params={m['params_m']:.2f}M"),
        ("train_compress_step_int8", m["step_int8_ms"], "ms",
         "int8 error-feedback reduce, residual carried"),
        ("train_compress_int8_overhead", m["step_int8_ms"] / m["step_exact_ms"],
         "x", "int8 step time / exact step time"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
