"""Paper future-work extension: energy-aware HEFT_RT Pareto frontier."""

import numpy as np

from benchmarks.common import emit
from repro.core.heft_energy import energy_pareto
from repro.runtime.apps import get_app, paper_soc_pe_types


def run():
    rows = []
    # the paper's SoC: FFT accelerator is fast AND efficient for FFTs;
    # power model: A53 ≈ 1.0 W-unit, FFT IP ≈ 0.3
    app = get_app("PD")
    ex = app.exec_matrix(paper_soc_pe_types())
    finite = np.where(np.isfinite(ex), ex, np.nan)
    avg = np.nanmean(finite, axis=1)
    power = np.array([1.0, 1.0, 1.0, 0.3])
    for lam, makespan, energy in energy_pareto(avg, ex, power):
        rows.append((f"energy_pareto_lam{lam}", makespan * 1e3, "ms",
                     f"energy={energy:.3f}W*ms"))
    pts = energy_pareto(avg, ex, power)
    rows.append(("energy_saving_at_max_lambda_pct",
                 (1 - pts[-1][2] / pts[0][2]) * 100, "pct",
                 f"makespan_cost={((pts[-1][1]/pts[0][1])-1)*100:.1f}%"))
    return rows


if __name__ == "__main__":
    emit(run())
