"""§Perf hillclimb runner: lower a cell under a policy/flag variant, print the
three roofline terms next to the baseline, append to the iteration log.

  PYTHONPATH=src python experiments/hillclimb.py <arch> <shape> <variant>

Variants are registered below: each is (description, kwargs for dryrun_cell)
or a policy-transform function.  Results cache under
experiments/artifacts/dryrun/<cell>_<variant>.json.
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,convert-mover "
    + os.environ.get("XLA_FLAGS", ""))

import json      # noqa: E402
import sys       # noqa: E402

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.dist.sharding import activation_hint_policy   # noqa: E402
from repro.launch.dryrun import ARTIFACT_DIR, cell_path, dryrun_cell  # noqa: E402
from repro.launch.mesh import mesh_axes                  # noqa: E402
from repro.models.config import SHAPES                   # noqa: E402

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def terms(d):
    w = d["weighted"]
    return (w["dot_flops_per_device"] / PEAK,
            w["dot_bytes_per_device"] / HBM,
            w["total_wire_bytes_per_device"] / LINK)


def base_policy(arch, shape_name):
    cfg = get_config(arch)
    return dict(activation_hint_policy(cfg, mesh_axes(), SHAPES[shape_name]))


# ---------------------------------------------------------------------------
# variant registry: name → (description, fn(arch, shape) -> dryrun kwargs)
# ---------------------------------------------------------------------------

def v_sp_gather(arch, shape_name):
    """Megatron-SP: gather activations over 'model' at each sublayer input;
    matmuls then keep weights local (col/row parallel) and the boundary
    constraint reduce-scatters the partial sums."""
    pol = base_policy(arch, shape_name)
    pol["sublayer_input"] = P("data", None, None)
    return {"policy_override": pol}


def v_no_fsdp(arch, shape_name):
    """Replicate params over 'data' (TP-only): kills FSDP weight gathers —
    decode cells are weight-gather-bound; fits when params/16 ≤ HBM."""
    return {"fsdp": False}


def v_sp_and_no_fsdp(arch, shape_name):
    kw = v_sp_gather(arch, shape_name)
    kw["fsdp"] = False
    return kw


def v_groups_data_only(arch, shape_name):
    """MoE dispatch groups over 'data' only (bigger groups, less padding)."""
    pol = base_policy(arch, shape_name)
    pol["moe_groups"] = P("data", None, None)
    pol["moe_groups4"] = P("data", None, None, None)
    pol["__moe_groups__"] = SHAPES[shape_name].global_batch
    return {"policy_override": pol}


def v_qpos_attention(arch, shape_name):
    """Attention sharded on QUERY POSITIONS instead of heads: head counts
    8/10/24/56 pad over model=16 and GSPMD re-gathers the softmax carries on
    every inner step (the dominant baseline collective).  One full-S q block
    with S-over-model sharded q/carries is padding-free for every arch."""
    pol = base_policy(arch, shape_name)
    pol["attn_heads"] = P("data", "model", None, None)   # (B, S, H, hd)
    pol["__attn_q_chunk__"] = "full"
    return {"policy_override": pol}


def v_qpos_sp(arch, shape_name):
    kw = v_qpos_attention(arch, shape_name)
    kw["policy_override"]["sublayer_input"] = P("data", None, None)
    return kw


def v_qpos_kvg(arch, shape_name):
    """qpos + gather K/V once per layer (replicated over 'model' for the
    kv-chunk scan) instead of a full re-gather per chunk step."""
    kw = v_qpos_attention(arch, shape_name)
    kw["policy_override"]["attn_kv"] = P("data", None, None, None)
    return kw


def v_qpos_kvg_sp(arch, shape_name):
    kw = v_qpos_kvg(arch, shape_name)
    kw["policy_override"]["sublayer_input"] = P("data", None, None)
    return kw


def v_qpos_nofsdp(arch, shape_name):
    kw = v_qpos_attention(arch, shape_name)
    kw["fsdp"] = False
    return kw


def v_qpos_kvg_tponly(arch, shape_name):
    """qpos + kv gather + TP-only weights (no FSDP gathers at all); optimizer
    moments stay 2D-sharded (data×model) — one param reshard per step."""
    kw = v_qpos_kvg(arch, shape_name)
    kw["fsdp"] = False
    kw["opt_2d"] = True
    return kw


def v_qpos_kvg_expfsdp(arch, shape_name):
    """qpos + kvg + FSDP restricted to expert tensors (attention/dense/router
    weights TP-only — small enough replicated over data, so their per-layer
    FSDP gathers disappear; experts keep ZeRO-3, which they need to fit)."""
    kw = v_qpos_kvg(arch, shape_name)
    kw["fsdp"] = False
    kw["fsdp_experts_only"] = True
    kw["opt_2d"] = True
    return kw


def v_flash_decode(arch, shape_name):
    """Flash-decode: KV cache sharded on SEQUENCE over 'model' + TP-only
    weights; per-layer collectives shrink to (B,H,1)-sized softmax/output
    partials."""
    pol = base_policy(arch, shape_name)
    pol["attn_heads"] = P("data", None, None, None)   # q replicated over m
    return {"policy_override": pol, "fsdp": False, "cache_seq_shard": True}


VARIANTS = {
    "sp": ("SP activation gather over model at sublayer inputs", v_sp_gather),
    "nofsdp": ("TP-only params (no FSDP gathers)", v_no_fsdp),
    "sp+nofsdp": ("SP + TP-only", v_sp_and_no_fsdp),
    "moegroups-d": ("MoE groups over data only", v_groups_data_only),
    "qpos": ("attention sharded on query positions (padding-free)",
             v_qpos_attention),
    "qpos+sp": ("qpos attention + SP sublayer inputs", v_qpos_sp),
    "qpos+nofsdp": ("qpos attention + TP-only params", v_qpos_nofsdp),
    "qpos+kvg": ("qpos + one-shot K/V gather per layer", v_qpos_kvg),
    "qpos+kvg+sp": ("qpos + K/V gather + SP inputs", v_qpos_kvg_sp),
    "qpos+kvg+tponly": ("qpos + K/V gather + TP-only weights (2D opt)",
                        v_qpos_kvg_tponly),
    "flashdecode": ("KV cache sharded on sequence + TP-only weights",
                    v_flash_decode),
    "qpos+kvg+expfsdp": ("qpos + kvg + FSDP on experts only",
                         v_qpos_kvg_expfsdp),
}


def main():
    arch, shape_name, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    desc, fn = VARIANTS[variant]
    base_file = cell_path(arch.replace("-", "_").replace(".", "_"),
                          shape_name, False)
    # artifacts written by run_all use config module naming
    if not os.path.exists(base_file):
        base_file = os.path.join(ARTIFACT_DIR,
                                 f"{arch}_{shape_name}_single.json")
    base = json.load(open(base_file)) if os.path.exists(base_file) else None

    kw = fn(arch, shape_name)
    res = dryrun_cell(arch, shape_name, False, verbose=False, **kw)
    out = cell_path(arch.replace("-", "_").replace(".", "_"), shape_name,
                    False, tag=variant)
    with open(out, "w") as f:
        json.dump(res, f, indent=1)

    tc, tm, tx = terms(res)
    print(f"\n=== {arch} × {shape_name} × 16x16 — variant '{variant}' ===")
    print(f"  {desc}")
    if base and "weighted" in base:
        bc, bm, bx = terms(base)
        print(f"  compute   : {bc*1e3:10.1f} → {tc*1e3:10.1f} ms  ({tc/bc:5.2f}x)")
        print(f"  memory    : {bm*1e3:10.1f} → {tm*1e3:10.1f} ms  ({tm/bm:5.2f}x)")
        print(f"  collective: {bx*1e3:10.1f} → {tx*1e3:10.1f} ms  ({tx/bx:5.2f}x)")
        f0 = bc / max(bc, bm, bx)
        f1 = tc / max(tc, tm, tx)
        print(f"  roofline fraction: {f0:.3f} → {f1:.3f}")
    else:
        print(f"  compute={tc*1e3:.1f}ms memory={tm*1e3:.1f}ms "
              f"collective={tx*1e3:.1f}ms")
    print(f"  temp/dev: {res['memory']['temp_size_in_bytes']/1e9:.1f} GB; "
          f"args/dev: {res['memory']['argument_size_in_bytes']/1e9:.1f} GB")


if __name__ == "__main__":
    main()
