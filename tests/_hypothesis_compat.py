"""`hypothesis` when installed, a seeded random-sampling fallback when not.

The container image does not ship `hypothesis` (it is declared in the `dev`
extra of pyproject.toml for environments that can install it).  Property
tests import `given` / `settings` / `st` from this module: with the real
library present they get full shrinking/replay behaviour; without it they
get a deterministic fallback that draws `max_examples` pseudo-random samples
per test (seeded from the test name, so failures reproduce) — strictly more
coverage than skipping the modules, with zero new dependencies.

Only the strategy surface this repo uses is emulated: `st.integers`,
`st.floats`, `st.booleans`, `st.sampled_from`.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # ---- fallback ---------------------------------------
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    st = _Strategies()

    class settings:  # noqa: N801 — mirrors the hypothesis API name
        _profiles: dict = {}
        _active: dict = {"max_examples": 20}

        def __init__(self, **kw):
            self.kw = kw

        def __call__(self, fn):  # used as a decorator: pass through
            fn._hc_settings = self.kw
            return fn

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._active = {**{"max_examples": 20}, **cls._profiles.get(name, {})}

    def given(**strategies):
        def deco(fn):
            # NOTE: deliberately not functools.wraps — the wrapper must
            # present a ZERO-ARG signature to pytest (the drawn parameters
            # would otherwise be collected as fixtures).
            def wrapper():
                eff = {**settings._active, **getattr(fn, "_hc_settings", {})}
                n = max(1, int(eff.get("max_examples") or 20))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for i in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    try:
                        fn(**drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__qualname__} failed on fallback example "
                            f"{i}/{n}: {drawn!r}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
