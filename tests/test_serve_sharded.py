"""Sharded serve: mesh-backed replicas + dry-run cost-model Exec_TID.

Covers the tentpole claims:

* ``collective_stats`` / ``summarize_compiled`` golden values (the dry-run
  quantities the cost model ingests),
* ``CostModelRegistry`` round-trips ``cell_path``-style dry-run artifacts,
  and its exec matrix falls back to the analytic roofline *bitwise* for
  uncovered (arch × mesh) cells,
* per-device FLOPs/bytes are monotone across mesh shapes on a real tiny
  compile (8 fake CPU devices, subprocess — device count locks at backend
  init),
* mesh-backed fleets feed ``simulate_serving``/``HeftFrontEnd`` while
  mapping decisions stay slot-for-slot identical to the ``heft_rt_numpy``
  oracle (property-tested on the f32-exact grid the device backends
  require),
* a ``ServeEngine`` backed by a mesh slice generates bit-identically to the
  single-device engine across heterogeneous slice shapes (subprocess).
"""

import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _subproc import run_sub as _run_sub

from repro.core import heft_rt_numpy
from repro.launch.hlo_analysis import collective_stats
from repro.sched_integration import (
    CostCell,
    CostModelRegistry,
    POLICIES,
    make_requests,
    mesh_fleet,
    scaled_cell,
    service_time_matrix,
    simulate_serving,
)
from repro.sched_integration.serve_scheduler import policy_heft_rt

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# collective_stats golden values
# ---------------------------------------------------------------------------

def test_collective_stats_golden_values():
    """Known HLO snippets → exact wire bytes per the ring conventions."""
    hlo = """
      %ag = f32[128]{0} all-gather(f32[32]{0} %x), replica_groups={}
      %ar = bf16[64,8]{1,0} all-reduce(bf16[64,8]{1,0} %y), to_apply=%add
      %rs = f32[16]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
      %tup = (f32[8]{0}, s32[4]{0}) all-to-all(f32[8]{0} %a, s32[4]{0} %b)
    """
    got = collective_stats(hlo)
    assert got["bytes_by_op"]["all-gather"] == 128 * 4          # result ×1
    assert got["bytes_by_op"]["all-reduce"] == 64 * 8 * 2 * 2   # result ×2
    assert got["bytes_by_op"]["reduce-scatter"] == 16 * 4
    assert got["bytes_by_op"]["all-to-all"] == 8 * 4 + 4 * 4    # tuple sum
    assert got["count_by_op"] == {"all-gather": 1, "all-reduce": 1,
                                  "reduce-scatter": 1, "all-to-all": 1}
    assert got["total_wire_bytes_per_device"] == sum(
        got["bytes_by_op"].values())


def test_collective_stats_empty_hlo():
    got = collective_stats("%m = f32[8]{0} multiply(%a, %b)")
    assert got["total_wire_bytes_per_device"] == 0.0
    assert got["bytes_by_op"] == {} and got["count_by_op"] == {}


# ---------------------------------------------------------------------------
# cost-model registry: dry-run artifact round-trip + roofline fallback
# ---------------------------------------------------------------------------

def _dryrun_dict(arch="deepseek_7b", shape="decode_32k", mesh="16x16",
                 flops=1e9, bytes_=2e9, wire=3e7):
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "num_devices": 256,
        "flops_per_device": flops, "bytes_accessed_per_device": bytes_,
        "collectives": {"bytes_by_op": {"all-gather": wire},
                        "count_by_op": {"all-gather": 4},
                        "total_wire_bytes_per_device": wire},
    }


def test_registry_round_trips_cell_path_artifacts(tmp_path):
    """cell_path-style JSON artifacts load back into equivalent cells."""
    from repro.models.config import SHAPES

    paths = {}
    for shape in ("decode_32k", "prefill_32k"):
        d = _dryrun_dict(shape=shape)
        p = tmp_path / f"deepseek_7b_{shape}_single.json"
        p.write_text(json.dumps(d))
        paths[shape] = p

    reg = CostModelRegistry()
    assert reg.load_dir(str(tmp_path)) == 2
    for shape in ("decode_32k", "prefill_32k"):
        sc = SHAPES[shape]
        cell = reg.cell("deepseek_7b", sc.kind, (16, 16))
        assert cell is not None
        direct = CostCell.from_dryrun(json.loads(paths[shape].read_text()))
        assert cell == direct
        tokens = sc.global_batch * (sc.seq_len if sc.kind == "prefill" else 1)
        assert cell.tokens_per_step == tokens
        assert cell.num_devices == 256
        assert cell.flops_per_token == pytest.approx(1e9 * 256 / tokens)
        assert cell.wire_bytes_per_token == pytest.approx(3e7 * 256 / tokens)


def test_registry_skips_train_and_failed_cells(tmp_path):
    reg = CostModelRegistry()
    assert reg.register_dryrun(_dryrun_dict(shape="train_4k")) is None
    assert reg.register_dryrun({"arch": "x", "shape": "decode_32k",
                                "mesh": "16x16", "error": "boom"}) is None
    assert len(reg) == 0


def test_exec_tid_matrix_uncovered_is_bitwise_roofline():
    fleet = mesh_fleet("deepseek-7b", ((16, 16), (4, 4)))
    reqs = make_requests(rate_rps=200, duration_s=0.5, seed=1)
    reg = CostModelRegistry()     # empty: every column falls back
    got = reg.exec_tid_matrix(reqs, fleet, active_params=7e9)
    want = service_time_matrix(reqs, fleet, active_params=7e9)
    np.testing.assert_array_equal(got, want)


def _serve_cells(arch, mesh_shape, *, pf_flops_tok=2.1 * 7e9,
                 dc_bytes_tok=2.6 * 7e9):
    n = int(np.prod(mesh_shape))
    return [
        CostCell(arch, "prefill", mesh_shape, tokens_per_step=1024,
                 flops_per_device=pf_flops_tok * 1024 / n,
                 bytes_per_device=1e9),
        CostCell(arch, "decode", mesh_shape, tokens_per_step=16,
                 flops_per_device=1e8,
                 bytes_per_device=dc_bytes_tok * 16 / n),
    ]


def test_exec_tid_matrix_covered_column_values():
    """A covered replica's column is the cost-model estimate; the uncovered
    replica's column stays roofline, in the same matrix."""
    fleet = mesh_fleet("deepseek-7b", ((16, 16), (4, 4)))
    reg = CostModelRegistry(_serve_cells("deepseek-7b", (16, 16)))
    assert reg.covers(fleet[0]) and not reg.covers(fleet[1])
    reqs = make_requests(rate_rps=100, duration_s=0.5, seed=2)
    ex = reg.exec_tid_matrix(reqs, fleet, active_params=7e9)
    roof = service_time_matrix(reqs, fleet, active_params=7e9)
    np.testing.assert_array_equal(ex[:, 1], roof[:, 1])
    pf = np.array([r.prefill_tokens for r in reqs], dtype=np.float64)
    dc = np.array([r.decode_tokens for r in reqs], dtype=np.float64)
    want = (pf * 2.1 * 7e9 / (fleet[0].compute_tflops * 1e12)
            + dc * 2.6 * 7e9 / (fleet[0].hbm_gbps * 1e9))
    np.testing.assert_allclose(ex[:, 0], want, rtol=1e-12)
    # measured > analytic here by construction (2.1/2.6 vs 2.0/2.0 factors)
    assert (ex[:, 0] > roof[:, 0]).all()


def test_scaled_cell_monotone_per_device_cost():
    """Projecting a cell onto more devices shrinks per-device cost (and the
    estimate), onto fewer grows it — efficiency ≤ 1 inflates the per-token
    cost when scaling up and deflates it when scaling down (the overhead
    gradient runs with mesh size)."""
    base = _serve_cells("a", (4, 4))[0]
    up = scaled_cell(base, (8, 8), efficiency=0.9)
    down = scaled_cell(base, (2, 2), efficiency=0.9)
    same = scaled_cell(base, (4, 4), efficiency=0.9)
    assert up.flops_per_device < base.flops_per_device < down.flops_per_device
    assert up.flops_per_token == pytest.approx(base.flops_per_token / 0.9)
    assert down.flops_per_token == pytest.approx(base.flops_per_token * 0.9)
    assert same.flops_per_token == pytest.approx(base.flops_per_token)


def test_simulate_serving_registry_equals_explicit_matrix():
    fleet = mesh_fleet("deepseek-7b", ((16, 16), (16, 16), (4, 16), (4, 4)))
    reg = CostModelRegistry(_serve_cells("deepseek-7b", (16, 16)))
    for cell in _serve_cells("deepseek-7b", (16, 16)):
        for shape in ((4, 16), (4, 4)):
            reg.register(scaled_cell(cell, shape, efficiency=0.9))
    reqs = make_requests(rate_rps=300, duration_s=1.0, seed=3)
    ex = reg.exec_tid_matrix(reqs, fleet, active_params=7e9)
    a = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, cost_registry=reg)
    b = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, exec_matrix=ex)
    assert a.mean_latency == b.mean_latency
    assert a.p99_latency == b.p99_latency
    assert a.achieved_rps == b.achieved_rps
    np.testing.assert_array_equal(a.replica_util, b.replica_util)


# ---------------------------------------------------------------------------
# decision fidelity: fleet policy vs the heft_rt_numpy oracle
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_mesh_fleet_decisions_bit_identical_to_oracle(n, seed):
    """For a fixed Exec_TID matrix the serving policy (any backend — the CI
    matrix runs this under REPRO_FABRIC_BACKEND=pallas) assigns exactly like
    ``heft_rt_numpy``.  Draws live on the 1/8-integer grid so every value,
    mean, and finish time is exactly representable in float32 (the device
    backends' documented fidelity domain)."""
    rng = np.random.default_rng(seed)
    P = 4
    ex = rng.integers(1, 32, (n, P)).astype(np.float64) / 8.0
    ex[rng.random(n) < 0.1] = np.inf
    avail = rng.integers(0, 16, P).astype(np.float64) / 8.0
    pol = POLICIES["heft_rt"]()
    np.testing.assert_array_equal(pol(ex, avail), policy_heft_rt(ex, avail))


def test_mesh_fleet_cost_model_decisions_bit_identical_numpy_backend():
    """Continuous (float64) registry-derived matrices: exact agreement on
    the numpy host backend, no f32 grid required."""
    fleet = mesh_fleet("deepseek-7b", ((16, 16), (4, 16), (4, 4)))
    reg = CostModelRegistry(_serve_cells("deepseek-7b", (16, 16)))
    for cell in _serve_cells("deepseek-7b", (16, 16)):
        for shape in ((4, 16), (4, 4)):
            reg.register(scaled_cell(cell, shape, efficiency=0.9))
    reqs = make_requests(rate_rps=200, duration_s=1.0, seed=4)
    ex = reg.exec_tid_matrix(reqs, fleet, active_params=7e9)
    avail = np.zeros(len(fleet))
    from repro.sched_integration import make_policy_fabric

    pol = make_policy_fabric("numpy")
    got = pol(ex, avail)
    avg = ex.mean(axis=1)
    order, assignment, _, _, _ = heft_rt_numpy(avg, ex, avail)
    want = np.empty(len(reqs), dtype=np.int64)
    want[order] = assignment
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# per-device FLOPs/bytes monotone across mesh shapes (real tiny compile)
# ---------------------------------------------------------------------------

def test_dryrun_cost_monotone_across_mesh_shapes():
    """Compile one tiny prefill step on 1×1 vs 2×2 mesh slices of an 8-device
    pool: ``summarize_compiled`` per-device FLOPs must shrink as the slice
    grows, and cost cells built from the two summaries must order the
    replicas' Exec_TID estimates the same way."""
    out = _run_sub("""
        import json
        import jax
        from repro.dist.hints import sharding_policy
        from repro.dist.sharding import MeshAxes, named, replica_pspecs
        from repro.launch.hlo_analysis import summarize_compiled
        from repro.launch.mesh import slice_device_pool
        from repro.models import ModelConfig
        from repro.models.model import init_params, prefill_step

        cfg = ModelConfig(name='t', num_layers=2, d_model=32, num_heads=4,
                          num_kv_heads=4, d_ff=64, vocab_size=64,
                          param_dtype='float32', compute_dtype='float32')
        ax = MeshAxes()
        out = {}
        for mesh in slice_device_pool([(1, 1), (2, 2)]):
            specs = replica_pspecs(cfg, ax)
            p_sh = named(mesh, specs['params'])
            b_sh = named(mesh, specs['batch'])
            c_sh = named(mesh, specs['cache'])
            policy = dict(specs['policy'], __mesh__=mesh)
            step = jax.jit(lambda p, t: prefill_step(p, t, cfg, max_len=16),
                           in_shardings=(p_sh, b_sh),
                           out_shardings=(None, c_sh))
            params = jax.eval_shape(
                lambda: init_params(jax.random.key(0), cfg))
            tokens = jax.ShapeDtypeStruct((1, 16), jax.numpy.int32)
            with jax.set_mesh(mesh), sharding_policy(policy):
                compiled = step.lower(params, tokens).compile()
            s = summarize_compiled(compiled)
            key = 'x'.join(map(str, mesh.devices.shape))
            out[key] = {'flops': s['flops_per_device'],
                        'bytes': s['bytes_accessed_per_device'],
                        'wire': s['collectives']
                                 ['total_wire_bytes_per_device']}
        print(json.dumps(out))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    one, four = res["1x1"], res["2x2"]
    assert one["flops"] > 0 and four["flops"] > 0
    assert four["flops"] < one["flops"]          # TP/FSDP split the work
    assert four["wire"] > one["wire"] == 0.0     # …at the cost of collectives

    # cells built from the two summaries order Exec_TID the same way
    tokens = 16
    reg = CostModelRegistry([
        CostCell("t", "prefill", (1, 1), tokens_per_step=tokens,
                 flops_per_device=one["flops"], bytes_per_device=one["bytes"]),
        CostCell("t", "prefill", (2, 2), tokens_per_step=tokens,
                 flops_per_device=four["flops"], bytes_per_device=four["bytes"]),
        CostCell("t", "decode", (1, 1), tokens_per_step=1,
                 flops_per_device=1.0, bytes_per_device=1.0),
        CostCell("t", "decode", (2, 2), tokens_per_step=1,
                 flops_per_device=1.0, bytes_per_device=1.0),
    ])
    small = reg.cell("t", "prefill", (1, 1))
    big = reg.cell("t", "prefill", (2, 2))
    # per-token global FLOPs may grow with mesh (padding/collective compute),
    # but per-device work — what one slice's chips each do — must shrink
    assert big.flops_per_device < small.flops_per_device


# ---------------------------------------------------------------------------
# mesh-backed ServeEngine (subprocess: real sharded prefill/decode)
# ---------------------------------------------------------------------------

def test_sharded_engine_matches_single_device_and_schedules():
    """Heterogeneous 1×1 / 2×1 / 2×2 slices of one 8-device pool: generation
    is bit-identical to the unsharded engine on every slice, params really
    land sharded, and the HEFT_RT front end spreads requests with the
    largest slice taking the most work."""
    out = _run_sub("""
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.model import init_params
        from repro.serve import HeftFrontEnd, ServeEngine, mesh_backed_fleet

        cfg = get_smoke_config('deepseek-7b')
        params = init_params(jax.random.key(0), cfg)
        fleet = mesh_backed_fleet(cfg, params, [(1, 1), (2, 1), (2, 2)],
                                  max_len=64)
        assert [r.mesh_shape for r in fleet] == [(1, 1), (2, 1), (2, 2)]

        # params of the 2x2 replica actually live on 4 devices
        leaf = jax.tree.leaves(fleet[2].engine.params)[0]
        assert len(leaf.sharding.device_set) == 4, leaf.sharding

        ref = ServeEngine(cfg, params, max_len=64)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        want = ref.generate(prompt[None, :], 8)
        for r in fleet:
            got = r.engine.generate(prompt[None, :], 8)
            assert np.array_equal(got, want), r.name

        front = HeftFrontEnd(fleet)
        reqs = [(rng.integers(0, cfg.vocab_size,
                              rng.integers(8, 32)).astype(np.int32), 6)
                for _ in range(6)]
        outs, counts = front.run_batch(reqs)
        assert len(outs) == 6 and sum(counts.values()) == 6
        per = [counts[r.name] for r in fleet]
        assert per[2] == max(per), counts      # biggest slice works hardest
        print('OK', counts)
    """)
    assert "OK" in out


def test_front_end_uses_registry_columns():
    """HeftFrontEnd.exec_estimates: covered replicas get cost-model columns,
    uncovered keep the host-scale fallback — no engines needed."""
    from repro.serve.engine import HeftFrontEnd, ReplicaHandle

    class _Eng:           # estimate-only stand-in; never executed
        mesh_shape = None

    fast = ReplicaHandle("fast", _Eng(), speed=4.0, arch="t",
                         mesh_shape=(2, 2), compute_tflops=4.0, hbm_gbps=4.0)
    slow = ReplicaHandle("slow", _Eng(), speed=1.0)
    reg = CostModelRegistry([
        CostCell("t", "prefill", (2, 2), tokens_per_step=8,
                 flops_per_device=16e12 / 4, bytes_per_device=0.0),
        CostCell("t", "decode", (2, 2), tokens_per_step=1,
                 flops_per_device=0.0, bytes_per_device=8e9 / 4),
    ])
    front = HeftFrontEnd([fast, slow], cost_registry=reg)
    reqs = [(np.zeros(10, np.int32), 4), (np.zeros(20, np.int32), 2)]
    ex = front.exec_estimates(reqs)
    assert ex.shape == (2, 2)
    # covered column: pf·(16e12/8)/4e12 + dc·(8e9/1)/4e9 = pf/2·1e-3·... exact:
    want_fast = np.array([10 * (16e12 / 8) / 4e12 + 4 * 8e9 / 4e9,
                          20 * (16e12 / 8) / 4e12 + 2 * 8e9 / 4e9])
    np.testing.assert_allclose(ex[:, 0], want_fast, rtol=1e-12)
    # fallback column: the host-scale roofline over speed
    want_slow = np.array([1e-4 * 10 + 2e-3 * 4, 1e-4 * 20 + 2e-3 * 2])
    np.testing.assert_allclose(ex[:, 1], want_slow, rtol=1e-12)

    plan = front.schedule(reqs)
    assert sorted(i for i, _ in plan) == [0, 1]
    assert all(0 <= p < 2 for _, p in plan)


def test_fabric_env_knob(monkeypatch):
    """REPRO_FABRIC_BACKEND drives auto backend resolution + policy factory
    (the CI backend-matrix contract)."""
    from repro.sched_integration.fabric import MappingFabric, default_backend

    monkeypatch.setenv("REPRO_FABRIC_BACKEND", "pallas")
    assert default_backend() == "pallas"
    assert MappingFabric(3, backend="auto").backend == "pallas"
    monkeypatch.setenv("REPRO_FABRIC_BACKEND", "numpy")
    assert MappingFabric(3, backend="auto").backend == "numpy"
    monkeypatch.setenv("REPRO_FABRIC_BACKEND", "bogus")
    with pytest.raises(ValueError):
        default_backend()
    from repro.sched_integration import make_policy_fabric

    with pytest.raises(ValueError):     # factory-time, not first-event-time
        make_policy_fabric()
    monkeypatch.delenv("REPRO_FABRIC_BACKEND")
    assert default_backend() in ("numpy", "jit")
