"""Optimizer, data pipeline, checkpointing, trainer fault-tolerance tests."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, TokenPipeline
from repro.models import ModelConfig
from repro.optim import AdamWConfig, adamw_update, init_opt_state, warmup_cosine
from repro.train import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adam_ref(p, g, m, v, step, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    p = p * (1 - lr * wd) - lr * mh / (np.sqrt(vh) + eps)
    return p, m, v


def test_adamw_matches_reference_f32():
    rng = np.random.default_rng(0)
    p = {"w": jnp.array(rng.normal(0, 1, (8, 16)).astype(np.float32))}
    cfg = AdamWConfig(learning_rate=1e-2, weight_decay=0.1,
                      grad_clip_norm=None)
    state = init_opt_state(p, cfg)
    pn, mn, vn = np.asarray(p["w"]), np.zeros((8, 16)), np.zeros((8, 16))
    for step in range(1, 4):
        g = {"w": jnp.array(rng.normal(0, 1, (8, 16)).astype(np.float32))}
        p, state, _ = adamw_update(g, state, p, cfg)
        pn, mn, vn = _adam_ref(pn, np.asarray(g["w"]), mn, vn, step,
                               1e-2, 0.9, 0.95, 1e-8, 0.1)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("mdt", ["float32", "bfloat16", "int8"])
def test_adamw_moment_dtypes_converge_similarly(mdt):
    """A quadratic toy: all moment precisions reach a much lower loss."""
    target = jnp.array(np.random.default_rng(1).normal(0, 1, (16, 64)),
                       dtype=jnp.float32)
    p = {"w": jnp.zeros((16, 64))}
    cfg = AdamWConfig(learning_rate=5e-2, moment_dtype=mdt,
                      grad_clip_norm=None)
    state = init_opt_state(p, cfg)

    def loss(w):
        return jnp.mean((w - target) ** 2)

    l0 = float(loss(p["w"]))
    for _ in range(60):
        g = {"w": jax.grad(loss)(p["w"])}
        p, state, _ = adamw_update(g, state, p, cfg)
    assert float(loss(p["w"])) < 0.05 * l0, mdt


def test_grad_clipping():
    p = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(learning_rate=1.0, grad_clip_norm=1.0)
    state = init_opt_state(p, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(g, state, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_warmup_cosine_schedule():
    f = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(f(jnp.int32(5))) == pytest.approx(5e-4)
    assert float(f(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=3)
    a = TokenPipeline(cfg)
    b = TokenPipeline(cfg)
    for step in [0, 7, 1000]:
        np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                      b.batch_at(step)["tokens"])
    assert not np.array_equal(a.batch_at(1)["tokens"], a.batch_at(2)["tokens"])


def test_pipeline_shards_partition_global_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8, seed=0)
    p = TokenPipeline(cfg)
    full = p.batch_at(5)["tokens"]
    parts = [p.shard_at(5, s, 4)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_labels_are_learnable():
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=4, seed=0)
    b = TokenPipeline(cfg).batch_at(0)
    # the Markov twist: far more next-token structure than chance (1/64)
    frac = np.mean(b["labels"] == (b["tokens"] + 1) % 64)
    assert frac > 0.2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.ones(4), {"c": jnp.zeros(())}]}
    for step in [1, 2, 3]:
        ck.save(step, tree, blocking=True)
    assert ck.available_steps() == [2, 3]
    out = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert ck.read_metadata()["step"] == 3


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((128, 128))}
    ck.save(10, tree)          # async
    ck.wait()
    assert ck.latest_step() == 10


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"w": jnp.ones(8)}, blocking=True)
    names = os.listdir(tmp_path)
    assert all(n.startswith("step_") for n in names)


def test_int8_opt_state_checkpoint_roundtrip(tmp_path):
    p = {"w": jnp.array(np.random.default_rng(0).normal(0, 1, (8, 256)),
                        dtype=jnp.float32)}
    cfg = AdamWConfig(moment_dtype="int8")
    state = init_opt_state(p, cfg)
    g = {"w": jnp.ones((8, 256)) * 0.1}
    _, state, _ = adamw_update(g, state, p, cfg)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state, blocking=True)
    out = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(out["m"]["w"]["q"]),
                                  np.asarray(state["m"]["w"]["q"]))


# ---------------------------------------------------------------------------
# trainer fault tolerance: exact restart
# ---------------------------------------------------------------------------

def _trainer(tmp, total=10):
    cfg = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    return Trainer(cfg, AdamWConfig(learning_rate=3e-3),
                   DataConfig(vocab_size=64, seq_len=32, global_batch=4),
                   TrainerConfig(total_steps=total, checkpoint_every=4,
                                 checkpoint_dir=tmp, log_every=5))


def test_trainer_restart_is_bitwise_exact(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    with pytest.raises(RuntimeError, match="injected failure"):
        _trainer(d1).run(inject_failure_at=6)
    p_resumed, _, _ = _trainer(d1).run()
    p_clean, _, hist = _trainer(d2).run()
    for a, b in zip(jax.tree.leaves(p_resumed), jax.tree.leaves(p_clean)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # loss actually decreases over training
    assert hist[-1][1] < 4.2


def test_trainer_loss_decreases(tmp_path):
    _, _, hist = _trainer(str(tmp_path), total=30).run()
    assert hist[-1][1] < hist[0][1]
