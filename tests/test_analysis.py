"""repro.analysis — the jax-aware lint pass (satellite of the ISSUE 9 tentpole).

Contract under test, per rule in the catalogue (docs/analysis.md):

* every rule **fires** on its ``tests/analysis_fixtures/*_bad*`` fixture
  with the exact expected count, and is **silent** on the ``*_good*`` twin;
* ``# repro: noqa[rule]`` suppresses exactly the annotated line;
* the checked-in baseline round-trips (write → load → apply) and absorbs
  by (rule, path, message) *count*, not blanket key;
* the full-repo run is clean modulo ``tools/analysis_baseline.json`` —
  the same invariant the CI ``lint-analysis`` step gates on;
* the CLI exits 1 on fresh findings, 0 when clean.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    AnalysisContext,
    Finding,
    all_rules,
    apply_baseline,
    default_context,
    load_baseline,
    run_analysis,
    write_baseline,
)

HERE = Path(__file__).resolve().parent
FIXTURES = HERE / "analysis_fixtures"
REPO_ROOT = HERE.parent


def _file_ctx(*names):
    """File-scope context over flat fixtures (repo anchors all None)."""
    return AnalysisContext(root=FIXTURES,
                           files=tuple(FIXTURES / n for n in names))


def _run(ctx, rule_name):
    return run_analysis(ctx, rule_names=[rule_name])


# ---------------------------------------------------------------------------
# File-scope rules: fires on bad (exact count), silent on good.
# ---------------------------------------------------------------------------

FILE_RULE_CASES = [
    # (rule, bad fixture, expected findings, good fixture)
    ("donation-after-use", "donation_after_use_bad.py", 2,
     "donation_after_use_good.py"),
    ("host-sync-in-hot-path", "host_sync_bad.py", 3, "host_sync_good.py"),
    ("sharding-axis", "sharding_axis_bad.py", 3, "sharding_axis_good.py"),
    ("retrace-hazard", "retrace_hazard_bad.py", 4, "retrace_hazard_good.py"),
]


@pytest.mark.parametrize("rule_name,bad,count,good", FILE_RULE_CASES,
                         ids=[c[0] for c in FILE_RULE_CASES])
def test_file_rule_fires_on_bad(rule_name, bad, count, good):
    res = _run(_file_ctx(bad), rule_name)
    assert len(res.findings) == count, [f.render() for f in res.findings]
    assert all(f.rule == rule_name for f in res.findings)
    assert not res.suppressed


@pytest.mark.parametrize("rule_name,bad,count,good", FILE_RULE_CASES,
                         ids=[c[0] for c in FILE_RULE_CASES])
def test_file_rule_silent_on_good(rule_name, bad, count, good):
    res = _run(_file_ctx(good), rule_name)
    assert res.findings == [], [f.render() for f in res.findings]


def test_donation_messages_name_the_buffer():
    res = _run(_file_ctx("donation_after_use_bad.py"), "donation-after-use")
    msgs = " | ".join(f.message for f in res.findings)
    assert "y" in msgs and "donat" in msgs


def test_host_sync_reports_each_pattern_once():
    res = _run(_file_ctx("host_sync_bad.py"), "host-sync-in-hot-path")
    msgs = [f.message for f in res.findings]
    assert any(".item()" in m for m in msgs)
    assert any("float(" in m for m in msgs)
    assert any("asarray" in m for m in msgs)


def test_sharding_axis_names_offending_axis():
    res = _run(_file_ctx("sharding_axis_bad.py"), "sharding-axis")
    named = {m for f in res.findings for m in ("tp", "dp", "expert")
             if m in f.message}
    assert named == {"tp", "dp", "expert"}


def test_sharding_axis_exempts_dist_paths(tmp_path):
    sub = tmp_path / "dist"
    sub.mkdir()
    bad = sub / "meshes.py"
    bad.write_text((FIXTURES / "sharding_axis_bad.py").read_text())
    ctx = AnalysisContext(root=tmp_path, files=(bad,))
    assert _run(ctx, "sharding-axis").findings == []


# ---------------------------------------------------------------------------
# Repo-scope rules, driven by fixture mini-trees via AnalysisContext anchors.
# ---------------------------------------------------------------------------

def _hint_ctx(which):
    tree = FIXTURES / f"hint_drift_{which}"
    return AnalysisContext(root=tree, files=(),
                           hints_path=tree / "hints.py",
                           models_dir=tree / "models")


def test_hint_drift_fires_on_bad():
    res = _run(_hint_ctx("bad"), "hint-drift")
    msgs = " | ".join(f.message for f in res.findings)
    assert len(res.findings) == 3, [f.render() for f in res.findings]
    assert "rogue_site" in msgs          # used but not inventoried
    assert "ghost_site" in msgs          # inventoried but never used
    assert "literal" in msgs             # non-literal site name


def test_hint_drift_silent_on_good():
    assert _run(_hint_ctx("good"), "hint-drift").findings == []


def _event_ctx(which):
    return AnalysisContext(root=FIXTURES, files=(),
                           fleet_path=FIXTURES / f"event_schema_{which}.py")


def test_event_schema_drift_fires_on_bad():
    res = _run(_event_ctx("bad"), "event-schema-drift")
    msgs = " | ".join(f.message for f in res.findings)
    assert len(res.findings) == 4, [f.render() for f in res.findings]
    assert "severity" in msgs            # field missing from validator
    assert "factor" in msgs              # schema key / required non-field
    assert "reason" in msgs              # ResizeEvent lost the envelope


def test_event_schema_drift_silent_on_good():
    assert _run(_event_ctx("good"), "event-schema-drift").findings == []


def _knob_ctx(which):
    tree = FIXTURES / f"knob_doc_{which}"
    return AnalysisContext(root=tree, files=(),
                           launch_dir=tree / "launch",
                           knobs_md=tree / "knobs.md")


def test_knob_doc_drift_fires_on_bad():
    res = _run(_knob_ctx("bad"), "knob-doc-drift")
    assert len(res.findings) == 1, [f.render() for f in res.findings]
    assert "--secret-knob" in res.findings[0].message


def test_knob_doc_drift_silent_on_good():
    assert _run(_knob_ctx("good"), "knob-doc-drift").findings == []


def test_repo_rules_skip_when_anchor_missing():
    """None anchors → repo rules self-skip instead of crashing."""
    ctx = AnalysisContext(root=FIXTURES, files=())
    for name in ("hint-drift", "event-schema-drift", "knob-doc-drift"):
        assert _run(ctx, name).findings == [], name


# ---------------------------------------------------------------------------
# Suppression + baseline machinery.
# ---------------------------------------------------------------------------

def test_noqa_suppresses_only_annotated_line(tmp_path):
    src = tmp_path / "hot.py"
    src.write_text(
        "import jax.numpy as jnp\n"
        "import numpy as np\n"
        "def decode_tick(x, y):\n"
        "    a = np.asarray(jnp.argmax(x))  # repro: noqa[host-sync-in-hot-path]\n"
        "    b = np.asarray(jnp.argmax(y))\n"
        "    return a, b\n")
    res = _run(AnalysisContext(root=tmp_path, files=(src,)),
               "host-sync-in-hot-path")
    assert len(res.suppressed) == 1 and res.suppressed[0].line == 4
    assert len(res.findings) == 1 and res.findings[0].line == 5


def test_noqa_is_rule_specific(tmp_path):
    src = tmp_path / "hot.py"
    src.write_text(
        "import jax.numpy as jnp\n"
        "import numpy as np\n"
        "def decode_tick(x):\n"
        "    return np.asarray(jnp.argmax(x))  # repro: noqa[retrace-hazard]\n")
    res = _run(AnalysisContext(root=tmp_path, files=(src,)),
               "host-sync-in-hot-path")
    assert len(res.findings) == 1 and not res.suppressed


def test_baseline_round_trip(tmp_path):
    res = _run(_file_ctx("sharding_axis_bad.py"), "sharding-axis")
    path = tmp_path / "baseline.json"
    write_baseline(path, res.findings)
    baseline = load_baseline(path)

    fresh, absorbed = apply_baseline(res.findings, baseline)
    assert fresh == [] and absorbed == len(res.findings)

    # A NEW instance of an already-baselined key is still fresh: absorption
    # is count-matched, not a blanket per-key waiver.
    extra = res.findings[0]
    dup = Finding(path=extra.path, line=extra.line + 40, col=extra.col,
                  rule=extra.rule, message=extra.message)
    fresh, absorbed = apply_baseline(res.findings + [dup], baseline)
    assert len(fresh) == 1 and fresh[0].line == dup.line


def test_baseline_is_line_drift_tolerant(tmp_path):
    res = _run(_file_ctx("retrace_hazard_bad.py"), "retrace-hazard")
    path = tmp_path / "baseline.json"
    write_baseline(path, res.findings)
    shifted = [Finding(path=f.path, line=f.line + 7, col=f.col,
                       rule=f.rule, message=f.message) for f in res.findings]
    fresh, absorbed = apply_baseline(shifted, load_baseline(path))
    assert fresh == [] and absorbed == len(shifted)


def test_unknown_rule_name_raises():
    with pytest.raises(ValueError):
        run_analysis(_file_ctx("host_sync_good.py"), rule_names=["no-such"])


def test_registry_has_the_full_catalogue():
    names = set(all_rules())
    assert {"donation-after-use", "host-sync-in-hot-path", "sharding-axis",
            "retrace-hazard", "hint-drift", "event-schema-drift",
            "knob-doc-drift"} <= names


# ---------------------------------------------------------------------------
# Meta-test + CLI: the exact invariant CI's lint-analysis step gates on.
# ---------------------------------------------------------------------------

def test_full_repo_clean_modulo_baseline():
    ctx = default_context(REPO_ROOT)
    assert len(ctx.files) > 50          # really scanning src/, not a stub dir
    res = run_analysis(ctx)
    baseline = load_baseline(REPO_ROOT / "tools" / "analysis_baseline.json")
    fresh, _ = apply_baseline(res.findings, baseline)
    assert fresh == [], "\n".join(f.render() for f in fresh)


def _cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT)


def test_cli_exit_codes_and_json(tmp_path):
    out = tmp_path / "findings.json"
    bad = _cli(str(FIXTURES / "host_sync_bad.py"), "--root", str(FIXTURES),
               "--json", str(out))
    assert bad.returncode == 1, bad.stdout + bad.stderr
    payload = json.loads(out.read_text())
    assert len(payload["findings"]) == 3
    assert all(f["rule"] == "host-sync-in-hot-path"
               for f in payload["findings"])

    good = _cli(str(FIXTURES / "host_sync_good.py"), "--root", str(FIXTURES))
    assert good.returncode == 0, good.stdout + good.stderr


def test_cli_update_baseline_then_clean(tmp_path):
    base = tmp_path / "baseline.json"
    first = _cli(str(FIXTURES / "retrace_hazard_bad.py"), "--root",
                 str(FIXTURES), "--baseline", str(base), "--update-baseline")
    assert first.returncode == 0, first.stdout + first.stderr
    assert len(json.loads(base.read_text())["findings"]) > 0

    second = _cli(str(FIXTURES / "retrace_hazard_bad.py"), "--root",
                  str(FIXTURES), "--baseline", str(base))
    assert second.returncode == 0, second.stdout + second.stderr


def test_cli_rejects_unknown_rule():
    res = _cli(str(FIXTURES / "host_sync_good.py"), "--root", str(FIXTURES),
               "--rules", "no-such-rule")
    assert res.returncode == 2
