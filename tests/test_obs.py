"""repro.obs: tracer ring, histogram bucket edges, Chrome export schema,
device-counter inertness.

The load-bearing claim is the last one: the instrumented ``MappingFabric``
(tracer + metrics + device-resident counters all enabled) stays
slot-for-slot bit-identical to the ``heft_rt_numpy`` oracle — the paper's
hardware counters don't perturb the schedule, and neither do ours.
"""

import json
import math

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import heft_rt_numpy
from repro.obs import (
    COUNTER_NAMES,
    HIST_BUCKETS,
    HIST_MIN_S,
    Histogram,
    LOG_LEVELS,
    MetricsRegistry,
    NULL_TRACER,
    Stopwatch,
    TraceEvent,
    Tracer,
    accumulate_counters_np,
    counters_dict,
    get_logger,
    time_s,
    validate_chrome_trace,
)
from repro.obs.trace import NULL_SPAN
from repro.sched_integration import MappingFabric

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# Histogram bucket edges (property: edge[i] <= v < edge[i+1], ns → s)
# ---------------------------------------------------------------------------

@given(v=st.floats(1e-10, 2000.0))
def test_histogram_bucket_edge_invariant(v):
    edges = Histogram.bucket_edges()
    i = Histogram.bucket_index(v)
    assert 0 <= i < HIST_BUCKETS
    if v <= HIST_MIN_S:
        assert i == 0                          # clamp below the 1 ns floor
    elif v >= edges[-1]:
        assert i == HIST_BUCKETS - 1           # clamp above the top edge
    else:
        assert edges[i] <= v < edges[i + 1]


def test_histogram_exact_power_of_two_edges():
    edges = Histogram.bucket_edges()
    assert len(edges) == HIST_BUCKETS + 1
    assert edges[0] == HIST_MIN_S
    assert edges[-1] > 1000.0                  # the axis really spans ns → s
    for i in range(HIST_BUCKETS):
        # an exact edge value belongs to the bucket it opens
        assert Histogram.bucket_index(edges[i]) == min(i, HIST_BUCKETS - 1)
        # just below the edge belongs to the previous bucket
        below = edges[i] * (1 - 1e-12)
        assert Histogram.bucket_index(below) == max(i - 1, 0)


def test_histogram_record_and_percentiles():
    h = Histogram()
    for v in (1e-9, 9.144e-9, 1e-6, 1e-3, 1.0):
        h.record(v)
    assert h.count == 5
    assert h.min == 1e-9 and h.max == 1.0
    assert math.isclose(h.sum, 1e-9 + 9.144e-9 + 1e-6 + 1e-3 + 1.0)
    p50 = h.percentile(50)
    edges = Histogram.bucket_edges()
    i = Histogram.bucket_index(1e-6)
    assert edges[i] <= p50 <= edges[i + 1]     # median bounded by its bucket
    assert h.percentile(99) <= h.max
    snap = h.snapshot()
    assert snap["count"] == 5 and sum(snap["buckets"].values()) == 5


def test_histogram_weighted_record():
    h = Histogram()
    h.record(2e-6, n=64)                       # one batched event, 64 decisions
    assert h.count == 64
    assert math.isclose(h.sum, 2e-6 * 64)
    assert h.buckets[Histogram.bucket_index(2e-6)] == 64


# ---------------------------------------------------------------------------
# Counters / gauges / registry
# ---------------------------------------------------------------------------

def test_registry_labels_and_types():
    m = MetricsRegistry()
    m.counter("x", backend="jit").inc()
    m.counter("x", backend="jit").inc(2)
    m.counter("x", backend="numpy").inc()
    assert m.counter("x", backend="jit").value == 3
    assert m.counter("x", backend="numpy").value == 1
    assert "x{backend=jit}" in m and len(m) == 2
    m.gauge("g").set(4.5)
    try:
        m.histogram("g")
    except TypeError:
        pass
    else:
        raise AssertionError("type mismatch must raise")
    snap = m.snapshot()
    assert snap["x{backend=jit}"] == 3 and snap["g"] == 4.5


def test_timing_helpers():
    _, dt = time_s(sum, range(10))
    assert dt >= 0.0
    h = Histogram()
    with Stopwatch(h, n=4) as sw:
        sum(range(100))
    assert sw.elapsed_s >= 0.0 and sw.start_s > 0.0
    assert h.count == 4                        # weighted by n


def test_log_levels():
    assert LOG_LEVELS["silent"] > LOG_LEVELS["error"]
    log = get_logger("obs-test")
    log.info("hello")                          # must not raise
    import pytest

    with pytest.MonkeyPatch.context() as mp:
        mp.setenv("REPRO_LOG", "bogus")
        with pytest.raises(ValueError):
            get_logger("obs-test2")
        mp.setenv("REPRO_LOG", "silent")
        assert not get_logger("obs-test3").isEnabledFor(LOG_LEVELS["error"])


# ---------------------------------------------------------------------------
# Tracer: ring wraparound, disabled no-op, Chrome export schema
# ---------------------------------------------------------------------------

def test_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}", ts_us=float(i))
    assert len(tr) == 8
    assert tr.dropped == 12
    names = [e.name for e in tr.events()]
    assert names == [f"e{i}" for i in range(12, 20)]   # oldest-first, newest 8


def test_disabled_tracer_is_noop():
    tr = Tracer(capacity=4, enabled=False)
    s1 = tr.span("a", k=1)
    s2 = tr.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN         # singleton, no alloc
    with s1:
        pass
    tr.instant("x")
    tr.counter("c", v=1)
    tr.complete("y", 0.0, 1.0)
    tr.record(TraceEvent("z", "i", 0.0))
    assert len(tr) == 0 and tr.dropped == 0
    assert len(NULL_TRACER) == 0


def test_chrome_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("outer", tag="t"):
        tr.instant("mark")
    tr.counter("depth", ts_us=5.0, depth=3)
    tr.complete("hot", 0.0, 1e-3, n=2)
    m = MetricsRegistry()
    m.histogram("lat_s").record(1e-6, n=10)
    path = str(tmp_path / "trace.json")
    tr.export(path, metrics=m)
    with open(path) as f:
        obj = json.load(f)
    n = validate_chrome_trace(obj, require_names=["outer", "mark", "depth"])
    assert n == 4
    ts = [ev["ts"] for ev in obj["traceEvents"]]
    assert ts == sorted(ts)                            # export is time-ordered
    assert obj["metrics"]["lat_s"]["count"] == 10
    assert obj["otherData"]["dropped"] == 0
    # spans carry dur; counters carry their values
    phs = {ev["name"]: ev for ev in obj["traceEvents"]}
    assert phs["outer"]["ph"] == "X" and phs["outer"]["dur"] >= 0
    assert phs["depth"]["ph"] == "C" and phs["depth"]["args"]["depth"] == 3


def test_validate_rejects_malformed():
    import pytest

    for bad in (
        {"traceEvents": "nope"},
        {"traceEvents": [{"ph": "X", "ts": 0.0}]},            # no name
        {"traceEvents": [{"name": "a", "ph": "?", "ts": 0.0}]},
        {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0}]},  # X sans dur
        {"traceEvents": [{"name": "a", "ph": "i", "ts": "x"}]},
    ):
        with pytest.raises(ValueError):
            validate_chrome_trace(bad)


# ---------------------------------------------------------------------------
# Device counters: provably inert + correct values
# ---------------------------------------------------------------------------

def _random_event(rng, n, p):
    avg = rng.integers(0, 6, n).astype(np.float32)
    ex = rng.integers(1, 16, (n, p)).astype(np.float32)
    ex[rng.random(n) < 0.2] = np.inf
    avail = rng.integers(0, 8, p).astype(np.float32)
    return avg, ex, avail


@given(
    backend=st.sampled_from(["numpy", "jit", "pallas"]),
    n=st.integers(1, 24),
    p=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_instrumented_fabric_bit_identical_to_oracle(backend, n, p, seed):
    """Tracer + metrics + device counters enabled: schedule unchanged."""
    rng = np.random.default_rng(seed)
    avg, ex, avail = _random_event(rng, n, p)
    fab = MappingFabric(p, backend=backend, tracer=Tracer(),
                        metrics=MetricsRegistry(), device_counters=True)
    got = fab.map_event(avg, ex, avail, update=False)
    want = heft_rt_numpy(avg, ex, avail)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_device_counters_match_host_twin_across_backends():
    rng = np.random.default_rng(3)
    events = [_random_event(rng, n, 4) for n in (3, 8, 11, 20)]
    ref = np.zeros(len(COUNTER_NAMES))
    for avg, ex, avail in events:
        _, a, _, _, na = heft_rt_numpy(avg, ex, avail)
        accumulate_counters_np(ref, a, na)
    want = counters_dict(ref)
    for backend in ("numpy", "jit", "pallas"):
        fab = MappingFabric(4, backend=backend, device_counters=True)
        for avg, ex, avail in events:
            fab.map_event(avg, ex, avail, update=False)
        got = fab.drain_counters()
        assert got == want, (backend, got, want)
        # drain(reset=True) zeroed the registers
        assert all(v == 0.0 for v in fab.drain_counters().values())


def test_fabric_dispatch_observability():
    tr, m = Tracer(), MetricsRegistry()
    fab = MappingFabric(4, backend="jit", tracer=tr, metrics=m,
                        device_counters=True)
    rng = np.random.default_rng(0)
    for n in (5, 5, 30):                       # 5→bucket 8 (x2), 30→bucket 32
        avg, ex, avail = _random_event(rng, n, 4)
        fab.map_event(avg, ex, avail, update=False)
    assert fab.retraces == 2                   # one per new bucketed shape
    assert m.counter("fabric.retraces").value == 2
    names = [e.name for e in tr.events()]
    assert names.count("fabric.retrace") == 2
    assert names.count("fabric.map_event") == 3
    hist = m.histogram("fabric.decision_s", backend="jit")
    assert hist.count == 5 + 5 + 30            # weighted per decision
    fab.grow(6)
    assert m.counter("fabric.resizes").value == 1
    assert m.gauge("fabric.num_pes").value == 6
    assert "fabric.resize" in {e.name for e in tr.events()}


def test_drain_requires_device_counters():
    import pytest

    fab = MappingFabric(2, backend="numpy")
    with pytest.raises(ValueError):
        fab.drain_counters()


# ---------------------------------------------------------------------------
# Serving / fleet integration stays bit-identical under instrumentation
# ---------------------------------------------------------------------------

def test_simulate_serving_identical_with_obs():
    from repro.sched_integration import default_fleet, make_requests
    from repro.sched_integration.serve_scheduler import (
        POLICIES,
        simulate_serving,
    )

    reqs = make_requests(30.0, 2.0, seed=5)
    base = simulate_serving(default_fleet(), reqs, POLICIES["heft_rt"](),
                            active_params=7e9)
    tr, m = Tracer(), MetricsRegistry()
    inst = simulate_serving(default_fleet(), reqs, POLICIES["heft_rt"](),
                            active_params=7e9, tracer=tr, metrics=m)
    assert base.achieved_rps == inst.achieved_rps
    assert base.p99_latency == inst.p99_latency
    np.testing.assert_array_equal(base.served_mask, inst.served_mask)
    np.testing.assert_array_equal(base.replica_util, inst.replica_util)
    depth = [e for e in tr.events() if e.name == "serve.queue_depth"]
    assert depth and all(e.ph == "C" for e in depth)
    ts = [e.ts for e in depth]
    assert ts == sorted(ts)                    # simulated-time ordering
    snap = m.snapshot()
    assert snap["serve.served"] == int(base.served_mask.sum())
    assert snap["serve.served"] + snap["serve.unserved"] == len(reqs)
    assert any(k.startswith("serve.replica_util{") for k in snap)


def test_fleet_controller_compat_trace_view():
    from repro.sched_integration.fleet import (
        FleetController,
        FleetControllerConfig,
        grown_replica_factory,
    )

    tr = Tracer()
    ctl = FleetController(FleetControllerConfig(grow_backlog_s=1.0,
                                                cooldown_s=0.0),
                          grown_replica_factory("a", (2, 2)), tracer=tr)
    ev = ctl.observe(1.0, queue_depth=9, backlog_s=5.0)
    assert ev is not None and ev.add
    ev2 = ctl.observe(2.0, queue_depth=0, backlog_s=0.0)
    assert ev2 is not None and ev2.remove
    # legacy tuple view preserved, derived from structured events
    assert [(t, k) for t, k, _ in ctl.trace] == [(1.0, "grow"), (2.0, "shrink")]
    assert all(isinstance(e, TraceEvent) for e in ctl.events)
    assert [e.name for e in ctl.events] == ["fleet.grow", "fleet.shrink"]
    assert ctl.events[0].ts == 1.0 * 1e6       # simulated-time stamp in µs
    # mirrored into the shared tracer
    assert [e.name for e in tr.events()] == ["fleet.grow", "fleet.shrink"]
