"""Energy-aware HEFT_RT (the paper's future-work extension)."""

import numpy as np
import pytest

from repro.core import heft_rt_numpy
from repro.core.heft_energy import energy_pareto, heft_rt_energy_numpy


def _soc(seed=0, n=40, p=4):
    rng = np.random.default_rng(seed)
    avg = rng.uniform(1, 10, n)
    ex = rng.uniform(1, 10, (n, p))
    power = np.array([1.0, 1.0, 1.0, 0.3])[:p]  # accelerator is efficient
    return avg, ex, power


def test_lambda_zero_recovers_heft_rt():
    avg, ex, power = _soc()
    o0, a0, s0, f0, av0 = heft_rt_numpy(avg, ex, np.zeros(4))
    o1, a1, s1, f1, av1, _ = heft_rt_energy_numpy(avg, ex, np.zeros(4),
                                                  power, lam=0.0)
    np.testing.assert_array_equal(o0, o1)
    np.testing.assert_array_equal(a0, a1)
    np.testing.assert_allclose(av0, av1)


def test_energy_decreases_along_lambda():
    avg, ex, power = _soc()
    pts = energy_pareto(avg, ex, power)
    energies = [e for _, _, e in pts]
    # energy is (weakly) monotone decreasing along the λ sweep
    assert energies[-1] <= energies[0]
    assert min(energies) < 0.95 * energies[0]  # a real trade-off exists


def test_makespan_energy_tradeoff_is_pareto_like():
    avg, ex, power = _soc(seed=3)
    pts = energy_pareto(avg, ex, power)
    lam0_makespan = pts[0][1]
    lamN_makespan = pts[-1][1]
    # pushing energy down costs makespan (or holds it, never improves it
    # beyond noise): λ=0 is makespan-optimal among the sweep
    assert lam0_makespan <= min(m for _, m, _ in pts) + 1e-9
    assert lamN_makespan >= lam0_makespan
