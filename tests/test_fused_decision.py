"""Fused-backend fabric + in-tick HEFT_RT decision: oracle bit-identity.

Covers this PR's tentpole contracts (docs/scheduling.md):

* the ``fused`` ``MappingFabric`` backend is decision-for-decision
  bit-identical to ``heft_rt_numpy`` — including all-``+inf`` exec rows
  (assignment ``-1``), duplicate priority keys (stable-sort ties), a PE
  mask, and chained resident registers,
* random interleavings of {``map_event``, ``set_pe_mask``, ``grow``,
  ``shrink``, ``drain_counters``} track a host-side numpy mirror exactly
  (registers, decisions, counters),
* padded PE lanes are inert: no assignment ever lands on a lane ≥ num_pes
  and resident registers are untouched by padding,
* ``decision_hw`` (the Pallas overlay lowering, interpret mode off-TPU)
  equals ``decision_ref`` equals the oracle,
* ``pack_tick_outputs``/``unpack_decision`` round-trip bit-exactly (the
  fused tick's single host transfer), ±inf included,
* ``PagedRuntime.decode_tick(sched=...)`` returns decode tokens
  byte-identical to the plain tick plus a decision equal to the oracle
  chain, with device counters accumulated in-program,
* ``HeftFrontEnd.run_continuous(fused=...)`` reproduces the dense oracle
  token-for-token and the host-path run decision-for-decision,
* ``backend_effective`` reports the lowering that actually ran.
"""

import numpy as np

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core import heft_rt_numpy
from repro.kernels import decision_hw
from repro.kernels.fused_decision import (decision_ref, pack_tick_outputs,
                                          unpack_decision)
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.sched_integration.fabric import BACKENDS, MappingFabric
from repro.serve import HeftFrontEnd, ReplicaHandle, ServeEngine

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

CFG = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=4, d_ff=64, vocab_size=64,
                  param_dtype="float32", compute_dtype="float32")

# Module-level lazy singletons instead of fixtures: the hypothesis fallback
# shim wraps @given tests with a zero-arg signature (see tests/_hypothesis_
# compat.py), so fixtures can't be injected into property tests.
_CACHE: dict = {}


def _params():
    if "params" not in _CACHE:
        _CACHE["params"] = init_params(jax.random.key(0), CFG)
    return _CACHE["params"]


def _oracle_engine():
    if "oracle" not in _CACHE:
        _CACHE["oracle"] = ServeEngine(CFG, _params(), max_len=32)
    return _CACHE["oracle"]


def _event(rng, n, p, inf_frac=0.15):
    """Small-integer event: every finish time exact in f32 (the paper's
    Fig. 3 bitwise requirement), with occasional all-inf rows."""
    avg = rng.integers(0, 4, n).astype(np.float64)     # duplicate keys
    ex = rng.integers(1, 16, (n, p)).astype(np.float64)
    kill = rng.random(n) < inf_frac
    ex[kill] = np.inf
    return avg, ex


# ---------------------------------------------------------------------------
# fused backend standalone dispatch: oracle bit-identity
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), p=st.integers(1, 9))
def test_fused_map_event_bit_identical_to_oracle(seed, p):
    rng = np.random.default_rng(seed)
    fab = MappingFabric(p, backend="fused")
    mirror = np.zeros(p)
    for _ in range(4):
        n = int(rng.integers(1, 20))
        avg, ex = _event(rng, n, p)
        got = fab.map_event(avg, ex)
        want = heft_rt_numpy(avg, ex, mirror)
        mirror = want[4]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g, dtype=np.float64),
                                          np.asarray(w, dtype=np.float64))


@given(seed=st.integers(0, 10_000))
def test_fused_random_op_interleaving_tracks_host_mirror(seed):
    """{map_event, set_pe_mask, grow, shrink, drain_counters} interleavings:
    the fused fabric's registers/decisions/counters equal a host-side numpy
    fabric's at every step."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 6))
    fab = MappingFabric(p, backend="fused", device_counters=True)
    ref = MappingFabric(p, backend="numpy", device_counters=True)
    for _ in range(12):
        op = rng.integers(0, 5)
        if op == 0:
            avg, ex = _event(rng, int(rng.integers(1, 12)), fab.num_pes)
            got, want = fab.map_event(avg, ex), ref.map_event(avg, ex)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(
                    np.asarray(g, dtype=np.float64),
                    np.asarray(w, dtype=np.float64))
        elif op == 1 and fab.num_pes > 1:
            mask = rng.random(fab.num_pes) < 0.4
            mask = mask if mask.any() and not mask.all() else None
            fab.set_pe_mask(mask)
            ref.set_pe_mask(mask)
        elif op == 2:
            fab.grow(fab.num_pes + 1, avail=float(rng.integers(0, 5)))
            ref.grow(ref.num_pes + 1, avail=fab.avail[-1])
        elif op == 3 and fab.num_pes > 1:
            keep = np.sort(rng.choice(fab.num_pes,
                                      size=fab.num_pes - 1, replace=False))
            fab.shrink(keep)
            ref.shrink(keep)
        else:
            assert fab.drain_counters() == ref.drain_counters()
        np.testing.assert_array_equal(fab.avail, ref.avail)
    assert fab.drain_counters() == ref.drain_counters()


def test_fused_padded_lane_inertness():
    """num_pes=5 pads to an 8-lane bucket: assignments never land on lanes
    ≥ 5, and padded-lane registers never leak into results."""
    rng = np.random.default_rng(3)
    fab = MappingFabric(5, backend="fused")
    for _ in range(6):
        avg, ex = _event(rng, 11, 5, inf_frac=0.3)
        _, assignment, _, _, new_avail = fab.map_event(avg, ex)
        assert new_avail.shape == (5,)
        assert set(np.asarray(assignment)) <= set(range(5)) | {-1}


def test_fused_masked_dispatch_equals_oracle_on_masked_matrix():
    rng = np.random.default_rng(4)
    fab = MappingFabric(4, backend="fused")
    mask = np.array([False, True, False, True])
    fab.set_pe_mask(mask)
    mirror = np.zeros(4)
    for _ in range(3):
        avg, ex = _event(rng, 9, 4)
        got = fab.map_event(avg, ex)
        exm = ex.copy()
        exm[:, mask] = np.inf
        want = heft_rt_numpy(avg, exm, mirror)
        mirror = want[4]
        np.testing.assert_array_equal(np.asarray(got[1]), want[1])
        np.testing.assert_array_equal(
            np.asarray(got[4], dtype=np.float64), want[4])
    # masked lanes' registers stayed resident
    assert mirror[1] == 0.0 and mirror[3] == 0.0


# ---------------------------------------------------------------------------
# kernels: decision_hw / decision_ref / pack round-trip
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_decision_hw_and_ref_equal_oracle(seed):
    rng = np.random.default_rng(seed)
    n, p = 8, 4
    avg, ex = _event(rng, n, p)
    avail = rng.integers(0, 8, p).astype(np.float64)
    mask = rng.random(p) < 0.3
    exm = ex.copy()
    exm[:, mask] = np.inf
    want = heft_rt_numpy(avg, exm, avail)
    ref = decision_ref(jnp.asarray(avg, jnp.float32),
                       jnp.asarray(ex, jnp.float32),
                       jnp.asarray(avail, jnp.float32),
                       jnp.ones(n, bool), jnp.asarray(mask))
    hw = decision_hw(np.asarray(avg, np.float32),
                     np.asarray(ex, np.float32),
                     np.asarray(avail, np.float32), mask)
    for got in (tuple(ref), tuple(hw)):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g, dtype=np.float64),
                                          np.asarray(w, dtype=np.float64))


def test_pack_unpack_roundtrip_bit_exact():
    """The single-transfer packing is a pure bit-move: ±inf and every
    mantissa pattern survive the int32 bitcast round trip."""
    rng = np.random.default_rng(0)
    n, p = 6, 4
    avg, ex = _event(rng, n, p, inf_frac=0.5)      # plenty of ±inf lanes
    res = decision_ref(jnp.asarray(avg, jnp.float32),
                       jnp.asarray(ex, jnp.float32),
                       jnp.asarray(rng.random(p), jnp.float32),
                       jnp.ones(n, bool), jnp.zeros(p, bool))
    toks = jnp.asarray(rng.integers(0, 64, (3, 1)), jnp.int32)
    buf = np.asarray(pack_tick_outputs(toks, res))
    assert buf.dtype == np.int32
    np.testing.assert_array_equal(buf[:3], np.asarray(toks).ravel())
    order, assignment, start, finish, avail = unpack_decision(buf[3:], p)
    np.testing.assert_array_equal(order, np.asarray(res.order))
    np.testing.assert_array_equal(assignment, np.asarray(res.assignment))
    np.testing.assert_array_equal(start, np.asarray(res.start_time))
    np.testing.assert_array_equal(finish, np.asarray(res.finish_time))
    np.testing.assert_array_equal(avail, np.asarray(res.new_avail))


def test_backend_effective_reports_actual_lowering():
    on_accel = jax.default_backend() in ("tpu", "gpu")
    assert MappingFabric(4, backend="numpy").backend_effective == "numpy"
    assert MappingFabric(4, backend="jit").backend_effective == "jit"
    assert (MappingFabric(4, backend="pallas").backend_effective
            == ("pallas" if on_accel else "pallas-interpret"))
    assert (MappingFabric(4, backend="fused").backend_effective
            == ("fused" if on_accel else "fused-jnp"))
    assert "fused" in BACKENDS


def test_tick_fusion_api_requires_fused_backend():
    import pytest
    fab = MappingFabric(4, backend="jit")
    with pytest.raises(ValueError, match="fused"):
        fab.tick_decision_inputs(np.zeros(2), np.ones((2, 4)))
    with pytest.raises(ValueError, match="fused"):
        fab.commit_tick_decision(2, np.zeros(20, np.int32), None)


# ---------------------------------------------------------------------------
# fused decode tick: tokens byte-identical, decision rides the transfer
# ---------------------------------------------------------------------------

def _paged_engine(max_len=32):
    eng = ServeEngine(CFG, _params(), max_len=max_len)
    eng.start_paged(max_batch=2, page_size=8)
    return eng


def test_decode_tick_sched_contract_and_counters():
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, CFG.vocab_size, 6).astype(np.int32)
    fab = MappingFabric(4, backend="fused", device_counters=True)

    eng = _paged_engine()
    assert eng.admit(prompt, 8) is not None
    plain_eng = _paged_engine()
    assert plain_eng.admit(prompt, 8) is not None

    mirror = np.zeros(4)
    for step in range(6):
        n = int(rng.integers(2, 10))
        avg, ex = _event(rng, n, 4)
        out, decision = eng.decode_tick((avg, ex, fab))
        assert out == plain_eng.decode_tick()      # byte-identical decode
        want = heft_rt_numpy(avg, ex, mirror)
        mirror = want[4]
        np.testing.assert_array_equal(np.asarray(decision[0]), want[0])
        np.testing.assert_array_equal(np.asarray(decision[1]), want[1])
        np.testing.assert_array_equal(
            np.asarray(decision[4], dtype=np.float64), want[4])
    ctr = fab.drain_counters()
    assert ctr["events"] == 6 and ctr["decisions"] > 0
    # empty-runtime fused tick: nothing active, no decision
    idle = ServeEngine(CFG, _params(), max_len=32)
    idle.start_paged(max_batch=2, page_size=8)
    assert idle.decode_tick((np.zeros(2), np.ones((2, 4)), fab)) == ({}, None)


def test_run_continuous_fused_matches_dense_oracle_and_host_path():
    rng = np.random.default_rng(11)
    reqs = []
    for _ in range(6):
        nt = int(rng.integers(1, 8))
        s0 = int(rng.integers(2, 32 - nt))
        reqs.append((rng.integers(1, CFG.vocab_size, s0).astype(np.int32),
                     nt))
    arrivals = [0, 0, 1, 2, 2, 4]

    def fleet():
        return [ReplicaHandle(f"replica{i}",
                              ServeEngine(CFG, _params(), max_len=32),
                              speed=s)
                for i, s in enumerate([1.0, 0.7])]

    fused_front = HeftFrontEnd(fleet(),
                               fabric=MappingFabric(2, backend="fused",
                                                    device_counters=True))
    outs, stats = fused_front.run_continuous(
        reqs, arrival_ticks=arrivals, max_batch=2, page_size=8, num_pages=8)
    for i, (p, nt) in enumerate(reqs):
        np.testing.assert_array_equal(
            outs[i], _oracle_engine().generate(p[None], nt)[0])
    assert stats["fused_decisions"] + stats["host_decisions"] == len(reqs)
    assert stats["fused_decisions"] > 0        # steady-state path exercised
    assert stats["allocated"] == stats["freed"]
    assert fused_front.fabric.drain_counters()["decisions"] == len(reqs)

    host_front = HeftFrontEnd(fleet())         # numpy-oracle host path
    host_outs, host_stats = host_front.run_continuous(
        reqs, arrival_ticks=arrivals, max_batch=2, page_size=8, num_pages=8)
    for a, b in zip(outs, host_outs):
        np.testing.assert_array_equal(a, b)
    # identical placement: per-replica processed counts agree
    assert stats["processed"] == host_stats["processed"]


def test_run_continuous_fused_flag_validation():
    import pytest
    front = HeftFrontEnd([ReplicaHandle(
        "r0", ServeEngine(CFG, _params(), max_len=32))])
    with pytest.raises(ValueError, match="fused"):
        front.run_continuous([(np.arange(1, 5, dtype=np.int32), 2)],
                             fused=True, max_batch=2, page_size=8,
                             num_pages=8)
