"""Distribution tests: sharding rules, multi-device execution, compression.

Multi-device cases run in subprocesses with a fake 8-device CPU platform
(device count locks at backend init, so the main test process stays at 1)
and EXECUTE real sharded steps — numerics must match the single-device run.
"""

import numpy as np
import pytest

from _subproc import run_sub as _run_sub

from repro.configs import all_arch_names, get_config
from repro.dist.sharding import (
    MeshAxes,
    activation_hint_policy,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
)
from repro.models.config import SHAPES
from repro.models.model import param_specs


# ---------------------------------------------------------------------------
# spec construction (no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", all_arch_names())
def test_param_specs_cover_every_leaf(arch):
    import jax
    from jax.sharding import PartitionSpec
    cfg = get_config(arch)
    ax = MeshAxes(pod="pod")
    specs = param_pspecs(cfg, ax)
    shapes = param_specs(cfg)

    # structure-checked elementwise zip: raises if trees mismatch
    def check(sh, sp):
        assert isinstance(sp, PartitionSpec), (sh, sp)
        assert len(tuple(sp)) <= len(sh.shape), (sp, sh.shape)
        return 0

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, PartitionSpec))


@pytest.mark.parametrize("arch", ["gemma2_9b", "jamba_v0_1_52b",
                                  "deepseek_v2_236b", "falcon_mamba_7b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k", "long_500k"])
def test_cache_and_policy_specs_build(arch, shape):
    cfg = get_config(arch)
    ax = MeshAxes()
    sc = SHAPES[shape]
    pol = activation_hint_policy(cfg, ax, sc)
    assert "layer_boundary" in pol
    if shape != "train_4k":
        specs = cache_pspecs(cfg, ax, sc)
        import jax
        assert len(jax.tree.leaves(specs,
                                   is_leaf=lambda x: hasattr(x, "index"))) > 0


def test_opt_pspecs_int8_structure():
    cfg = get_config("deepseek_7b")
    ax = MeshAxes()
    ps = param_pspecs(cfg, ax)
    shapes = param_specs(cfg)
    o = opt_pspecs(ps, "int8", ax, param_shapes=shapes)
    assert "q" in o["m"]["embed"] and "scale" in o["m"]["embed"]


# ---------------------------------------------------------------------------
# multi-device execution (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

def test_sharded_train_step_matches_single_device():
    """Tiny MoE+attention model: 2×2×2 mesh (pod,data,model) pod-compressed
    step ≈ single-device step (int8 gradient compression tolerance)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models import ModelConfig, MoEConfig, init_params, loss_fn
        from repro.optim import AdamWConfig, adamw_update, init_opt_state
        from repro.dist.sharding import MeshAxes, param_pspecs, activation_hint_policy
        from repro.dist.hints import sharding_policy
        from repro.models.config import ShapeConfig

        cfg = ModelConfig(name='t', num_layers=2, d_model=32, num_heads=4,
                          num_kv_heads=2, d_ff=64, vocab_size=64,
                          param_dtype='float32', compute_dtype='float32',
                          moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=48,
                                        capacity_factor=8.0, layer_period=2,
                                        layer_offset=1))
        ocfg = AdamWConfig(learning_rate=1e-3)
        key = jax.random.key(0)
        params = init_params(key, cfg)
        opt = init_opt_state(params, ocfg)
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, 64)
        labels = jax.random.randint(jax.random.key(2), (8, 32), 0, 64)

        def step(p, o, t, l):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, t, l, cfg)
            p, o, _ = adamw_update(g, o, p, ocfg)
            return p, loss

        # single device reference
        p_ref, loss_ref = jax.jit(step)(params, opt, toks, labels)

        # 8-device mesh
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ax = MeshAxes(pod="pod")
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           param_pspecs(cfg, ax),
                           is_leaf=lambda x: isinstance(x, P))
        shape_cfg = ShapeConfig('train_4k', 'train', 32, 8)
        pol = dict(activation_hint_policy(cfg, ax, shape_cfg,
                                          model_axis_size=2))
        pol['__mesh__'] = mesh
        pol['__moe_groups__'] = 8 * 2
        bsh = NamedSharding(mesh, P(("pod", "data"), None))
        with jax.set_mesh(mesh), sharding_policy(pol):
            jstep = jax.jit(step, in_shardings=(psh, None, bsh, bsh))
            p_sh, loss_sh = jstep(params, opt, toks, labels)
        print("LOSS", float(loss_ref), float(loss_sh))
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
        print("MAXDIFF", d)
        assert abs(float(loss_ref) - float(loss_sh)) < 1e-4
        assert d < 1e-4
        print("OK")
    """)
    assert "OK" in out


def test_compressed_pod_allreduce_close_to_exact():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import compressed_psum_mean, psum_mean
        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        g = jax.random.normal(jax.random.key(0), (4, 64, 128))

        def exact(x):
            return psum_mean({"g": x}, "pod")["g"]

        def comp(x):
            out, err = compressed_psum_mean({"g": x}, "pod")
            return out["g"], err["g"]

        with jax.set_mesh(mesh):
            ex = jax.jit(jax.shard_map(
                exact, mesh=mesh, in_specs=P("pod", None, None),
                out_specs=P("pod", None, None),
                axis_names={"pod"}, check_vma=False))(g)
            cm, err = jax.jit(jax.shard_map(
                comp, mesh=mesh, in_specs=P("pod", None, None),
                out_specs=(P("pod", None, None), P("pod", None, None)),
                axis_names={"pod"}, check_vma=False))(g)
        rel = float(jnp.abs(cm - ex).max() / jnp.abs(ex).max())
        print("REL", rel)
        assert rel < 0.02          # int8 quantization error bound
        # error feedback residual equals local quantization error
        assert float(jnp.abs(err).max()) < float(jnp.abs(g).max()) / 50
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on 1 device, restore onto an 8-device mesh with new shardings."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import Checkpointer
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(1, tree, blocking=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        out = ck.restore(tree, shardings=sh)
        assert out["w"].sharding.spec == P("data", "model")
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out
