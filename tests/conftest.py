import os

# Keep the default test environment at ONE device — the 512-device fake mesh
# belongs to launch/dryrun.py only (it must set XLA_FLAGS before jax import).
# Distribution tests that need a small fake mesh spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
