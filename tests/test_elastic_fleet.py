"""Elastic fleet: variable-P fabric, live replica re-sharding, resize events.

Covers the tentpole claims:

* a ``MappingFabric`` after any grow/shrink/remap sequence carries committed
  ``T_avail`` bit-exact, and dispatches exactly like a fresh fixed-P fabric
  holding the surviving registers (property-tested, every backend via the CI
  matrix),
* a scripted grow/shrink with PEs that never took work is bit-identical to a
  fixed-P fabric replaying the same surviving events,
* ``simulate_serving(fleet_events=[])`` is bit-identical to the fixed-fleet
  simulator; a scripted grow under a load spike strictly improves latency,
* the closed-loop ``FleetController`` grows on backlog and merges back after
  the spike drains, tracing its decisions,
* ``ServeEngine.reshard`` migrates a live replica (params + mid-generation
  KV caches) across mesh slices with token-for-token identical output
  (subprocess, (1,1)→(2,2)→(2,1)),
* ``reshard_tree`` / ``slice_device_pool`` remainder contracts.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _subproc import run_sub as _run_sub

from repro.core import heft_rt_numpy
from repro.sched_integration import (
    CostCell,
    CostModelRegistry,
    FleetController,
    FleetControllerConfig,
    MappingFabric,
    POLICIES,
    ResizeEvent,
    default_fleet,
    grown_replica_factory,
    make_requests,
    make_spike_requests,
    merge_event,
    mesh_fleet,
    simulate_serving,
    split_event,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# variable-P fabric: resize sequences vs fresh fixed-P replay
# ---------------------------------------------------------------------------

def _event(rng, n, p):
    """f32-exact integer grid draws (the device backends' fidelity domain)."""
    avg = rng.integers(0, 5, n).astype(np.float32)
    ex = rng.integers(1, 16, (n, p)).astype(np.float32)
    ex[rng.random(n) < 0.1] = np.inf
    return avg, ex


@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(2, 10))
def test_fabric_resize_sequence_matches_host_mirror(seed, steps):
    """Random interleavings of mapping events and grow/shrink/remap: the
    resident registers track a host-side mirror bit-exact at every step, and
    the final fabric dispatches exactly like a fresh fixed-P fabric seeded
    with the surviving registers (default backend — the CI matrix runs this
    under REPRO_FABRIC_BACKEND=pallas/jit too)."""
    rng = np.random.default_rng(seed)
    fab = MappingFabric(int(rng.integers(1, 6)), backend="auto")
    mirror = np.zeros(fab.num_pes)
    for _ in range(steps):
        op = rng.integers(0, 4)
        if op == 0:                                   # mapping event
            avg, ex = _event(rng, int(rng.integers(1, 12)), fab.num_pes)
            fab.map_event(avg, ex)                    # resident, donated
            mirror = heft_rt_numpy(avg, ex, mirror)[4]
        elif op == 1:                                 # grow
            k = int(rng.integers(1, 4))
            fab.grow(fab.num_pes + k)
            mirror = np.concatenate([mirror, np.zeros(k)])
        elif op == 2 and fab.num_pes > 1:             # shrink
            keep = np.sort(rng.choice(
                fab.num_pes, int(rng.integers(1, fab.num_pes)),
                replace=False))
            fab.shrink(keep)
            mirror = mirror[keep]
        elif op == 3:                                 # remap
            perm = rng.permutation(fab.num_pes)
            fab.remap(perm)
            new = np.empty(fab.num_pes)
            new[perm] = mirror
            mirror = new
        np.testing.assert_array_equal(fab.avail, mirror)

    fresh = MappingFabric(fab.num_pes, backend="auto", avail=mirror)
    avg, ex = _event(rng, 8, fab.num_pes)
    got = fab.map_event(avg, ex, update=False)
    want = fresh.map_event(avg, ex, update=False)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_fabric_grow_shrink_equals_fixed_p_replaying_surviving_events():
    """PEs that joined and left without ever taking work (all-inf exec
    columns) leave no trace: the grown-then-shrunk fabric ends bit-identical
    to a fixed-P fabric replaying the same events without those columns."""
    rng = np.random.default_rng(5)
    P = 3
    fab = MappingFabric(P, backend="jit")
    fixed = MappingFabric(P, backend="jit")
    events = [_event(rng, 6, P) for _ in range(4)]

    fab.map_event(*events[0])                         # event 0 at base P
    fixed.map_event(*events[0])
    fab.grow(P + 2)                                   # two transient PEs
    for avg, ex in events[1:3]:
        ex_wide = np.concatenate(
            [ex, np.full((ex.shape[0], 2), np.inf, np.float32)], axis=1)
        fab.map_event(avg, ex_wide)                   # they never win a task
        fixed.map_event(avg, ex)
    fab.shrink(np.arange(P))                          # transients leave
    fab.map_event(*events[3])
    fixed.map_event(*events[3])
    np.testing.assert_array_equal(fab.avail, fixed.avail)
    assert fab.resizes == 2 and fixed.resizes == 0


def test_fabric_resize_validation():
    fab = MappingFabric(4, backend="numpy")
    with pytest.raises(ValueError, match="grow target"):
        fab.grow(2)
    with pytest.raises(ValueError, match="duplicates"):
        fab.shrink([0, 0, 1])
    with pytest.raises(ValueError, match="out of range"):
        fab.shrink([0, 7])
    with pytest.raises(ValueError, match="permutation"):
        fab.remap([0, 1, 1, 2])
    with pytest.raises(ValueError, match="num_pes"):
        fab.map_event(np.ones(3), np.ones((3, 5)))


def test_fabric_resize_stays_in_compiled_bucket():
    """Grows inside one P bucket reuse the compiled dispatch: the event fn
    object is stable and p_bucket doesn't move until the bucket is crossed."""
    fab = MappingFabric(3, backend="jit", min_pe_bucket=4)
    fn0 = fab._event_fn()
    assert fab.p_bucket == 4
    fab.map_event(*_event(np.random.default_rng(0), 5, 3))
    fab.grow(4)
    assert fab.p_bucket == 4 and fab._event_fn() is fn0
    fab.map_event(*_event(np.random.default_rng(1), 5, 4))
    fab.grow(5)
    assert fab.p_bucket == 8 and fab._event_fn() is fn0
    fab.shrink([0, 1])
    assert fab.p_bucket == 4


def test_policy_fabric_survives_fleet_resize():
    """make_policy_fabric resizes its live fabric on a P change instead of
    rebuilding it (decisions stay oracle-identical at both widths)."""
    from repro.sched_integration import make_policy_fabric
    from repro.sched_integration.serve_scheduler import policy_heft_rt

    rng = np.random.default_rng(2)
    pol = make_policy_fabric()
    for p in (3, 5, 2):
        ex = rng.integers(1, 16, (10, p)).astype(np.float64) / 8.0
        avail = rng.integers(0, 8, p).astype(np.float64) / 8.0
        np.testing.assert_array_equal(pol(ex, avail),
                                      policy_heft_rt(ex, avail))


# ---------------------------------------------------------------------------
# simulate_serving: fleet-event timeline
# ---------------------------------------------------------------------------

def test_empty_fleet_events_bit_identical_to_fixed_fleet():
    fleet = default_fleet()
    reqs = make_requests(rate_rps=600, duration_s=1.0, seed=0)
    a = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9)
    b = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, fleet_events=[])
    assert a.mean_latency == b.mean_latency
    assert a.p50_latency == b.p50_latency
    assert a.p99_latency == b.p99_latency
    assert a.achieved_rps == b.achieved_rps
    np.testing.assert_array_equal(a.replica_util, b.replica_util)
    np.testing.assert_array_equal(a.served_mask, b.served_mask)


def test_grow_event_improves_spike_latency():
    base = mesh_fleet("a", ((4, 4), (4, 4)))
    reqs = make_spike_requests(2.0, 30.0, spike_start=1.0, spike_end=2.0,
                               duration_s=8.0, seed=1)
    static = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                              active_params=7e9)
    grow = ResizeEvent(1.2, add=tuple(mesh_fleet("a", ((4, 4),))))
    elastic = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                               active_params=7e9, fleet_events=[grow])
    assert elastic.served_mask.sum() >= static.served_mask.sum()
    assert elastic.p99_latency < static.p99_latency


def test_remove_event_is_drain_then_leave():
    """Removing a replica mid-run never un-serves committed work, and the
    survivors absorb the rest."""
    fleet = mesh_fleet("a", ((4, 4), (4, 4)))
    reqs = make_requests(rate_rps=3.0, duration_s=4.0, seed=3)
    ev = [ResizeEvent(1.0, remove=(fleet[1].name,))]
    r = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, fleet_events=ev)
    assert r.served_mask.all()
    assert r.replica_util.shape == (1,)    # final roster: one survivor


def test_fleet_events_reject_exec_matrix_and_unknown_names():
    fleet = default_fleet()
    reqs = make_requests(rate_rps=100, duration_s=0.5, seed=4)
    ex = np.ones((len(reqs), len(fleet)))
    with pytest.raises(ValueError, match="exec_matrix"):
        simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, exec_matrix=ex,
                         fleet_events=[ResizeEvent(0.1)])
    with pytest.raises(ValueError, match="no replica named"):
        simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9,
                         fleet_events=[ResizeEvent(0.0, remove=("nope",))])


def test_split_merge_events_balance_devices():
    fleet = mesh_fleet("a", ((2, 2), (4, 4)))
    with pytest.raises(ValueError, match="devices"):
        split_event(0.5, fleet[0], [(1, 1)])
    with pytest.raises(ValueError, match="devices"):
        merge_event(0.5, fleet, (2, 2))
    se = split_event(0.5, fleet[1], [(2, 4), (2, 4)])
    assert se.remove == (fleet[1].name,) and len(se.add) == 2
    assert all(r.compute_tflops == fleet[1].compute_tflops / 2
               for r in se.add)
    me = merge_event(2.0, se.add, (4, 4))
    assert me.add[0].compute_tflops == fleet[1].compute_tflops
    reqs = make_requests(rate_rps=4.0, duration_s=4.0, seed=5)
    r = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, fleet_events=[se, me])
    assert r.served_mask.all()


def test_fleet_event_joiner_gets_scaled_cost_cells():
    """A replica added with a never-dry-run shape is covered by projecting
    the arch's measured cell (ensure_coverage → scaled_cell) mid-run."""
    reg = CostModelRegistry([
        CostCell("a", "prefill", (4, 4), tokens_per_step=1024,
                 flops_per_device=1e12, bytes_per_device=1e9),
        CostCell("a", "decode", (4, 4), tokens_per_step=16,
                 flops_per_device=1e8, bytes_per_device=2e9),
    ])
    fleet = mesh_fleet("a", ((4, 4), (4, 4)))
    joiner = mesh_fleet("a", ((2, 2),))[0]
    assert reg.covers(fleet[0]) and not reg.covers(joiner)
    reqs = make_requests(rate_rps=6.0, duration_s=3.0, seed=6)
    simulate_serving(fleet, reqs, POLICIES["heft_rt"](), active_params=7e9,
                     cost_registry=reg,
                     fleet_events=[ResizeEvent(0.5, add=(joiner,))])
    assert reg.covers(joiner)
    # the projection anchored on the measured (4, 4) cell
    cp = reg.cell("a", "prefill", (2, 2))
    assert cp.flops_per_token == pytest.approx(
        reg.cell("a", "prefill", (4, 4)).flops_per_token * 0.9)


def test_ensure_coverage_anchors_on_measured_cells_join_order_free():
    """Projected cells never anchor further projections: the discount is
    applied once from the measured cell, whatever order joiners arrive."""
    def fresh():
        return CostModelRegistry([
            CostCell("a", "prefill", (1, 1), tokens_per_step=16,
                     flops_per_device=1e12, bytes_per_device=1e9),
            CostCell("a", "decode", (1, 1), tokens_per_step=1,
                     flops_per_device=1e8, bytes_per_device=2e9),
        ])

    small = mesh_fleet("a", ((2, 2),))[0]
    big = mesh_fleet("a", ((4, 4),))[0]
    r1, r2 = fresh(), fresh()
    assert r1.ensure_coverage(small) and r1.ensure_coverage(big)
    assert r2.ensure_coverage(big) and r2.ensure_coverage(small)
    for kind in ("prefill", "decode"):
        c1 = r1.cell("a", kind, (4, 4))
        c2 = r2.cell("a", kind, (4, 4))
        assert c1.projected and c1 == c2          # order-independent
        measured = r1.cell("a", kind, (1, 1))
        assert not measured.projected
        # single 1/0.9 discount from the measured anchor, never compounded
        assert c1.flops_per_token == pytest.approx(
            measured.flops_per_token / 0.9)


def test_make_requests_rejects_non_positive_rate():
    with pytest.raises(ValueError, match="positive"):
        make_requests(lambda t: 0.0 if t < 1 else 10.0, 5.0, seed=0)


def test_merge_event_rejects_mixed_chip_generations():
    fast = mesh_fleet("a", ((2, 2),), chip_tflops=200.0)[0]
    slow = mesh_fleet("a", ((2, 2),), chip_tflops=100.0)[0]
    with pytest.raises(ValueError, match="mixed"):
        merge_event(0.0, [fast, slow], (2, 4))


def test_ensure_coverage_atomic_when_kind_missing():
    reg = CostModelRegistry([
        CostCell("a", "prefill", (4, 4), tokens_per_step=1024,
                 flops_per_device=1e12, bytes_per_device=1e9),
    ])   # no decode cell for the arch at all
    joiner = mesh_fleet("a", ((2, 2),))[0]
    assert not reg.ensure_coverage(joiner)
    assert reg.cell("a", "prefill", (2, 2)) is None   # nothing half-registered


# ---------------------------------------------------------------------------
# closed-loop controller
# ---------------------------------------------------------------------------

def test_controller_grows_on_spike_and_merges_back():
    base = mesh_fleet("a", ((4, 4), (4, 4)))
    reqs = make_spike_requests(2.0, 30.0, spike_start=1.0, spike_end=2.0,
                               duration_s=8.0, seed=1)
    ctl = FleetController(
        FleetControllerConfig(grow_backlog_s=1.0, shrink_backlog_s=0.3,
                              cooldown_s=0.5, max_grown=3),
        grown_replica_factory("a", (4, 4)))
    elastic = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                               active_params=7e9, controller=ctl)
    static = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                              active_params=7e9)
    kinds = [k for _, k, _ in ctl.trace]
    assert "grow" in kinds and "shrink" in kinds
    assert elastic.p99_latency < static.p99_latency
    # every grow happened during/after the spike built backlog
    first_grow = next(t for t, k, _ in ctl.trace if k == "grow")
    assert first_grow >= 1.0
    # shrinks only retire controller-grown replicas, never the base fleet
    assert ctl.grown == [] or all(n.endswith(f"+g{i}") for i, n in
                                  enumerate(ctl.grown))


def test_controller_p95_signal_windows_and_does_not_oscillate():
    """grow_p95_s drives the loop through the *windowed* p95: the spike
    trips it, the window forgets the spike after the drain, and the
    grow/shrink phases stay monotone (a cumulative p95 would latch
    overloaded and oscillate grow/shrink forever)."""
    base = mesh_fleet("a", ((4, 4), (4, 4)))
    reqs = make_spike_requests(2.0, 30.0, spike_start=1.0, spike_end=2.0,
                               duration_s=10.0, seed=1)
    ctl = FleetController(
        FleetControllerConfig(grow_backlog_s=float("inf"), grow_p95_s=1.5,
                              p95_window_s=3.0, shrink_backlog_s=0.3,
                              cooldown_s=0.5, max_grown=2),
        grown_replica_factory("a", (4, 4)))
    simulate_serving(base, reqs, POLICIES["heft_rt"](), active_params=7e9,
                     controller=ctl)
    kinds = [k for _, k, _ in ctl.trace]
    assert "grow" in kinds and "shrink" in kinds
    # monotone phases: once shrinking starts, no further grow (no oscillation)
    first_shrink = kinds.index("shrink")
    assert all(k == "shrink" for k in kinds[first_shrink:])
    assert ctl.grown == []


def test_pending_grow_event_rescues_dead_backlog():
    """Requests no live replica can serve (zero-rate fleet → +inf roofline)
    wait for a *future* scripted joiner instead of being dropped when the
    arrival stream ends before the event fires."""
    from repro.sched_integration import Replica

    dead = [Replica("dead", 0.0, 0.0)]
    reqs = make_requests(rate_rps=20.0, duration_s=0.3, seed=7)
    unserved = simulate_serving(dead, reqs, POLICIES["heft_rt"](),
                                active_params=7e9)
    assert not unserved.served_mask.any()
    live = mesh_fleet("a", ((4, 4),))[0]
    served = simulate_serving(dead, reqs, POLICIES["heft_rt"](),
                              active_params=7e9,
                              fleet_events=[ResizeEvent(2.0, add=(live,))])
    assert served.served_mask.all()


def test_split_merge_reject_non_mesh_replicas():
    from repro.sched_integration import Replica

    abstract = Replica("abstract", 1.0, 1.0)
    with pytest.raises(ValueError, match="mesh-backed"):
        split_event(0.0, abstract, [(1, 1)])
    with pytest.raises(ValueError, match="mesh-backed"):
        merge_event(0.0, [mesh_fleet("a", ((1, 1),))[0], abstract], (2, 1))


def test_controller_cooldown_and_budget():
    ctl = FleetController(
        FleetControllerConfig(grow_backlog_s=1.0, cooldown_s=1.0,
                              max_grown=1),
        grown_replica_factory("a", (2, 2)))
    ev = ctl.observe(0.0, backlog_s=5.0)
    assert ev is not None and len(ev.add) == 1
    assert ctl.observe(0.5, backlog_s=5.0) is None       # cooling down
    assert ctl.observe(2.0, backlog_s=5.0) is None       # budget exhausted
    ev = ctl.observe(4.0, backlog_s=0.0, queue_depth=0)  # drained → shrink
    assert ev is not None and ev.remove
    assert ctl.observe(9.0, backlog_s=0.0) is None       # nothing grown left


# ---------------------------------------------------------------------------
# live engines: reshard + dynamic front-end registry
# ---------------------------------------------------------------------------

def test_engine_reshard_bit_identical_across_slices():
    """(1,1)→(2,2)→(2,1) migration of a live engine: same tokens out at
    every stop, params really move, and a mid-generation KV cache migrates
    through reshard(caches=...) without perturbing the continuation."""
    out = _run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import init_params
        from repro.serve import ServeEngine

        cfg = get_smoke_config('deepseek-7b')
        params = init_params(jax.random.key(0), cfg)
        pool = jax.devices()
        m11 = make_debug_mesh((1, 1), devices=pool[:1])
        m22 = make_debug_mesh((2, 2), devices=pool[:4])
        m21 = make_debug_mesh((2, 1), devices=pool[4:6])

        eng = ServeEngine(cfg, params, max_len=64, mesh=m11)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        want = eng.generate(prompt[None, :], 8)
        for mesh, nd in ((m22, 4), (m21, 2)):
            eng.reshard(mesh)
            assert eng.mesh_shape == tuple(mesh.devices.shape)
            got = eng.generate(prompt[None, :], 8)
            assert np.array_equal(got, want), mesh
            leaf = jax.tree.leaves(eng.params)[0]
            assert len(leaf.sharding.device_set) == nd, leaf.sharding

        # mid-generation migration: 4 tokens on (2,1), move the caches to
        # (2,2), 4 more — equals the uninterrupted run token-for-token
        logits, caches = eng.start(prompt[None, :])
        toks, pos = [], prompt.shape[0]
        for i in range(8):
            if i == 4:
                caches = eng.reshard(m22, caches=caches)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(np.asarray(tok))
            logits, caches = eng.step(caches, tok[:, None], pos + i)
        got = np.concatenate([t[:, None] for t in toks], axis=1)
        assert np.array_equal(got, want[:, 12:]), (got, want[:, 12:])

        eng.reshard(None)       # back to the unmeshed single-device engine
        assert eng.mesh_shape is None
        # the old slice is actually vacated (its devices can be re-carved)
        leaf = jax.tree.leaves(eng.params)[0]
        assert len(leaf.sharding.device_set) == 1, leaf.sharding
        assert np.array_equal(eng.generate(prompt[None, :], 8), want)
        print('OK')
    """)
    assert "OK" in out


def test_front_end_dynamic_registry_resizes_fabric():
    from repro.serve.engine import HeftFrontEnd, ReplicaHandle

    class _Eng:
        mesh_shape = None

    front = HeftFrontEnd([ReplicaHandle("a", _Eng()),
                          ReplicaHandle("b", _Eng(), speed=2.0)],
                         fabric=MappingFabric(2, backend="numpy"))
    reqs = [(np.zeros(10, np.int32), 4), (np.zeros(6, np.int32), 2)]
    front.schedule(reqs)
    front.add_replica(ReplicaHandle("c", _Eng(), speed=4.0,
                                    avail_at=0.125))
    assert front.fabric.num_pes == 3
    assert front.fabric.avail[2] == 0.125     # joiner's register seeded
    plan = front.schedule(reqs)
    assert all(0 <= p < 3 for _, p in plan)
    removed = front.remove_replica("a")
    assert removed.name == "a" and front.fabric.num_pes == 2
    plan = front.schedule(reqs)
    assert all(0 <= p < 2 for _, p in plan)
    with pytest.raises(KeyError):
        front.remove_replica("a")


# ---------------------------------------------------------------------------
# reshard_tree + slice_device_pool contracts
# ---------------------------------------------------------------------------

def test_reshard_tree_identity_and_placement():
    import jax
    from repro.dist import reshard_tree

    tree = {"w": np.arange(6.0).reshape(2, 3), "b": np.ones(3)}
    same = reshard_tree(tree, {"w": None, "b": None})
    assert same["w"] is tree["w"] and same["b"] is tree["b"]

    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    placed = reshard_tree(tree, {"w": sh, "b": None})
    np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
    assert placed["w"].sharding == sh and placed["b"] is tree["b"]
    # old == new placements are skipped (no fresh transfer)
    again = reshard_tree(placed, {"w": sh, "b": None},
                         old_shardings={"w": sh, "b": None})
    assert again["w"] is placed["w"]


def test_slice_device_pool_remainder_contract():
    import jax
    from repro.launch.mesh import slice_device_pool

    pool = list(jax.devices())
    meshes, rem = slice_device_pool([(1, 1)], devices=pool,
                                    return_remainder=True)
    assert len(meshes) == 1 and rem == pool[1:]
    with pytest.raises(ValueError, match="oversubscribed"):
        slice_device_pool([(len(pool) + 1, 1)], devices=pool)
    if len(pool) == 1:
        # exact tiling satisfies the strict contract
        slice_device_pool([(1, 1)], devices=pool, allow_remainder=False)
        with pytest.raises(ValueError, match="unused"):
            slice_device_pool([], devices=pool, allow_remainder=False)
