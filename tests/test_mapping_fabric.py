"""MappingFabric: bucketed/padded dispatch is slot-for-slot oracle-identical.

Covers the tentpole claims of the fabric-batched mapping-event pipeline:

* padded/bucketed ``map_event`` (jit and pallas backends) agrees with the
  unpadded ``heft_rt_numpy`` oracle across bucket boundaries, duplicate
  ``Avg_TID`` keys (stable-sort ties), and all-``inf`` rows,
* the host fast path ``heft_rt_fast`` is bit-identical to the oracle in
  float64 (no f32 representability caveat),
* ``map_batch`` equals per-event oracle calls,
* the early-exit ``dispatch`` contract equals the seed simulator's
  reference implementation for every backend,
* device-resident availability registers chain across events exactly like
  host-side chaining.

Device-backend draws use small integers so every finish time is exactly
representable in f32 (the paper's Fig. 3 bitwise requirement).
"""

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import heft_rt_numpy
from repro.sched_integration import (
    MappingFabric,
    default_fleet,
    eft_dispatch_numpy,
    heft_rt_fast,
    make_policy_fabric,
    make_requests,
    service_time_matrix,
)
from repro.sched_integration.serve_scheduler import policy_heft_rt, service_time_s

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _random_event(rng, n, p, dup_range, inf_frac):
    avg = rng.integers(0, dup_range, n).astype(np.float32)
    ex = rng.integers(1, 16, (n, p)).astype(np.float32)
    kill = rng.random(n) < inf_frac
    ex[kill] = np.inf
    avail = rng.integers(0, 8, p).astype(np.float32)
    return avg, ex, avail


def _assert_matches_oracle(fab, avg, ex, avail):
    order, assignment, start, finish, new_avail = fab.map_event(
        avg, ex, avail, update=False)
    o, a, s, f, na = heft_rt_numpy(avg, ex, avail)
    np.testing.assert_array_equal(order, o, err_msg="priority order diverged")
    np.testing.assert_array_equal(assignment, a)
    np.testing.assert_array_equal(start, s)
    np.testing.assert_array_equal(finish, f)
    np.testing.assert_array_equal(new_avail, na)


@given(
    n=st.integers(1, 40),          # crosses the 8/16/32/64 bucket boundaries
    p=st.integers(1, 8),
    dup_range=st.integers(1, 6),   # small range forces duplicate keys
    inf_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_jit_fabric_matches_oracle(n, p, dup_range, inf_frac, seed):
    rng = np.random.default_rng(seed)
    avg, ex, avail = _random_event(rng, n, p, dup_range, inf_frac)
    fab = MappingFabric(p, backend="jit")
    assert fab.bucket_size(n) >= n and fab.bucket_size(n) >= fab.min_bucket
    _assert_matches_oracle(fab, avg, ex, avail)


@given(
    n=st.integers(1, 40),
    p=st.integers(1, 8),
    inf_frac=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_heft_rt_fast_bit_identical_float64(n, p, inf_frac, seed):
    """The host fast path is exact in float64 — continuous draws, no f32 grid."""
    rng = np.random.default_rng(seed)
    avg = rng.uniform(0, 3, n)
    avg[rng.random(n) < 0.3] = 1.5          # inject exact duplicate keys
    ex = rng.uniform(0.1, 5, (n, p))
    ex[rng.random(n) < inf_frac] = np.inf
    avail = rng.uniform(0, 2, p)
    out = heft_rt_fast(avg, ex, avail)
    ref = heft_rt_numpy(avg, ex, avail)
    for got, want in zip(out, ref):
        np.testing.assert_array_equal(got, want)


def test_pallas_fabric_matches_oracle_across_buckets():
    rng = np.random.default_rng(7)
    fab = MappingFabric(4, backend="pallas")
    for n in (3, 8, 9):                      # below / at / across min_bucket
        avg, ex, avail = _random_event(rng, n, 4, dup_range=3, inf_frac=0.2)
        _assert_matches_oracle(fab, avg, ex, avail)


def test_pallas_fabric_all_inf_rows():
    fab = MappingFabric(3, backend="pallas")
    avg = np.float32([2, 2, 1, 5, 5])        # duplicate keys too
    ex = np.full((5, 3), np.inf, np.float32)
    avail = np.float32([1, 0, 2])
    _assert_matches_oracle(fab, avg, ex, avail)


@given(
    b=st.integers(1, 5),
    n=st.integers(1, 20),
    p=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_map_batch_matches_per_event_oracle(b, n, p, seed):
    rng = np.random.default_rng(seed)
    avg = rng.integers(0, 5, (b, n)).astype(np.float32)
    ex = rng.integers(1, 16, (b, n, p)).astype(np.float32)
    ex[rng.random((b, n)) < 0.15] = np.inf
    avail = rng.integers(0, 8, (b, p)).astype(np.float32)
    fab = MappingFabric(p, backend="jit")
    res = fab.map_batch(avg, ex, avail)
    assert res.order.shape == (b, n)
    for i in range(b):
        o, a, s, f, na = heft_rt_numpy(avg[i], ex[i], avail[i])
        np.testing.assert_array_equal(np.asarray(res.order[i]), o)
        np.testing.assert_array_equal(np.asarray(res.assignment[i]), a)
        np.testing.assert_array_equal(np.asarray(res.start_time[i]), s)
        np.testing.assert_array_equal(np.asarray(res.finish_time[i]), f)
        np.testing.assert_array_equal(np.asarray(res.new_avail[i]), na)


# ---------------------------------------------------------------------------
# dispatch contract (runtime simulator)
# ---------------------------------------------------------------------------

def _reference_dispatch(avg, exec_times, avail, capacity):
    """The seed simulator's early-exit dispatch, kept verbatim as the oracle."""
    order = np.argsort(-avg, kind="stable")
    av = avail.copy()
    cap = capacity.copy()
    out = []
    remaining = int(cap.sum())
    for t in order:
        if remaining == 0:
            break
        fin = av + exec_times[t]
        pe = int(np.argmin(fin))
        if not np.isfinite(fin[pe]):
            continue
        av[pe] = fin[pe]
        if cap[pe] > 0:
            out.append((int(t), pe))
            cap[pe] -= 1
            remaining -= 1
    return out


@given(
    n=st.integers(1, 40),
    p=st.integers(1, 6),
    depth=st.integers(0, 3),
    inf_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_matches_seed_reference(n, p, depth, inf_frac, seed):
    rng = np.random.default_rng(seed)
    avg, ex, avail = _random_event(rng, n, p, dup_range=4, inf_frac=inf_frac)
    avg = avg.astype(np.float64)
    ex = ex.astype(np.float64)
    avail = avail.astype(np.float64)
    capacity = rng.integers(0, depth + 1, p)
    want = _reference_dispatch(avg, ex, avail, capacity)
    assert eft_dispatch_numpy(avg, ex, avail, capacity) == want
    fab = MappingFabric(p, backend="jit")
    assert fab.dispatch(avg, ex, avail, capacity) == want


def test_runtime_dispatch_heft_rt_unchanged():
    from repro.runtime import dispatch_heft_rt

    rng = np.random.default_rng(3)
    avg, ex, avail = _random_event(rng, 25, 5, dup_range=3, inf_frac=0.2)
    capacity = np.array([1, 0, 2, 1, 1])
    assert dispatch_heft_rt(avg, ex, avail, capacity) == \
        _reference_dispatch(avg.astype(np.float64), ex.astype(np.float64),
                            avail.astype(np.float64), capacity)


# ---------------------------------------------------------------------------
# device-resident availability registers
# ---------------------------------------------------------------------------

def test_resident_avail_chains_across_events():
    rng = np.random.default_rng(11)
    p = 4
    fab = MappingFabric(p, backend="jit")
    host_avail = np.zeros(p)
    for _ in range(5):
        avg, ex, _ = _random_event(rng, int(rng.integers(1, 12)), p,
                                   dup_range=4, inf_frac=0.1)
        *_, na = heft_rt_numpy(avg, ex, host_avail)
        fab.map_event(avg, ex)               # resident registers, donated
        host_avail = na
        np.testing.assert_array_equal(fab.avail, host_avail)
    assert fab.events == 5
    fab.reset()
    np.testing.assert_array_equal(fab.avail, np.zeros(p))


def test_explicit_avail_leaves_registers_untouched():
    rng = np.random.default_rng(12)
    fab = MappingFabric(3, backend="jit", avail=[1.0, 2.0, 3.0])
    avg, ex, avail = _random_event(rng, 6, 3, dup_range=4, inf_frac=0.0)
    fab.map_event(avg, ex, avail)
    np.testing.assert_array_equal(fab.avail, [1.0, 2.0, 3.0])
    fab.map_event(avg, ex, update=False)     # resident but read-only
    np.testing.assert_array_equal(fab.avail, [1.0, 2.0, 3.0])


# ---------------------------------------------------------------------------
# vectorized roofline front-end + policy contract
# ---------------------------------------------------------------------------

def test_service_time_matrix_bitwise_equals_scalar_loop():
    fleet = default_fleet()
    reqs = make_requests(rate_rps=300, duration_s=0.5, seed=4)
    got = service_time_matrix(reqs, fleet, active_params=7e9)
    want = np.array([[service_time_s(r, rep, active_params=7e9)
                      for rep in fleet] for r in reqs])
    np.testing.assert_array_equal(got, want)


@given(
    n=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_fabric_policy_matches_oracle_policy(n, seed):
    """Default-backend policy (REPRO_FABRIC_BACKEND in the CI matrix) on the
    f32-exact 1/8-integer grid, so device backends owe bitwise agreement."""
    rng = np.random.default_rng(seed)
    p = 4
    ex = rng.integers(1, 16, (n, p)).astype(np.float64) / 8.0
    ex[rng.random(n) < 0.1] = np.inf
    avail = rng.integers(0, 8, p).astype(np.float64) / 8.0
    pol = make_policy_fabric()
    np.testing.assert_array_equal(pol(ex, avail), policy_heft_rt(ex, avail))


@given(
    n=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_fabric_policy_matches_oracle_policy_float64(n, seed):
    """Continuous draws exercise the numpy host path's exact float64 chain
    (no f32 grid restriction — pinned backend)."""
    rng = np.random.default_rng(seed)
    p = 4
    ex = rng.uniform(0.05, 2.0, (n, p))
    ex[rng.random(n) < 0.1] = np.inf
    avail = rng.uniform(0, 1, p)
    pol = make_policy_fabric("numpy")
    np.testing.assert_array_equal(pol(ex, avail), policy_heft_rt(ex, avail))


def test_fabric_policy_mean_tie_collision_matches_oracle():
    """Distinct row sums can divide to the *same* mean (float division is
    not injective); the tie set — and the stable order — must follow the
    mean, exactly like the oracle policy."""
    ex = np.array([[1.0, 1.0, 1.0000000000000004],
                   [1.0, 1.0, 1.000000000000001]])
    assert ex[0].sum() != ex[1].sum() and ex[0].mean() == ex[1].mean()
    avail = np.zeros(3)
    np.testing.assert_array_equal(make_policy_fabric()(ex, avail),
                                  policy_heft_rt(ex, avail))


def test_bucket_sizes():
    fab = MappingFabric(4, backend="jit", min_bucket=8)
    assert [fab.bucket_size(n) for n in (1, 8, 9, 16, 17, 100)] == \
        [8, 8, 16, 16, 32, 128]
