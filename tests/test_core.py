"""Core HEFT_RT / cycle model / resource model / classic-HEFT tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    DAG,
    PAPER_CRITICAL_PATH_NS,
    SchedulerDesign,
    critical_path_ns,
    first_decision_worst_case,
    heft_rt_numpy,
    heft_static,
    oddeven_sort_cycles,
    per_decision_latency_ns,
    simulate_mapping_event,
    total_luts,
    total_registers,
    upward_rank,
    worst_case_cycles,
)
from repro.core.resource_model import PAPER_TABLE_IV, lutram

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# cycle model — the paper's 3n+3 complexity claims
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 400), seed=st.integers(0, 2**31 - 1))
def test_cycle_model_bounded_by_3n_plus_3(n, seed):
    rng = np.random.default_rng(seed)
    rep = simulate_mapping_event(rng.uniform(0, 1, n))
    assert rep.total_cycles <= worst_case_cycles(n)
    assert rep.first_decision_cycle <= first_decision_worst_case(n)
    assert rep.fill_cycles == n and rep.drain_cycles == n


def test_cycle_model_worst_case_is_tight():
    """Ascending keys are worst-case for a descending sort: within 2 cycles
    of the closed form (parity of the final clean checks)."""
    for n in [4, 16, 64, 256]:
        rep = simulate_mapping_event(np.arange(n, dtype=float))
        assert worst_case_cycles(n) - rep.total_cycles <= 2


def test_presorted_terminates_early():
    n = 128
    rep = simulate_mapping_event(np.arange(n, 0, -1, dtype=float))
    assert rep.sort_cycles == 2  # two clean phases, nothing else
    assert rep.total_cycles == n + 2 + 1 + n - 1


def test_oddeven_sort_correct():
    rng = np.random.default_rng(0)
    keys = rng.uniform(0, 1, 101)
    order, cycles = oddeven_sort_cycles(keys)
    assert (np.diff(keys[order]) <= 1e-12).all()  # descending
    assert cycles <= 101 + 2


def test_paper_headline_9_144ns():
    assert per_decision_latency_ns(512, PAPER_CRITICAL_PATH_NS,
                                   asymptotic=True) == pytest.approx(9.144)


# ---------------------------------------------------------------------------
# resource model — Tables II–IV reproduction quality
# ---------------------------------------------------------------------------

def test_resource_model_vs_table_iv():
    for (P, D, luts, lr, regs, bram, delay) in PAPER_TABLE_IV:
        d = SchedulerDesign(P=P, D=D)
        assert total_luts(d) == pytest.approx(luts, rel=0.06)
        assert total_registers(d) == pytest.approx(regs, rel=0.10)
        assert lutram(d) == pytest.approx(lr, rel=0.01)
        assert critical_path_ns(d) == pytest.approx(delay, rel=0.04)


def test_path_delay_flat_in_depth_grows_in_pes():
    """Paper's scaling claims: D-independent, P-dependent critical path."""
    base = critical_path_ns(SchedulerDesign(P=4, D=64))
    assert critical_path_ns(SchedulerDesign(P=4, D=1024)) == pytest.approx(base)
    assert critical_path_ns(SchedulerDesign(P=16, D=64)) > \
        critical_path_ns(SchedulerDesign(P=8, D=64)) > base


# ---------------------------------------------------------------------------
# HEFT_RT software reference properties
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1))
def test_heft_rt_priority_order_is_descending_avg(seed):
    rng = np.random.default_rng(seed)
    n, p = 37, 4
    avg = rng.uniform(0, 10, n)
    ex = rng.uniform(1, 10, (n, p))
    order, _, _, _, _ = heft_rt_numpy(avg, ex, np.zeros(p))
    assert (np.diff(avg[order]) <= 1e-12).all()


def test_heft_rt_beats_worst_pe_serialization():
    """Scheduling quality sanity: makespan ≤ running everything on one PE."""
    rng = np.random.default_rng(1)
    n, p = 50, 4
    avg = rng.uniform(1, 10, n)
    ex = rng.uniform(1, 10, (n, p))
    _, _, _, fins, new_avail = heft_rt_numpy(avg, ex, np.zeros(p))
    assert new_avail.max() <= ex[:, 0].sum()


# ---------------------------------------------------------------------------
# classic (static) HEFT baseline
# ---------------------------------------------------------------------------

def _diamond_dag():
    comp = np.array([
        [2.0, 1.0],
        [3.0, 6.0],
        [4.0, 2.0],
        [1.0, 1.0],
    ])
    dag = DAG(num_tasks=4, comp=comp,
              succ={0: [(1, 1.0), (2, 1.0)], 1: [(3, 1.0)], 2: [(3, 1.0)]})
    return dag


def test_upward_rank_ordering():
    dag = _diamond_dag()
    r = upward_rank(dag)
    assert r[0] > max(r[1], r[2]) > r[3]  # entry highest, exit lowest


def test_static_heft_schedules_all_respecting_deps():
    dag = _diamond_dag()
    s = heft_static(dag, num_pes=2)
    assert (s.assignment >= 0).all()
    # dependencies respected
    for t, children in dag.succ.items():
        for c, _ in children:
            assert s.start[c] >= s.finish[t] - 1e-9
    assert s.makespan <= dag.comp.min(axis=1).sum() + 10
