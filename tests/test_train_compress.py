"""Error-feedback residual as first-class training state.

Covers the bias bug the residual-carry fixes (property test: carried residual
→ strictly lower cumulative error than the residual-dropping variant), the
amax=0 edge case, microbatched metric accumulation, TrainerConfig knob
wiring, and — in subprocesses with a fake 8-device CPU platform — the
compressed-path fault-injection restart (bitwise identical to an
uninterrupted run, residual included) and the elastic pod-count reshard of
the checkpointed residual.
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _subproc import run_sub as _run_sub

from repro.data import DataConfig
from repro.dist.compression import (
    compressed_psum_mean,
    init_residual,
    reshard_residual,
)
from repro.models import ModelConfig
from repro.optim import AdamWConfig, init_opt_state
from repro.train import Trainer, TrainerConfig, make_train_step


TINY = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=1, d_ff=64, vocab_size=64,
                   param_dtype="float32", compute_dtype="float32")


# ---------------------------------------------------------------------------
# compressed_psum_mean: residual carry vs residual drop (the fixed bias)
# ---------------------------------------------------------------------------

def _pod_compress(carry_err):
    """vmap-over-pods wrapper: lax collectives bind to the vmapped axis."""
    if carry_err:
        return jax.vmap(
            lambda g, e: compressed_psum_mean(g, "pod", e),
            axis_name="pod", in_axes=(0, 0), out_axes=(0, 0))
    return jax.vmap(lambda g: compressed_psum_mean(g, "pod"),
                    axis_name="pod", in_axes=0, out_axes=(0, 0))


@given(seed=st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_residual_carry_strictly_reduces_cumulative_error(seed):
    """Carried for K steps, the cumulative compressed mean telescopes to the
    exact cumulative mean (± final residual / n); dropping the residual lets
    per-step rounding bias accumulate linearly.  Per leaf, mean |cumulative
    error| must be *strictly* lower with the carry."""
    K, pods = 12, 4
    rng = np.random.default_rng(seed)
    shapes = {"w": (pods, 6, 5), "b": (pods, 7)}
    # per-pod constant component → the dropped variant's rounding error
    # correlates across steps (the bias regime error feedback exists for)
    base = {k: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
            for k, s in shapes.items()}

    step_cold = jax.jit(_pod_compress(carry_err=False))
    step_carry = jax.jit(_pod_compress(carry_err=True))

    err = jax.tree.map(lambda b: jnp.zeros_like(b), base)
    cum_carry = {k: 0.0 * base[k][0] for k in base}
    cum_drop = {k: 0.0 * base[k][0] for k in base}
    for t in range(K):
        g = {k: base[k] + 0.05 * jnp.asarray(
                 rng.normal(0, 1, shapes[k]), jnp.float32) for k in base}
        exact = {k: jnp.mean(g[k], axis=0) for k in g}
        m_c, err = step_carry(g, err)
        m_d, _ = step_cold(g)
        # every pod's copy of the mean is identical — take pod 0
        cum_carry = {k: cum_carry[k] + m_c[k][0] - exact[k] for k in g}
        cum_drop = {k: cum_drop[k] + m_d[k][0] - exact[k] for k in g}

    for k in base:
        carried = float(jnp.mean(jnp.abs(cum_carry[k])))
        dropped = float(jnp.mean(jnp.abs(cum_drop[k])))
        assert carried < dropped, (k, carried, dropped)


def test_compressed_all_zero_gradients_amax_zero_path():
    """amax=0 must not produce NaN/Inf: mean and residual stay exactly 0."""
    g = {"w": jnp.zeros((4, 8, 3)), "b": jnp.zeros((4, 5))}
    mean, err = jax.jit(_pod_compress(carry_err=False))(g)
    for leaf in jax.tree.leaves(mean) + jax.tree.leaves(err):
        arr = np.asarray(leaf)
        assert np.all(np.isfinite(arr))
        np.testing.assert_array_equal(arr, np.zeros_like(arr))
    # and a second step carrying the (zero) residual stays zero too
    mean2, err2 = jax.jit(_pod_compress(carry_err=True))(g, err)
    np.testing.assert_array_equal(np.asarray(mean2["w"]),
                                  np.zeros_like(np.asarray(mean2["w"])))
    np.testing.assert_array_equal(np.asarray(err2["b"]),
                                  np.zeros_like(np.asarray(err2["b"])))


def test_reshard_residual_preserves_applied_correction():
    rng = np.random.default_rng(0)
    res = {"w": jnp.asarray(rng.normal(0, 1, (2, 3, 4)), jnp.float32)}
    same = reshard_residual(res, 2)
    np.testing.assert_array_equal(np.asarray(same["w"]),
                                  np.asarray(res["w"]))
    up = reshard_residual(res, 4)["w"]
    assert up.shape == (4, 3, 4)
    # Σ'e'/n' == Σe/n: every new pod carries the old pods' mean
    np.testing.assert_allclose(np.asarray(jnp.mean(up, axis=0)),
                               np.asarray(jnp.mean(res["w"], axis=0)),
                               rtol=1e-6)
    down = reshard_residual({"w": up}, 1)["w"]
    assert down.shape == (1, 3, 4)


def test_init_residual_shapes():
    params = {"a": jnp.ones((3, 4)), "n": {"b": jnp.ones(7)}}
    res = init_residual(params, 2)
    assert res["a"].shape == (2, 3, 4)
    assert res["n"]["b"].shape == (2, 7)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(res))


# ---------------------------------------------------------------------------
# microbatched metrics (satellite bugfix: grads_of used to return {})
# ---------------------------------------------------------------------------

def _tiny_state(cfg=TINY, seed=0):
    from repro.models.model import init_params
    ocfg = AdamWConfig(learning_rate=1e-3)
    params = init_params(jax.random.key(seed), cfg)
    opt = init_opt_state(params, ocfg)
    pipe = TokenPipelineBatch()
    return ocfg, params, opt, pipe


class TokenPipelineBatch:
    def __init__(self):
        from repro.data import TokenPipeline
        self.p = TokenPipeline(DataConfig(vocab_size=64, seq_len=32,
                                          global_batch=8))

    def at(self, step):
        return {k: jnp.asarray(v) for k, v in self.p.batch_at(step).items()}


def test_microbatched_step_keeps_ce_metric_and_matches_plain():
    ocfg, params, opt, pipe = _tiny_state()
    step1 = jax.jit(make_train_step(TINY, ocfg))
    step4 = jax.jit(make_train_step(TINY, ocfg, microbatches=4))
    batch = pipe.at(0)
    p1, o1, r1, m1 = step1(params, opt, None, batch)
    p4, o4, r4, m4 = step4(params, opt, None, batch)
    assert r1 is None and r4 is None
    assert "ce" in m1 and "ce" in m4      # used to be dropped under accum
    assert float(m4["ce"]) == pytest.approx(float(m1["ce"]), rel=1e-4)
    assert float(m4["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_microbatched_moe_aux_metrics_accumulated():
    from repro.models import MoEConfig
    cfg = ModelConfig(name="tm", num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32",
                      moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=48,
                                    capacity_factor=8.0, layer_period=2,
                                    layer_offset=1))
    ocfg, params, opt, pipe = _tiny_state(cfg)
    step1 = jax.jit(make_train_step(cfg, ocfg))
    step2 = jax.jit(make_train_step(cfg, ocfg, microbatches=2))
    batch = pipe.at(0)
    _, _, _, m1 = step1(params, opt, None, batch)
    _, _, _, m2 = step2(params, opt, None, batch)
    for k in ("ce", "aux_loss", "z_loss", "expert_load"):
        assert k in m1 and k in m2, (k, list(m1), list(m2))
    for k in ("ce", "aux_loss", "z_loss"):   # intensive: per-token means
        np.testing.assert_allclose(np.asarray(m2[k]), np.asarray(m1[k]),
                                   rtol=5e-2, atol=1e-3)
    # expert_load is an extensive token count: summed (not meaned) across
    # microbatches, so the same global batch reports comparable totals
    # whatever the accumulation factor (routing may shift a little because
    # per-microbatch capacity drops go through different boundaries)
    np.testing.assert_allclose(np.asarray(m2["expert_load"]),
                               np.asarray(m1["expert_load"]), rtol=0.3)


# ---------------------------------------------------------------------------
# Trainer knob wiring (satellite bugfix: knobs used to be ignored)
# ---------------------------------------------------------------------------

def _trainer(tmp, total=3, checkpoint_every=10, **kw):
    return Trainer(TINY, AdamWConfig(learning_rate=3e-3),
                   DataConfig(vocab_size=64, seq_len=32, global_batch=8),
                   TrainerConfig(total_steps=total,
                                 checkpoint_every=checkpoint_every,
                                 checkpoint_dir=tmp, log_every=5, **kw))


def test_trainer_microbatches_knob_is_wired(tmp_path):
    p1, _, _ = _trainer(str(tmp_path / "a"), microbatches=1).run()
    p4, _, _ = _trainer(str(tmp_path / "b"), microbatches=4).run()
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_trainer_plain_path_residual_none_roundtrip(tmp_path):
    d = str(tmp_path)
    tr = _trainer(d, total=2, checkpoint_every=2)
    tr.run()
    assert tr.last_residual is None
    tr2 = _trainer(d, total=4, checkpoint_every=2)
    params, opt, residual, start = tr2.init_or_restore()
    assert residual is None and start == 2


def test_trainer_single_pod_mesh_checkpoints_residual(tmp_path):
    """mesh_shape=(1,1) runs the full compressed pod path on one device."""
    d = str(tmp_path)
    tr = _trainer(d, total=4, checkpoint_every=2, mesh_shape=(1, 1),
                  compress_pods=True)
    tr.run()
    saved = tr.last_residual
    assert saved is not None
    assert all(l.shape[0] == 1 for l in jax.tree.leaves(saved))
    # residual really carries information after 4 int8 steps
    assert max(float(jnp.abs(l).max()) for l in jax.tree.leaves(saved)) > 0
    tr2 = _trainer(d, total=6, checkpoint_every=2, mesh_shape=(1, 1),
                   compress_pods=True)
    _, _, restored, start = tr2.init_or_restore()
    assert start == 4
    for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_legacy_checkpoint_without_residual(tmp_path):
    """Pre-residual checkpoints cold-start the error feedback at zero."""
    from repro.checkpoint import Checkpointer
    d = str(tmp_path)
    tr = _trainer(d, total=4, mesh_shape=(1, 1), compress_pods=True)
    params, opt, residual, _ = tr.init_or_restore()
    Checkpointer(d).save(2, {"params": params, "opt": opt}, blocking=True)
    _, _, restored, start = tr.init_or_restore()
    assert start == 2
    for leaf in jax.tree.leaves(restored):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))


# ---------------------------------------------------------------------------
# multi-device: compressed-path restart bitwise + elastic pod reshard
# ---------------------------------------------------------------------------

_SUB_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.models import ModelConfig
    from repro.optim import AdamWConfig
    from repro.data import DataConfig
    from repro.train import Trainer, TrainerConfig

    cfg = ModelConfig(name='t', num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype='float32', compute_dtype='float32')

    def mk(d, total, mesh_shape=(2, 2), micro=1):
        return Trainer(cfg, AdamWConfig(learning_rate=3e-3),
                       DataConfig(vocab_size=64, seq_len=32, global_batch=8),
                       TrainerConfig(total_steps=total, checkpoint_every=3,
                                     checkpoint_dir=d, mesh_shape=mesh_shape,
                                     compress_pods=True, microbatches=micro))
"""


def _run_pod_sub(body: str) -> str:
    # dedent the pieces separately: the prelude and body have different
    # indent depths, and a joint dedent would nest the body inside mk()
    return _run_sub(textwrap.dedent(_SUB_PRELUDE) + textwrap.dedent(body))


def test_compressed_restart_bitwise_identical_to_uninterrupted():
    """Crash at step 5 of 8 on the int8 pod path, resume, and match the
    straight-through run bit for bit — params AND residual (the state the
    seed trainer silently dropped)."""
    out = _run_pod_sub("""
        d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
        try:
            mk(d1, 8).run(inject_failure_at=5)
            raise SystemExit('no injected failure?')
        except RuntimeError:
            pass
        ta = mk(d1, 8); pa, _, _ = ta.run()          # resumed
        tb = mk(d2, 8); pb, _, _ = tb.run()          # uninterrupted
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ta.last_residual),
                        jax.tree.leaves(tb.last_residual)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK')
    """)
    assert "OK" in out


def test_compressed_restart_bitwise_with_microbatches():
    out = _run_pod_sub("""
        d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
        try:
            mk(d1, 7, micro=2).run(inject_failure_at=4)
            raise SystemExit('no injected failure?')
        except RuntimeError:
            pass
        pa, _, _ = mk(d1, 7, micro=2).run()
        pb, _, _ = mk(d2, 7, micro=2).run()
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK')
    """)
    assert "OK" in out


def test_pod_count_reshard_restores_residual_elastically():
    """Save on 2 pods, restore onto 4 (and back onto 1): residual leaves are
    mean-broadcast (Σe/n preserved), placed P(pod) on the new mesh, and
    training continues."""
    out = _run_pod_sub("""
        d = tempfile.mkdtemp()
        tr2 = mk(d, 4)
        tr2.run()
        want = np.asarray(jax.tree.leaves(tr2.last_residual)[0]).mean(axis=0)
        tr4 = mk(d, 6, mesh_shape=(4, 2))
        p, o, r, start = tr4.init_or_restore()
        assert start == 4
        leaves = jax.tree.leaves(r)
        assert all(l.shape[0] == 4 for l in leaves)
        got = np.asarray(leaves[0])
        for i in range(4):
            np.testing.assert_allclose(got[i], want, rtol=1e-6)
        _, _, hist = tr4.run()
        assert hist, 'no training after reshard'
        tr1 = mk(d, 6, mesh_shape=(1, 2))
        _, _, r1, _ = tr1.init_or_restore()
        assert all(l.shape[0] == 1 for l in jax.tree.leaves(r1))
        print('OK')
    """)
    assert "OK" in out


def test_pod_step_matches_single_device_within_int8_tolerance():
    """The (2,2)-mesh compressed step stays close to the plain single-config
    step (int8 quantization tolerance) — the vmap-over-pods + manual-reduce
    restructuring must not change the math."""
    out = _run_pod_sub("""
        from repro.optim import init_opt_state
        from repro.models.model import init_params
        from repro.train import make_train_step
        from repro.data import TokenPipeline
        ocfg = AdamWConfig(learning_rate=3e-3)
        params = init_params(jax.random.key(0), cfg)
        opt = init_opt_state(params, ocfg)
        batch = {k: jnp.asarray(v) for k, v in TokenPipeline(
            DataConfig(vocab_size=64, seq_len=32, global_batch=8)
        ).batch_at(0).items()}
        plain = jax.jit(make_train_step(cfg, ocfg))
        p_ref, _, _, m_ref = plain(params, opt, None, batch)
        mesh = jax.make_mesh((2, 2), ('pod', 'data'))
        exact = jax.jit(make_train_step(cfg, ocfg, pod_axis='pod',
                                        compress_pods=False, mesh=mesh))
        comp = jax.jit(make_train_step(cfg, ocfg, pod_axis='pod',
                                       compress_pods=True, mesh=mesh))
        with jax.set_mesh(mesh):
            p_ex, _, r_ex, m_ex = exact(params, opt, None, batch)
            p_cp, _, res, m_cp = comp(params, opt, None, batch)
        assert r_ex is None
        assert all(l.shape[0] == 2 for l in jax.tree.leaves(res))
        # exact pod reduce: pure restructuring, must match plain tightly
        assert abs(float(m_ref['loss']) - float(m_ex['loss'])) < 1e-5
        d_ex = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree.leaves(p_ref),
                                   jax.tree.leaves(p_ex)))
        print('MAXDIFF exact', d_ex)
        assert d_ex < 1e-5
        # int8 path: loss (pre-update) identical; params within the Adam
        # step bound — a quantized near-zero grad can flip m/sqrt(v) by
        # O(1), moving that element by up to ~lr on the first step
        assert abs(float(m_ref['loss']) - float(m_cp['loss'])) < 1e-5
        d_cp = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree.leaves(p_ref),
                                   jax.tree.leaves(p_cp)))
        print('MAXDIFF int8', d_cp)
        assert d_cp < 2 * 3e-3
        print('OK')
    """)
    assert "OK" in out
