"""Per-architecture smoke tests (reduced configs) + model-level equivalences.

Assignment requirement: for each of the 10 architectures, instantiate a
REDUCED config of the same family and run one forward/train step on CPU
asserting output shapes + no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config, get_smoke_config
from repro.models import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    decode_step,
    init_params,
    logits_fn,
    loss_fn,
    prefill_step,
)
from repro.models.mamba import init_mamba_params, mamba_block, selective_scan
from repro.models.moe import init_moe_params, moe_block


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)

    logits, _ = logits_fn(params, toks, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, metrics = loss_fn(params, toks, labels, cfg)
    assert jnp.isfinite(loss)
    assert float(loss) > 0

    grads = jax.grad(lambda p: loss_fn(p, toks, labels, cfg)[0])(params)
    gsum = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_decode_matches_forward(arch):
    """prefill + token-by-token decode == full forward (last-token logits)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # avoid capacity-drop divergence in the tiny regime
        cfg = cfg.with_(moe=MoEConfig(**{
            **cfg.moe.__dict__, "capacity_factor": 16.0}))
    params = init_params(jax.random.key(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits_full, _ = logits_fn(params, toks, cfg)
    lg, caches = prefill_step(params, toks[:, : S // 2], cfg, max_len=S)
    for t in range(S // 2, S):
        lg, caches = decode_step(params, caches, toks[:, t:t + 1],
                                 jnp.int32(t), cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_full_configs_match_published_sizes():
    expected = {
        "musicgen_medium": (1.37e9, 0.03), "deepseek_7b": (6.9e9, 0.03),
        "phi3_medium_14b": (14.7e9, 0.03), "gemma2_9b": (9.2e9, 0.03),
        "yi_34b": (34.4e9, 0.03), "deepseek_v2_236b": (235.7e9, 0.03),
        "arctic_480b": (476.9e9, 0.03), "falcon_mamba_7b": (7.3e9, 0.03),
        "jamba_v0_1_52b": (51.6e9, 0.03), "chameleon_34b": (34.3e9, 0.03),
    }
    for arch, (n, tol) in expected.items():
        cfg = get_config(arch)
        assert cfg.param_count() == pytest.approx(n, rel=tol), arch


def test_moe_active_params_much_smaller():
    for arch in ["deepseek_v2_236b", "arctic_480b", "jamba_v0_1_52b"]:
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.3 * cfg.param_count()


# ---------------------------------------------------------------------------
# mamba: chunked scan == sequential recurrence oracle
# ---------------------------------------------------------------------------

def _mamba_cfg(chunk):
    return ModelConfig(
        name="m", num_layers=1, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=7, block_pattern=("mamba",),
        ssm=SSMConfig(d_inner=64, d_state=8, chunk=chunk, dt_rank=4),
        param_dtype="float32", compute_dtype="float32")


def test_mamba_chunked_equals_sequential():
    cfg16 = _mamba_cfg(16)
    cfg1 = _mamba_cfg(1)   # chunk=1 → pure sequential recurrence
    params = init_mamba_params(jax.random.key(0), cfg16)
    u = jax.random.normal(jax.random.key(1), (2, 32, 64))
    y16, h16 = selective_scan(params, u, cfg16)
    y1, h1 = selective_scan(params, u, cfg1)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h1),
                               rtol=1e-5, atol=1e-5)


def test_mamba_streaming_equals_batch():
    """Processing a sequence in two halves with carried state == one shot."""
    cfg = _mamba_cfg(4)
    params = init_mamba_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y_full, _ = mamba_block(params, x, cfg)
    B = 2
    cache = {"conv": jnp.zeros((B, 3, 64)), "ssm": jnp.zeros((B, 64, 8))}
    y1, cache = mamba_block(params, x[:, :8], cfg, cache=cache)
    ys = [y1]
    for t in range(8, 16):
        yt, cache = mamba_block(params, x[:, t:t + 1], cfg, cache=cache,
                                decode_pos=jnp.int32(t))
        ys.append(yt)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE properties
# ---------------------------------------------------------------------------

def _moe_cfg(cf=16.0, experts=8, k=2):
    return ModelConfig(
        name="moe", num_layers=1, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=64, vocab_size=7,
        moe=MoEConfig(num_experts=experts, top_k=k, expert_d_ff=48,
                      capacity_factor=cf),
        param_dtype="float32", compute_dtype="float32")


def test_moe_no_drops_at_high_capacity():
    cfg = _moe_cfg(cf=32.0)
    params = init_moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    out, metrics = moe_block(params, x, cfg)
    assert out.shape == x.shape
    # all T·k assignments kept
    assert int(metrics["expert_load"].sum()) == 2 * 16 * cfg.moe.top_k


def test_moe_load_conserved_with_drops():
    cfg = _moe_cfg(cf=0.5)
    params = init_moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    out, metrics = moe_block(params, x, cfg)
    total = int(metrics["expert_load"].sum())
    assert 0 < total <= 2 * 16 * cfg.moe.top_k
    assert bool(jnp.isfinite(out).all())


def test_moe_aux_losses_finite_positive():
    cfg = _moe_cfg()
    params = init_moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    _, metrics = moe_block(params, x, cfg)
    assert float(metrics["aux_loss"]) > 0
    assert float(metrics["z_loss"]) >= 0


# ---------------------------------------------------------------------------
# attention variants (windows, softcap) — already covered by arch smokes;
# extra: local window masks really restrict context.
# ---------------------------------------------------------------------------

def test_local_window_changes_long_range_attention():
    base = dict(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                d_ff=64, vocab_size=11, param_dtype="float32",
                compute_dtype="float32")
    cfg_local = ModelConfig(name="loc", window_pattern=("local",),
                            local_window=4, **base)
    cfg_global = ModelConfig(name="glob", **base)
    params = init_params(jax.random.key(0), cfg_local)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, 11)
    l_loc, _ = logits_fn(params, toks, cfg_local)
    l_glob, _ = logits_fn(params, toks, cfg_global)
    assert not np.allclose(np.asarray(l_loc[:, -1]), np.asarray(l_glob[:, -1]),
                           atol=1e-5)
