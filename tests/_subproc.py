"""Shared fake-multi-device subprocess runner for tests.

jax locks the device count at first backend init, so multi-device cases run
in fresh subprocesses with ``--xla_force_host_platform_device_count`` set in
the environment *before* any jax import.  One copy here instead of one per
test module (test_dist / test_serve_sharded / test_train_compress).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900,
            expect_returncode: int = 0) -> str:
    """Run ``code`` in a fresh fake-multi-device python.

    ``expect_returncode`` lets chaos tests assert a process *died the way it
    was killed* (e.g. ``-signal.SIGKILL`` for the kill-and-recover test)
    instead of exiting cleanly.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == expect_returncode, (
        f"returncode {out.returncode} != {expect_returncode}; "
        f"stderr:\n{out.stderr[-3000:]}")
    return out.stdout
