"""End-to-end behaviour tests for the paper's system.

The headline claims, verified through the full stack (Pallas kernels →
software scheduler → cycle/overhead models → CEDR runtime simulation):

  1. HW and SW schedulers make bit-identical mapping decisions (Fig 3);
  2. per-decision latency of the hardware design is 9.144 ns (3 cycles at
     the 3.048 ns critical path of the D=512/P=4 design);
  3. scheduling-computation speedup is 183× at queue size 1330; end-to-end
     (with AXI transfer) 2.6×; crossover at queue size 5;
  4. in the oversubscribed runtime, the hardware scheduler sustains a higher
     achieved frame rate and lower per-app execution time.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_CRITICAL_PATH_NS,
    heft_rt_numpy,
    per_decision_latency_ns,
)
from repro.kernels import heft_rt_hw
from repro.runtime import (
    HW_MODEL,
    SW_MODEL,
    CedrSimulator,
    hw_compute_s,
    hw_overhead_s,
    paper_soc_pe_types,
    sw_overhead_s,
)
from repro.runtime.workload import high_latency_arrivals, low_latency_arrivals


def test_end_to_end_hw_sw_equivalence_on_runtime_workload():
    """Drive the Pallas overlay with real mapping events harvested from the
    runtime sim and check bit-identical decisions vs the software path."""
    pes = paper_soc_pe_types()
    sim = CedrSimulator(pes, seed=0)
    res = sim.run(low_latency_arrivals(150, seed=0))
    assert res.completed_apps == res.num_apps
    rng = np.random.default_rng(0)
    for n in [1, 3, 17, 64]:
        avg = rng.uniform(0.1, 5.0, n).astype(np.float32)
        ex = rng.uniform(0.1, 5.0, (n, 4)).astype(np.float32)
        avail = rng.uniform(0, 2, 4).astype(np.float32)
        o_hw, a_hw, _, _, _ = heft_rt_hw(jnp.array(avg), jnp.array(ex),
                                         jnp.array(avail))
        o_sw, a_sw, _, _, _ = heft_rt_numpy(avg, ex, avail)
        np.testing.assert_array_equal(np.asarray(o_hw), o_sw)
        np.testing.assert_array_equal(np.asarray(a_hw), a_sw)


def test_headline_numbers():
    assert per_decision_latency_ns(512, PAPER_CRITICAL_PATH_NS,
                                   asymptotic=True) == pytest.approx(9.144)
    assert sw_overhead_s(1330) / hw_compute_s(1330) == pytest.approx(183, rel=0.02)
    assert sw_overhead_s(1330) / hw_overhead_s(1330) == pytest.approx(2.6, rel=0.05)


def test_oversubscribed_system_performance():
    pes = paper_soc_pe_types()
    arr = high_latency_arrivals(550, seed=1)
    r_sw = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(arr)
    r_hw = CedrSimulator(pes, overhead=HW_MODEL, seed=7).run(arr)
    assert r_hw.achieved_frame_rate > r_sw.achieved_frame_rate
    assert r_hw.avg_app_exec_time < r_sw.avg_app_exec_time
    # ready queues really reach the hundreds (Fig 4 regime)
    assert r_sw.max_queue_size > 100
