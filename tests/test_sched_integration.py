"""HEFT_RT as a framework feature: expert placement + serving scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, MoEConfig
from repro.models.moe import init_moe_params, moe_block
from repro.sched_integration import (
    POLICIES,
    apply_placement,
    default_fleet,
    make_requests,
    makespan,
    placement_permutation,
    plan_expert_placement,
    round_robin_assignment,
    service_time_matrix,
    simulate_serving,
)


# ---------------------------------------------------------------------------
# expert placement
# ---------------------------------------------------------------------------

def test_heft_placement_beats_round_robin_on_skewed_load():
    rng = np.random.default_rng(0)
    E, P = 64, 8
    # Zipf-skewed expert loads (realistic router statistics)
    load = (np.arange(1, E + 1) ** -1.1)
    load = rng.permutation(load)
    speed = np.ones(P)
    heft = plan_expert_placement(load, speed)
    rr = round_robin_assignment(E, P)
    ms_h = makespan(load, speed, heft)
    ms_rr = makespan(load, speed, rr)
    lower = max(load.max(), load.sum() / P)   # makespan lower bound
    assert ms_h < 0.85 * ms_rr                # clearly better than default
    assert ms_h <= 1.05 * lower               # near-optimal greedy packing


def test_heft_placement_heterogeneous_devices():
    """Faster devices should absorb more load."""
    rng = np.random.default_rng(1)
    E, P = 32, 4
    load = rng.uniform(1, 10, E)
    speed = np.array([1.0, 1.0, 2.0, 4.0])
    a = plan_expert_placement(load, speed)
    per_dev = np.zeros(P)
    for e, d in enumerate(a):
        per_dev[d] += load[e]
    assert per_dev[3] > per_dev[0]


def test_placement_permutation_is_balanced():
    rng = np.random.default_rng(2)
    E, P, epd = 16, 4, 4
    load = rng.uniform(1, 10, E)
    a = plan_expert_placement(load, np.ones(P))
    perm = placement_permutation(a, P, epd)
    assert sorted(perm.tolist()) == list(range(E))


def test_moe_output_invariant_under_placement_permutation():
    """Permuting experts + router columns preserves the model function."""
    cfg = ModelConfig(
        name="m", num_layers=1, d_model=32, num_heads=1, num_kv_heads=1,
        d_ff=64, vocab_size=7,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=48,
                      capacity_factor=32.0),
        param_dtype="float32", compute_dtype="float32")
    params = init_moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    out_base, m_base = moe_block(params, x, cfg)
    load = np.asarray(m_base["expert_load"])
    a = plan_expert_placement(load + 1.0, np.ones(4))
    perm = placement_permutation(a, 4, 2)
    params_p = apply_placement(params, perm)
    out_perm, m_perm = moe_block(params_p, x, cfg)
    np.testing.assert_allclose(np.asarray(out_base), np.asarray(out_perm),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_base["expert_load"])[perm],
                               np.asarray(m_perm["expert_load"]))


# ---------------------------------------------------------------------------
# serving scheduler (the paper's oversubscription experiment, LLM-flavoured)
# ---------------------------------------------------------------------------

def test_heft_serving_beats_round_robin_under_oversubscription():
    fleet = default_fleet()
    reqs = make_requests(rate_rps=400, duration_s=4.0, seed=0)
    active = 7e9
    res = {}
    for name, factory in POLICIES.items():
        res[name] = simulate_serving(fleet, reqs, factory(),
                                     active_params=active)
    assert res["heft_rt"].mean_latency <= res["round_robin"].mean_latency
    assert res["heft_rt"].mean_latency <= res["random"].mean_latency
    assert res["heft_rt"].p99_latency <= 1.05 * res["least_loaded"].p99_latency


def test_serving_saturation_behaviour():
    """Achieved ≈ offered below capacity; flat above (paper Fig 6 analogue)."""
    fleet = default_fleet()
    active = 7e9
    lo = simulate_serving(fleet, make_requests(50, 4.0, seed=1),
                          POLICIES["heft_rt"](), active_params=active)
    assert lo.achieved_rps == pytest.approx(lo.offered_rps, rel=0.25)
    hi1 = simulate_serving(fleet, make_requests(2000, 4.0, seed=1),
                           POLICIES["heft_rt"](), active_params=active)
    hi2 = simulate_serving(fleet, make_requests(3000, 4.0, seed=1),
                           POLICIES["heft_rt"](), active_params=active)
    assert hi2.achieved_rps == pytest.approx(hi1.achieved_rps, rel=0.15)


def test_unschedulable_request_terminates_and_does_not_poison_fleet():
    """Regression: a request no replica can serve (exec = +inf row) used to
    be committed to replica -1 (poisoning the last replica's horizon); now it
    stays unserved and the hoisted runaway-clock guard ends the simulation."""
    fleet = default_fleet()
    reqs = make_requests(rate_rps=100, duration_s=1.0, seed=3)
    ex = service_time_matrix(reqs, fleet, active_params=7e9)
    poisoned = ex.copy()
    poisoned[5, :] = np.inf                  # request 5: unsupported everywhere
    res = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                           active_params=7e9, exec_matrix=poisoned)
    clean = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                             active_params=7e9, exec_matrix=ex)
    assert np.isfinite(res.p99_latency) and np.isfinite(res.mean_latency)
    assert np.isfinite(res.replica_util).all()
    # exactly the one poisoned request is dropped
    assert res.achieved_rps < clean.achieved_rps
    assert res.achieved_rps > 0.9 * clean.achieved_rps


def test_unsupported_row_does_not_poison_baseline_policies():
    """Baseline policies don't check supportability; the commit pass must
    still refuse infinite-exec picks instead of setting free_at = inf."""
    fleet = default_fleet()
    reqs = make_requests(rate_rps=100, duration_s=1.0, seed=3)
    ex = service_time_matrix(reqs, fleet, active_params=7e9)
    poisoned = ex.copy()
    poisoned[5, :] = np.inf
    res = simulate_serving(fleet, reqs, POLICIES["round_robin"](),
                           active_params=7e9, exec_matrix=poisoned)
    assert np.isfinite(res.p99_latency)
    assert np.isfinite(res.replica_util).all()
    assert res.achieved_rps > 0


def test_nothing_servable_returns_empty_result():
    """All requests unschedulable: a defined empty ServeResult, no crash."""
    fleet = default_fleet()
    reqs = make_requests(rate_rps=50, duration_s=0.5, seed=0)
    ex = np.full((len(reqs), len(fleet)), np.inf)
    res = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                           active_params=7e9, exec_matrix=ex)
    assert res.achieved_rps == 0.0
    assert np.isnan(res.mean_latency) and np.isnan(res.p99_latency)
    np.testing.assert_array_equal(res.replica_util, np.zeros(len(fleet)))


def test_round_robin_policy_vectorized_matches_counter():
    """The offset+arange round-robin must equal the per-request counter, and
    the counter must persist across mapping events."""
    import itertools

    pol = POLICIES["round_robin"]()
    c = itertools.count()
    rng = np.random.default_rng(0)
    for _ in range(5):
        n, P = int(rng.integers(1, 12)), 4
        ex = rng.uniform(0.1, 1.0, (n, P))
        want = np.array([next(c) % P for _ in range(n)], dtype=np.int64)
        np.testing.assert_array_equal(pol(ex, np.zeros(P)), want)


def _reference_simulate(replicas, requests, policy, *, active_params,
                        sched_tick_s=0.005):
    """The seed's tick-spinning simulator, kept as the bit-identity oracle
    for the event-horizon rewrite (well-formed workloads: every request
    schedulable, so the seed's assignment==-1 commit bug is unreachable)."""
    from repro.sched_integration.serve_scheduler import ServeResult, service_time_s

    P = len(replicas)
    exec_cache = {}

    def ex_row(req):
        if req.rid not in exec_cache:
            exec_cache[req.rid] = np.array([
                service_time_s(req, r, active_params=active_params)
                for r in replicas])
        return exec_cache[req.rid]

    pending = sorted(requests, key=lambda r: r.arrival)
    idx, ready = 0, []
    free_at = np.zeros(P)
    busy = np.zeros(P)
    finish_times = {}
    t = 0.0
    end = max(r.arrival for r in requests) + 1.0
    while idx < len(pending) or ready:
        t += sched_tick_s
        while idx < len(pending) and pending[idx].arrival <= t:
            ready.append(pending[idx])
            idx += 1
        if not ready:
            continue
        ex = np.stack([ex_row(r) for r in ready])
        assignment = policy(ex, np.maximum(free_at, t))
        for r, p in zip(ready, assignment):
            start = max(free_at[p], r.arrival, t)
            dur = ex_row(r)[p]
            free_at[p] = start + dur
            busy[p] += dur
            finish_times[r.rid] = free_at[p]
        ready.clear()
        if t > end + 3600:
            break
    lat = np.array([finish_times[r.rid] - r.arrival for r in requests
                    if r.rid in finish_times])
    span = max(finish_times.values()) - min(r.arrival for r in requests)
    offered = len(requests) / (max(r.arrival for r in requests) + 1e-9)
    return ServeResult(
        offered_rps=offered,
        achieved_rps=len(finish_times) / span,
        p50_latency=float(np.percentile(lat, 50)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        replica_util=busy / span,
    )


@pytest.mark.parametrize("policy_name", ["heft_rt", "round_robin",
                                         "least_loaded", "random"])
def test_event_horizon_rewrite_bit_identical_to_seed(policy_name):
    fleet = default_fleet()
    reqs = make_requests(rate_rps=300, duration_s=1.5, seed=5)
    got = simulate_serving(fleet, reqs, POLICIES[policy_name](),
                           active_params=7e9)
    want = _reference_simulate(fleet, reqs, POLICIES[policy_name](),
                               active_params=7e9)
    assert got.mean_latency == want.mean_latency
    assert got.p50_latency == want.p50_latency
    assert got.p99_latency == want.p99_latency
    assert got.achieved_rps == want.achieved_rps
    np.testing.assert_array_equal(got.replica_util, want.replica_util)


def test_heft_uses_heterogeneity():
    """HEFT routes more work to the fastest replica than round-robin does."""
    fleet = default_fleet()
    reqs = make_requests(600, 3.0, seed=2)
    h = simulate_serving(fleet, reqs, POLICIES["heft_rt"](), active_params=7e9)
    r = simulate_serving(fleet, reqs, POLICIES["round_robin"](),
                         active_params=7e9)
    # utilization imbalance should track replica speed under HEFT
    assert h.replica_util[0] > h.replica_util[3] * 0.8
    assert h.mean_latency <= r.mean_latency
