"""Property test: vmapped HEFT_RT agrees slot-for-slot with the numpy twin.

`heft_rt_batched` is the serving scheduler's scoring path (many independent
ready queues per fabric step); `heft_rt_numpy` is the discrete-event
simulator's hot path.  They must make *identical* mapping decisions —
including under duplicate `Avg_TID` keys (stable-sort tie semantics of the
shift-register priority queue) and all-`inf` rows (unsupported tasks map to
PE -1 and must not corrupt the availability registers).

Execution times are drawn as small integers so every finish time is exactly
representable in f32 and comparisons are bitwise, mirroring the paper's
Fig. 3 functional-verification requirement.
"""

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import heft_rt_numpy
from repro.core.heft_rt import heft_rt_batched

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _random_queues(rng, q, n, p, dup_range, inf_frac):
    # duplicate-heavy priorities: small integer range forces ties
    avg = rng.integers(0, dup_range, (q, n)).astype(np.float32)
    ex = rng.integers(1, 16, (q, n, p)).astype(np.float32)
    # all-inf rows: task unsupported on every PE → unschedulable (-1)
    kill = rng.random((q, n)) < inf_frac
    ex[kill] = np.inf
    avail = rng.integers(0, 8, (q, p)).astype(np.float32)
    return avg, ex, avail


@given(
    q=st.integers(1, 6),
    n=st.integers(1, 40),
    p=st.integers(1, 8),
    dup_range=st.integers(1, 6),
    inf_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_batched_vmap_matches_numpy_per_queue(q, n, p, dup_range, inf_frac,
                                              seed):
    rng = np.random.default_rng(seed)
    avg, ex, avail = _random_queues(rng, q, n, p, dup_range, inf_frac)

    res = heft_rt_batched(avg, ex, avail)     # jax.vmap over the queue dim

    for i in range(q):
        order, assignment, start, finish, new_avail = heft_rt_numpy(
            avg[i], ex[i], avail[i])
        np.testing.assert_array_equal(np.asarray(res.order[i]), order,
                                      err_msg="stable tie order diverged")
        np.testing.assert_array_equal(np.asarray(res.assignment[i]),
                                      assignment)
        np.testing.assert_array_equal(np.asarray(res.start_time[i]), start)
        np.testing.assert_array_equal(np.asarray(res.finish_time[i]), finish)
        np.testing.assert_array_equal(np.asarray(res.new_avail[i]), new_avail)


def test_all_inf_queue_leaves_avail_untouched():
    """Every task unsupported everywhere: nothing schedules, registers hold."""
    q, n, p = 2, 7, 3
    avg = np.tile(np.float32([3, 3, 1, 1, 5, 0, 2]), (q, 1))  # heavy ties
    ex = np.full((q, n, p), np.inf, np.float32)
    avail = np.arange(q * p, dtype=np.float32).reshape(q, p)
    res = heft_rt_batched(avg, ex, avail)
    assert (np.asarray(res.assignment) == -1).all()
    assert np.isinf(np.asarray(res.finish_time)).all()
    np.testing.assert_array_equal(np.asarray(res.new_avail), avail)
    for i in range(q):
        order, assignment, *_ = heft_rt_numpy(avg[i], ex[i], avail[i])
        np.testing.assert_array_equal(np.asarray(res.order[i]), order)
        np.testing.assert_array_equal(np.asarray(res.assignment[i]),
                                      assignment)
