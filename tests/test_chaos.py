"""Chaos tier: topology-aware failure injection + provable recovery.

Covers the tentpole claims:

* an empty/None failure timeline leaves ``simulate_serving`` bit-identical
  to the failure-free simulator (property-tested across seeds × policies),
* any legal interleaving of Resize+Failure events (split/merge/grow beside
  loss/straggler windows, with a cooldown-limited controller in the loop)
  replays to the same final ``T_avail`` and served set,
* ``replica_loss`` re-queues every unfinished request through the mapping
  policy — never dropped, exempt from the retry budget, and losses striking
  *after* the last dispatch still drain against in-flight work,
* straggler windows stretch-and-restore bit-exact (analytic mirror), and
  the controller's backlog-median detector remaps flagged replicas under
  exponential backoff bounded by the per-request retry budget,
* the :class:`Topology` contention/degrade/partition model: concurrent
  flows serialize on shared links, partitions delay (never drop) transfers
  and mask unreachable replicas' columns for the window,
* the fabric PE mask dispatches exactly like the oracle on a masked matrix,
* failure/recovery/requeue events land on the Tracer/MetricsRegistry rails
  without perturbing the simulation,
* real-engine recovery: a ``ServeEngine`` subprocess is SIGKILLed
  mid-generation and a spare slice restores its snapshot via
  ``restore_caches`` (``reshard_tree``), token-identical from the last
  committed step.
"""

import signal

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _subproc import run_sub as _run_sub

from repro.sched_integration import (
    FAILURE_KINDS,
    FailureEvent,
    FleetController,
    FleetControllerConfig,
    MappingFabric,
    POLICIES,
    Replica,
    Request,
    ResizeEvent,
    ServeResult,
    Topology,
    default_fleet,
    fully_connected,
    goodput,
    grown_replica_factory,
    load_failure_timeline,
    make_requests,
    make_spike_requests,
    merge_event,
    mesh_fleet,
    migration_bytes,
    parse_link_target,
    simulate_serving,
    spine_topology,
    split_event,
    validate_failure_timeline,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _slow_fleet(n=2):
    """Replicas with multi-second service times (roofline at 7e9 params), so
    failure windows overlap in-flight work without huge request counts."""
    return [Replica(f"r{i}", 50.0, 500.0) for i in range(n)]


# ---------------------------------------------------------------------------
# empty timeline == failure-free simulator (bit-identity, property)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1),
       policy=st.sampled_from(sorted(POLICIES)))
def test_empty_failure_timeline_bit_identical(seed, policy):
    """failure_events=[] (plus an inert topology and a retry budget) leaves
    every code path untouched: all result fields match the plain simulator
    bit-for-bit, for every dispatch policy."""
    reqs = make_requests(rate_rps=300, duration_s=1.0, seed=seed)
    topo = fully_connected(["gw", "pod0"], 100.0, gateway="gw")
    a = simulate_serving(default_fleet(), reqs, POLICIES[policy](),
                         active_params=7e9)
    b = simulate_serving(default_fleet(), reqs, POLICIES[policy](),
                         active_params=7e9, failure_events=[],
                         topology=topo, retry_budget=1)
    assert a.mean_latency == b.mean_latency
    assert a.p50_latency == b.p50_latency
    assert a.p99_latency == b.p99_latency
    assert a.achieved_rps == b.achieved_rps
    np.testing.assert_array_equal(a.replica_util, b.replica_util)
    np.testing.assert_array_equal(a.served_mask, b.served_mask)
    np.testing.assert_array_equal(a.finish_times, b.finish_times)
    np.testing.assert_array_equal(a.final_avail, b.final_avail)
    assert b.requeued.sum() == 0


# ---------------------------------------------------------------------------
# Resize + Failure interleavings replay to the same state (property)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1))
def test_resize_failure_interleaving_replay_reaches_same_state(seed):
    """A split/merge/grow resize timeline interleaved with loss + straggler
    failures, with a cooldown-limited controller in the loop: a host-mirror
    replay (fresh controller/policy, timelines handed over in shuffled
    order) reaches the same final T_avail, served set, re-queue counts, and
    utilization — the unified event queue canonicalizes (t, kind) order, so
    the outcome is a function of the timeline, not of how it was fed in.
    Losses are re-queued, never dropped: everything ends served."""
    rng = np.random.default_rng(seed)
    # Distinct f32-grid event times in (0, 5): same-t collisions *within* a
    # timeline would make input order semantically significant.
    times = np.sort(rng.choice(np.arange(1, 40), size=5, replace=False)) / 8.0
    strag_dur = float(rng.integers(1, 8)) / 4.0
    strag_fac = float(rng.integers(2, 5))
    reqs = make_spike_requests(2.0, 25.0, spike_start=0.5, spike_end=1.5,
                               duration_s=5.0, seed=int(seed % 997))

    def run(shuffle):
        base = mesh_fleet("a", ((4, 4), (4, 4), (2, 2)))
        se = split_event(float(times[0]), base[1], [(2, 4), (2, 4)])
        grow = ResizeEvent(float(times[1]),
                           add=(mesh_fleet("a", ((2, 4),))[0],))
        me = merge_event(float(times[3]), se.add, (4, 4))
        resizes = [se, grow, me]
        fails = [
            FailureEvent(float(times[2]), "replica_loss", base[0].name),
            FailureEvent(float(times[4]), "straggler", base[2].name,
                         duration_s=strag_dur, factor=strag_fac),
        ]
        if shuffle:
            srng = np.random.default_rng(seed + 1)
            resizes = [resizes[i] for i in srng.permutation(len(resizes))]
            fails = [fails[i] for i in srng.permutation(len(fails))]
        ctl = FleetController(
            FleetControllerConfig(grow_backlog_s=2.0, cooldown_s=0.5,
                                  max_grown=1, straggler_factor=4.0),
            grown_replica_factory("a", (2, 2)))
        return simulate_serving(base, reqs, POLICIES["heft_rt"](),
                                active_params=7e9, fleet_events=resizes,
                                failure_events=fails, controller=ctl)

    a, b = run(False), run(True)
    np.testing.assert_array_equal(a.final_avail, b.final_avail)
    np.testing.assert_array_equal(a.served_mask, b.served_mask)
    np.testing.assert_array_equal(a.requeued, b.requeued)
    np.testing.assert_array_equal(a.finish_times, b.finish_times)
    np.testing.assert_array_equal(a.replica_util, b.replica_util)
    assert a.served_mask.all()


# ---------------------------------------------------------------------------
# replica_loss: re-queued through the policy, never dropped
# ---------------------------------------------------------------------------

def test_replica_loss_requeues_unfinished_work():
    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=3)
    loss = [FailureEvent(0.3, "replica_loss", "r1", reason="pod down")]
    r = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9, failure_events=loss)
    assert r.served_mask.all()
    assert r.requeued.sum() > 0
    assert r.replica_util.shape == (1,)      # final roster: the survivor
    # Nothing served attributes to the dead replica past the loss instant:
    # the in-sim invariant already raises on that, so reaching here with all
    # requests served *is* the recovery proof.


def test_loss_after_last_dispatch_drains_in_flight_work():
    """A loss striking after the final mapping event (backlog still in
    flight) re-queues through the drain branch and dispatch resumes."""
    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=3)
    clean = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                             active_params=7e9)
    assert np.nanmax(clean.finish_times) > 2.0   # work is in flight at t=2
    loss = [FailureEvent(2.0, "replica_loss", "r1")]
    r = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9, failure_events=loss)
    assert r.served_mask.all() and r.requeued.sum() > 0


def test_loss_requeues_are_exempt_from_retry_budget():
    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=3)
    loss = [FailureEvent(0.3, "replica_loss", "r1")]
    r = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9, failure_events=loss,
                         retry_budget=0)
    assert r.served_mask.all() and r.requeued.sum() > 0


def test_loss_emptying_the_fleet_raises():
    reqs = make_requests(rate_rps=20, duration_s=0.3, seed=1)
    with pytest.raises(ValueError, match="left the fleet empty"):
        simulate_serving(_slow_fleet(1), reqs, POLICIES["heft_rt"](),
                         active_params=7e9,
                         failure_events=[FailureEvent(0.2, "replica_loss",
                                                      "r0")])


def test_exec_matrix_allowed_with_loss_rejected_with_windowed_kinds():
    """A pinned exec matrix composes with pure replica_loss timelines (only
    columns are deleted) but not with kinds that must *restore* columns."""
    fleet = _slow_fleet()
    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=1)
    ex = np.full((len(reqs), 2), 0.25)
    r = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, exec_matrix=ex,
                         failure_events=[FailureEvent(0.3, "replica_loss",
                                                      "r1")])
    assert r.served_mask.all()
    with pytest.raises(ValueError, match="pinned exec_matrix"):
        simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                         active_params=7e9, exec_matrix=ex,
                         failure_events=[FailureEvent(
                             0.3, "straggler", "r1", duration_s=0.5,
                             factor=2.0)])


def test_link_kinds_require_topology():
    reqs = make_requests(rate_rps=20, duration_s=0.3, seed=1)
    with pytest.raises(ValueError, match="need a topology"):
        simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9,
                         failure_events=[FailureEvent(
                             0.1, "link_partition", "pod0:spine",
                             duration_s=0.5)])


# ---------------------------------------------------------------------------
# straggler windows: stretch + bit-exact restore
# ---------------------------------------------------------------------------

def test_straggler_stretch_matches_analytic_mirror():
    """Single replica, single in-flight request: the stretched finish is
    exactly ``pivot + k*(f - pivot)``, and a window closing before that
    un-stretches the tail to ``tr + (f' - tr)/k`` — float-for-float."""
    fleet = [Replica("solo", 50.0, 500.0)]
    reqs = [Request(0, 0.0, 1000, 100)]
    f0 = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                          active_params=7e9).finish_times[0]
    k, ts = 3.0, 0.5
    assert ts < f0

    # Window outlives the stretched finish: pure stretch.
    long_w = FailureEvent(ts, "straggler", "solo", duration_s=1e3, factor=k)
    r1 = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                          active_params=7e9, failure_events=[long_w])
    f1 = ts + k * (f0 - ts)
    assert r1.finish_times[0] == f1

    # Window closes mid-request: the tail past the recovery un-stretches.
    dur = 0.5 * (f1 - ts)                     # recovery lands inside [ts, f1]
    tr = ts + dur
    short_w = FailureEvent(ts, "straggler", "solo", duration_s=dur, factor=k)
    r2 = simulate_serving(fleet, reqs, POLICIES["heft_rt"](),
                          active_params=7e9, failure_events=[short_w])
    assert r2.finish_times[0] == tr + (f1 - tr) / k


def test_straggler_window_with_no_overlapping_work_leaves_no_trace():
    """A window that opens after all work has finished stretches nothing and
    restores the exec column bit-exact: the run equals the failure-free one
    in every field."""
    reqs = make_requests(rate_rps=100, duration_s=0.5, seed=2)
    a = simulate_serving(default_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9)
    assert np.nanmax(a.finish_times) < 50.0
    w = FailureEvent(50.0, "straggler", "v4-128", duration_s=1.0, factor=8.0)
    b = simulate_serving(default_fleet(), reqs, POLICIES["heft_rt"](),
                         active_params=7e9, failure_events=[w])
    np.testing.assert_array_equal(a.finish_times, b.finish_times)
    np.testing.assert_array_equal(a.replica_util, b.replica_util)
    np.testing.assert_array_equal(a.final_avail, b.final_avail)
    assert a.p99_latency == b.p99_latency


def test_controller_straggler_detection_backoff_and_reset():
    ctl = FleetController(
        FleetControllerConfig(straggler_factor=2.0,
                              straggler_min_backlog_s=0.1,
                              straggler_cooldown_s=1.0),
        grown_replica_factory("a", (2, 2)))
    names = ["a", "b", "c"]
    hot = [0.1, 0.1, 5.0]
    assert ctl.observe_stragglers(0.0, names, hot) == ["c"]
    assert ctl.observe_stragglers(0.5, names, hot) == []     # backing off
    assert ctl.observe_stragglers(1.0, names, hot) == ["c"]  # backoff now 2s
    assert ctl.observe_stragglers(2.0, names, hot) == []
    # Observed healthy: backoff history forgiven, flags fire fresh again.
    assert ctl.observe_stragglers(2.5, names, [0.1, 0.1, 0.1]) == []
    assert ctl.observe_stragglers(2.6, names, hot) == ["c"]
    assert [k for _, k, _ in ctl.trace] == ["remap"] * 3
    # Disabled detector / single replica: never flags.
    assert FleetController(FleetControllerConfig(),
                           grown_replica_factory("a", (2, 2))
                           ).observe_stragglers(0.0, names, hot) == []
    assert ctl.observe_stragglers(9.9, ["a"], [99.0]) == []


def test_straggler_remap_requeues_within_retry_budget():
    """A hard straggler window under load: the controller flags it off the
    backlog-median signal and its queued work re-queues onto the healthy
    fleet — each request at most retry_budget times.  Small requests keep
    the backlog rail smooth, so the median comparison sees the ×16 window
    and not single-request lumpiness."""
    reqs = make_requests(rate_rps=200, duration_s=1.0, seed=5,
                         prefill_range=(128, 512), decode_range=(8, 32))
    w = FailureEvent(0.5, "straggler", "r3", duration_s=60.0, factor=16.0)
    ctl = FleetController(
        FleetControllerConfig(grow_backlog_s=float("inf"),
                              straggler_factor=2.0,
                              straggler_min_backlog_s=0.5,
                              straggler_cooldown_s=0.25),
        grown_replica_factory("a", (2, 2)))
    r = simulate_serving(_slow_fleet(4), reqs, POLICIES["heft_rt"](),
                         active_params=7e9, failure_events=[w],
                         controller=ctl, retry_budget=2)
    assert "remap" in [k for _, k, _ in ctl.trace]
    assert r.requeued.sum() > 0
    assert r.requeued.max() <= 2             # bounded by the retry budget
    assert r.served_mask.all()
    # The remap is load-bearing: without it the straggler's queue rides out
    # the whole ×16 window.
    passive = simulate_serving(_slow_fleet(4), reqs, POLICIES["heft_rt"](),
                               active_params=7e9, failure_events=[w])
    assert r.p99_latency < passive.p99_latency


# ---------------------------------------------------------------------------
# topology: contention, degrade, partition
# ---------------------------------------------------------------------------

def test_link_target_parsing_and_validation():
    assert parse_link_target("b:a") == ("a", "b")
    for bad in ("a", "a:", ":b", "a:b:c"):
        with pytest.raises(ValueError, match="podA:podB"):
            parse_link_target(bad)
    topo = Topology()
    with pytest.raises(ValueError, match="self-link"):
        topo.connect("a", "a", 1.0)
    with pytest.raises(ValueError, match="bandwidth"):
        topo.connect("a", "b", 0.0)
    with pytest.raises(KeyError):
        topo.link("a", "b")


def test_transfer_contention_serializes_shared_links():
    topo = spine_topology(["a", "b", "c"], 10.0, latency_s=0.001)
    # 1 GB over 10 GB/s + 2 hops of latency.
    s1, f1 = topo.transfer_s(1e9, "a", "b", at=0.0)
    assert s1 == 0.0 and f1 == pytest.approx(0.102)
    # A second flow sharing the a:spine link queues behind the first...
    s2, f2 = topo.transfer_s(1e9, "a", "c", at=0.0)
    assert s2 == f1 and f2 == pytest.approx(f1 + 0.102)
    # ...while a disjoint-path flow does not (b:spine freed at f1).
    s3, _ = topo.transfer_s(1e9, "b", "c", at=f2)
    assert s3 == f2
    # reserve=False probes without committing the wire.
    topo2 = spine_topology(["a", "b"], 10.0)
    topo2.transfer_s(1e9, "a", "b", at=0.0, reserve=False)
    assert topo2.transfer_s(1e9, "a", "b", at=0.0)[0] == 0.0


def test_degrade_and_background_util_scale_bandwidth():
    topo = fully_connected(["a", "b"], 10.0)
    assert topo.transfer_s(1e9, "a", "b", reserve=False)[1] == pytest.approx(0.1)
    topo.degrade("a", "b", 0.5)
    assert topo.transfer_s(1e9, "a", "b", reserve=False)[1] == pytest.approx(0.2)
    topo.set_background_util("a", "b", 0.5)    # collectives hold half the wire
    assert topo.transfer_s(1e9, "a", "b", reserve=False)[1] == pytest.approx(0.4)
    topo.restore("a", "b")
    topo.set_background_util("a", "b", 0.0)
    assert topo.transfer_s(1e9, "a", "b", reserve=False)[1] == pytest.approx(0.1)
    with pytest.raises(ValueError, match="degrade factor"):
        topo.degrade("a", "b", 0.0)
    with pytest.raises(ValueError, match="background_util"):
        topo.set_background_util("a", "b", 1.0)


def test_partition_delays_transfers_and_masks_reachability():
    topo = spine_topology(["gw", "pod0"], 10.0, pod_of={"r0": "pod0"},
                          gateway="gw")
    topo.set_down("gw", "spine", 2.0)
    assert not topo.replica_reachable("r0", at=1.0)
    assert topo.replica_reachable("r0", at=2.0)
    assert topo.replica_reachable("unmapped", at=1.0)   # masking is opt-in
    # A transfer into the window waits it out — delayed, never dropped.
    s, f = topo.transfer_s(1e9, "gw", "pod0", at=1.0)
    assert s == 2.0 and f == pytest.approx(2.1)
    # set_down extends, never shrinks, an open window.
    topo.set_down("gw", "spine", 1.0)
    assert topo.link("gw", "spine").down_until == 2.0


def test_collective_contends_with_migration_on_shared_links():
    topo = spine_topology(["a", "b", "c"], 10.0)
    _, fm = topo.transfer_s(1e9, "a", "b", at=0.0)     # migration holds a:spine
    s, f = topo.collective_s(1e9, ["a", "b", "c"], at=0.0)
    per_hop = 2.0 * 1e9 * 2 / 3
    assert s >= 0.0 and f >= fm + per_hop / 10e9       # a-hop queued behind it
    assert topo.collective_s(1e9, ["a"], at=3.0) == (3.0, 3.0)


def test_topology_joiner_pays_migration_horizon():
    """A ResizeEvent joiner behind a topology gateway opens its queue
    horizon at its params migration's finish, not instantly."""
    topo = spine_topology(["gw", "podj"], 10.0, pod_of={"joiner": "podj"},
                          gateway="gw")
    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=2)
    joiner = Replica("joiner", 50.0, 500.0)
    r = simulate_serving(_slow_fleet(1), reqs, POLICIES["heft_rt"](),
                         active_params=7e9,
                         fleet_events=[ResizeEvent(0.2, add=(joiner,))],
                         topology=topo)
    assert r.served_mask.all()
    # gw → spine → podj at 10 GB/s: a 2-byte/param bf16 copy of 7e9 params.
    assert r.final_avail[-1] >= 0.2 + migration_bytes(7e9) / 10e9


def test_partition_diverts_new_admissions_and_recovers():
    pod_of = {"r0": "pod0", "r1": "pod1"}
    reqs = make_requests(rate_rps=20, duration_s=1.0, seed=4)

    def run(duration_s):
        topo = spine_topology(["gw", "pod0", "pod1"], 100.0, pod_of=pod_of,
                              gateway="gw")
        ev = [FailureEvent(0.0, "link_partition", "pod1:spine",
                           duration_s=duration_s)]
        return simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                                active_params=7e9, failure_events=ev,
                                topology=topo)

    whole_run = run(1e3)
    assert whole_run.served_mask.all()       # survivors absorb everything
    assert whole_run.replica_util[1] == 0.0  # r1 never admitted new work
    windowed = run(0.3)
    assert windowed.served_mask.all()
    assert windowed.replica_util[1] > 0.0    # window closed: r1 back in


# ---------------------------------------------------------------------------
# FailureEvent / timeline schema validation
# ---------------------------------------------------------------------------

def test_failure_event_knob_validation():
    with pytest.raises(ValueError, match="failure kind"):
        FailureEvent(0.0, "meteor", "r0")
    with pytest.raises(ValueError, match="no target"):
        FailureEvent(0.0, "replica_loss", "")
    with pytest.raises(ValueError, match="duration_s"):
        FailureEvent(0.0, "straggler", "r0", factor=2.0)
    with pytest.raises(ValueError, match="factor must be > 1"):
        FailureEvent(0.0, "straggler", "r0", duration_s=1.0, factor=0.5)
    with pytest.raises(ValueError, match=r"in \(0, 1\)"):
        FailureEvent(0.0, "link_degrade", "a:b", duration_s=1.0, factor=1.5)
    assert set(FAILURE_KINDS) == {"replica_loss", "straggler",
                                  "link_degrade", "link_partition"}


def test_failure_timeline_schema_validation(tmp_path):
    good = {"events": [
        {"t": 0.5, "kind": "replica_loss", "target": "r0", "reason": "x"},
        {"t": 1.0, "kind": "straggler", "target": "r1",
         "duration_s": 0.5, "factor": 4.0},
    ]}
    evs = validate_failure_timeline(good)
    assert [e.kind for e in evs] == ["replica_loss", "straggler"]
    with pytest.raises(ValueError, match="root must be an object"):
        validate_failure_timeline([])
    with pytest.raises(ValueError, match="'events' list"):
        validate_failure_timeline({})
    with pytest.raises(ValueError, match="unknown keys"):
        validate_failure_timeline(
            {"events": [{"t": 0.0, "kind": "replica_loss", "target": "r0",
                         "severity": 9}]})
    with pytest.raises(ValueError, match="missing required 'kind'"):
        validate_failure_timeline({"events": [{"t": 0.0, "target": "r0"}]})
    with pytest.raises(ValueError, match=r"events\[0\].t must be"):
        validate_failure_timeline(
            {"events": [{"t": "soon", "kind": "replica_loss",
                         "target": "r0"}]})
    p = tmp_path / "chaos.json"
    p.write_text('{"events": [{"t": 0.25, "kind": "replica_loss", '
                 '"target": "r0"}]}')
    assert load_failure_timeline(str(p))[0].t == 0.25


def test_launcher_resolves_unique_prefix_targets():
    from repro.launch.serve import _resolve_targets

    names = ["replica0(x1.0)", "replica1(x0.7)"]
    tl = [FailureEvent(0.1, "replica_loss", "replica1"),
          FailureEvent(0.2, "link_degrade", "pod0:spine", duration_s=1.0,
                       factor=0.5)]
    out = _resolve_targets(tl, names)
    assert out[0].target == "replica1(x0.7)"
    assert out[1].target == "pod0:spine"          # link targets pass through
    with pytest.raises(SystemExit, match="matches"):
        _resolve_targets([FailureEvent(0.1, "replica_loss", "replica")],
                         names)
    with pytest.raises(SystemExit, match="no replicas"):
        _resolve_targets([FailureEvent(0.1, "replica_loss", "ghost")], names)


def test_goodput_counts_only_in_slo_serves():
    reqs = [Request(0, 0.0, 100, 10), Request(1, 0.0, 100, 10),
            Request(2, 0.5, 100, 10)]
    res = ServeResult(3.0, 2.0, 0.5, 3.0, 1.75, np.zeros(1),
                      served_mask=np.array([True, True, False]),
                      finish_times=np.array([0.5, 3.0, np.nan]))
    assert goodput(res, reqs, slo_s=1.0) == 1
    assert goodput(res, reqs, slo_s=10.0) == 2


# ---------------------------------------------------------------------------
# fabric PE mask + front-end partition mask
# ---------------------------------------------------------------------------

def test_fabric_pe_mask_matches_oracle_on_masked_matrix():
    rng = np.random.default_rng(7)
    avg = rng.integers(0, 5, 10).astype(np.float32)
    ex = rng.integers(1, 16, (10, 4)).astype(np.float32)
    masked_ex = ex.copy()
    masked_ex[:, 1] = np.inf
    fab = MappingFabric(4, backend="numpy")
    ref = MappingFabric(4, backend="numpy")
    fab.set_pe_mask([False, True, False, False])
    got = fab.map_event(avg, ex)
    want = ref.map_event(avg, masked_ex)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    np.testing.assert_array_equal(fab.avail, ref.avail)
    assert fab.avail[1] == 0.0                 # masked lane took no work


def test_fabric_pe_mask_validation_and_resize_clearing():
    fab = MappingFabric(3, backend="numpy")
    with pytest.raises(ValueError, match="pe mask"):
        fab.set_pe_mask([True, False])
    fab.set_pe_mask([True, False, False])
    fab.grow(4)                                # lane indices change meaning
    assert fab._pe_mask is None
    fab.set_pe_mask([True, False, False, False])
    fab.set_pe_mask(None)
    assert fab._pe_mask is None


def test_front_end_set_unreachable_masks_and_clears():
    from repro.serve.engine import HeftFrontEnd, ReplicaHandle

    class _Eng:
        mesh_shape = None

    front = HeftFrontEnd([ReplicaHandle("a", _Eng()),
                          ReplicaHandle("b", _Eng(), speed=2.0)],
                         fabric=MappingFabric(2, backend="numpy"))
    reqs = [(np.zeros(10, np.int32), 4), (np.zeros(6, np.int32), 2)]
    front.set_unreachable(["a", "ghost"])      # unknown names are ignored
    assert np.isinf(front.exec_estimates(reqs)[:, 0]).all()
    assert all(p == 1 for _, p in front.schedule(reqs))
    front.set_unreachable([])
    assert front.fabric._pe_mask is None
    assert np.isfinite(front.exec_estimates(reqs)).all()
    # Removing a masked replica drops it from the mask with the roster.
    front.set_unreachable(["b"])
    front.remove_replica("b")
    assert front.unreachable == set() and front.fabric._pe_mask is None


# ---------------------------------------------------------------------------
# observability rails
# ---------------------------------------------------------------------------

def test_chaos_events_land_on_tracer_and_metrics_without_perturbing():
    from repro.obs import MetricsRegistry, Tracer

    reqs = make_requests(rate_rps=20, duration_s=0.5, seed=3)
    fails = [FailureEvent(0.3, "replica_loss", "r1", reason="chaos"),
             FailureEvent(0.5, "straggler", "r0", duration_s=0.5,
                          factor=2.0)]
    tracer, metrics = Tracer(), MetricsRegistry()
    obs = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                           active_params=7e9, failure_events=fails,
                           tracer=tracer, metrics=metrics)
    plain = simulate_serving(_slow_fleet(), reqs, POLICIES["heft_rt"](),
                             active_params=7e9, failure_events=fails)
    np.testing.assert_array_equal(obs.finish_times, plain.finish_times)
    np.testing.assert_array_equal(obs.final_avail, plain.final_avail)
    names = {e.name for e in tracer.events()}
    assert {"serve.failure", "serve.recovery", "serve.requeue",
            "serve.queue_depth"} <= names
    assert metrics.counter("serve.failures", kind="replica_loss").value == 1
    assert metrics.counter("serve.failures", kind="straggler").value == 1
    assert (metrics.counter("serve.retries", cause="replica_loss").value
            == plain.requeued.sum())
    assert metrics.counter("serve.served").value == plain.served_mask.sum()


# ---------------------------------------------------------------------------
# real-engine recovery: SIGKILL mid-generation, restore on a spare slice
# ---------------------------------------------------------------------------

def test_engine_kill_and_recover_token_identical(tmp_path):
    """The tentpole's recovery demo: a mesh-backed ServeEngine is SIGKILLed
    mid-generation after snapshotting its in-flight KV at a committed decode
    step; a second process restores params + snapshot onto a *different*
    slice via ``restore_caches`` (``reshard_tree``) and finishes the
    generation token-identical to an uninterrupted run."""
    snap = str(tmp_path / "snap.pkl")
    _run_sub(f"""
        import os, pickle, signal
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import init_params
        from repro.serve import ServeEngine

        cfg = get_smoke_config('deepseek-7b')
        params = init_params(jax.random.key(0), cfg)
        pool = jax.devices()
        eng = ServeEngine(cfg, params, max_len=64,
                          mesh=make_debug_mesh((2, 1), devices=pool[:2]))
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        logits, caches = eng.start(prompt[None, :])
        toks = []
        for i in range(4):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(np.asarray(tok))
            logits, caches = eng.step(caches, tok[:, None], 12 + i)
        with open({snap!r}, 'wb') as f:
            pickle.dump(dict(toks=toks, logits=np.asarray(logits),
                             snap=eng.snapshot_caches(caches)), f)
            f.flush(); os.fsync(f.fileno())
        os.kill(os.getpid(), signal.SIGKILL)   # die mid-generation
    """, expect_returncode=-signal.SIGKILL)
    out = _run_sub(f"""
        import pickle
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import init_params
        from repro.serve import ServeEngine

        cfg = get_smoke_config('deepseek-7b')
        params = init_params(jax.random.key(0), cfg)   # same init seed
        pool = jax.devices()
        # The spare slice: different devices AND a different shape — the
        # snapshot reshards onto the new cache layout.
        eng = ServeEngine(cfg, params, max_len=64,
                          mesh=make_debug_mesh((2, 2), devices=pool[4:8]))
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        want = eng.generate(prompt[None, :], 8)        # uninterrupted run
        with open({snap!r}, 'rb') as f:
            saved = pickle.load(f)
        caches = eng.restore_caches(saved['snap'])
        logits, toks = jnp.asarray(saved['logits']), list(saved['toks'])
        for i in range(4, 8):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(np.asarray(tok))
            logits, caches = eng.step(caches, tok[:, None], 12 + i)
        got = np.concatenate([t[:, None] for t in toks], axis=1)
        assert np.array_equal(got, want[:, 12:]), (got, want[:, 12:])
        print('OK')
    """)
    assert "OK" in out
