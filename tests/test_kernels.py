"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles.

Per-kernel shape/dtype sweeps (hypothesis) asserting exact agreement with
ref.py — sorting is integer/exact-comparison work, so equality is bitwise,
which is precisely the paper's Fig. 3 functional-verification requirement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import heft_rt, heft_rt_numpy
from repro.kernels import eft_select, heft_rt_hw, oddeven_sort
from repro.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# odd–even transposition sort (priority queue)
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 300),
    dup_range=st.integers(2, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_sort_matches_oracle_f32(n, dup_range, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, dup_range, n).astype(np.float32)  # heavy ties
    payload = np.arange(n, dtype=np.int32)
    ks, ps = oddeven_sort(jnp.array(keys), jnp.array(payload))
    rk, rp = ref.oddeven_sort_ref(jnp.array(keys), jnp.array(payload))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rk))
    # stability: payload order must match the stable oracle exactly
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(rp))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_sort_dtypes(dtype):
    rng = np.random.default_rng(0)
    if jnp.issubdtype(dtype, jnp.integer):
        keys = jnp.array(rng.integers(-1000, 1000, 257), dtype=dtype)
    else:
        keys = jnp.array(rng.normal(0, 100, 257), dtype=dtype)
    payload = jnp.arange(257, dtype=jnp.int32)
    ks, ps = oddeven_sort(keys, payload)
    rk, rp = ref.oddeven_sort_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(rp))


def test_sort_sim_spec_matches_oracle():
    """The brick-wall executable spec == stable argsort (for a power-of-two)."""
    rng = np.random.default_rng(3)
    keys = jnp.array(rng.integers(0, 9, 128).astype(np.float32))
    payload = jnp.arange(128, dtype=jnp.int32)
    sk, sp = ref.oddeven_sort_sim(keys, payload)
    rk, rp = ref.oddeven_sort_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(rp))


# ---------------------------------------------------------------------------
# EFT selector (PE handlers + min tree)
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 128),
    p=st.integers(1, 40),
    inf_frac=st.floats(0.0, 0.4),
    seed=st.integers(0, 2**31 - 1),
)
def test_eft_select_matches_oracle(n, p, inf_frac, seed):
    rng = np.random.default_rng(seed)
    ex = rng.uniform(1, 100, (n, p)).astype(np.float32)
    ex[rng.random((n, p)) < inf_frac] = np.inf
    avail = rng.uniform(0, 50, p).astype(np.float32)
    k = eft_select(jnp.array(ex), jnp.array(avail))
    r = ref.eft_select_ref(jnp.array(ex), jnp.array(avail))
    np.testing.assert_array_equal(np.asarray(k[0]), np.asarray(r[0]))
    np.testing.assert_allclose(np.asarray(k[1]), np.asarray(r[1]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(k[2]), np.asarray(r[2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(k[3]), np.asarray(r[3]), rtol=1e-6)


def test_eft_tie_breaks_to_lowest_pe():
    """Comparator-tree semantics: equal finish times pick the lowest index."""
    ex = jnp.array([[5.0, 5.0, 5.0]])
    avail = jnp.zeros(3)
    pes, _, _, _ = eft_select(ex, avail)
    assert int(pes[0]) == 0


# ---------------------------------------------------------------------------
# fused overlay (full mapping event)
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 200),
    p=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_matches_software_scheduler(n, p, seed):
    """HW kernel == software HEFT_RT == numpy twin (paper Fig. 3, exactly)."""
    rng = np.random.default_rng(seed)
    avg = rng.integers(1, 30, n).astype(np.float32)
    ex = rng.uniform(1, 100, (n, p)).astype(np.float32)
    avail = rng.uniform(0, 50, p).astype(np.float32)
    order, pes, starts, fins, new_avail = heft_rt_hw(
        jnp.array(avg), jnp.array(ex), jnp.array(avail))
    sw = heft_rt(jnp.array(avg), jnp.array(ex), jnp.array(avail))
    np.testing.assert_array_equal(np.asarray(order), np.asarray(sw.order))
    np.testing.assert_array_equal(np.asarray(pes), np.asarray(sw.assignment))
    np.testing.assert_allclose(np.asarray(new_avail), np.asarray(sw.new_avail),
                               rtol=1e-6)
    no, na, _, _, nav = heft_rt_numpy(avg, ex, avail)
    np.testing.assert_array_equal(np.asarray(order), no)
    np.testing.assert_array_equal(np.asarray(pes), na)


def test_fused_invariants():
    """Greedy-EFT invariants: starts ≥ avail, per-PE serialization."""
    rng = np.random.default_rng(7)
    n, p = 64, 4
    avg = rng.uniform(1, 20, n).astype(np.float32)
    ex = rng.uniform(1, 10, (n, p)).astype(np.float32)
    avail = rng.uniform(0, 5, p).astype(np.float32)
    order, pes, starts, fins, new_avail = map(
        np.asarray, heft_rt_hw(jnp.array(avg), jnp.array(ex), jnp.array(avail)))
    # every task assigned
    assert (pes >= 0).all() and (pes < p).all()
    # per-PE: tasks execute back-to-back without overlap
    for pe in range(p):
        mask = pes == pe
        s, f = starts[mask], fins[mask]
        idx = np.argsort(s)
        assert (s[idx][1:] >= f[idx][:-1] - 1e-4).all()
        # final availability = last finish on that PE (or untouched)
        if mask.any():
            np.testing.assert_allclose(new_avail[pe], f.max(), rtol=1e-6)
    # makespan is ≥ any single task exec, ≤ serial sum
    makespan = fins.max()
    assert makespan <= ex.min(axis=1).sum() + avail.max() + 1e-3
