"""CEDR-runtime simulator tests: calibrated anchors + paper-trend reproduction."""

import numpy as np
import pytest

from repro.runtime import (
    HW_MODEL,
    SW_MODEL,
    CedrSimulator,
    DISPATCHERS,
    OverheadModel,
    dispatch_earliest_idle,
    get_app,
    hw_compute_s,
    hw_overhead_s,
    paper_soc_pe_types,
    sw_overhead_s,
)
from repro.runtime.workload import (
    frames_per_second,
    high_latency_arrivals,
    low_latency_arrivals,
)


# ---------------------------------------------------------------------------
# overhead models — calibrated to the paper's three published anchors
# ---------------------------------------------------------------------------

def test_crossover_at_queue_size_5():
    """Paper Fig 4 inset: software wins up to n=5, hardware beyond."""
    for n in range(1, 5):
        assert sw_overhead_s(n) <= hw_overhead_s(n)
    for n in range(6, 100):
        assert sw_overhead_s(n) > hw_overhead_s(n), n


def test_183x_compute_speedup_at_1330():
    ratio = sw_overhead_s(1330) / hw_compute_s(1330)
    assert ratio == pytest.approx(183.0, rel=0.02)


def test_2_6x_end_to_end_speedup_at_1330():
    ratio = sw_overhead_s(1330) / hw_overhead_s(1330)
    assert ratio == pytest.approx(2.6, rel=0.05)


def test_sw_growth_is_nlogn_hw_is_linear():
    """Scaling shape claims from the complexity analysis."""
    n1, n2 = 100, 1000
    sw_ratio = sw_overhead_s(n2) / sw_overhead_s(n1)
    assert sw_ratio == pytest.approx(10 * np.log2(n2) / np.log2(n1), rel=0.15)
    hw_c = (hw_compute_s(n2)) / (hw_compute_s(n1))
    assert hw_c == pytest.approx((3 * n2 + 3) / (3 * n1 + 3), rel=1e-6)


# ---------------------------------------------------------------------------
# application DAGs
# ---------------------------------------------------------------------------

def test_apps_structure():
    for name, lo, hi in [("RC", 4, 8), ("TM", 5, 9),
                         ("PD", 100, 140), ("TX", 60, 70)]:
        app = get_app(name)
        assert lo <= app.num_tasks <= hi, name
        ex = app.exec_matrix(paper_soc_pe_types())
        assert np.isfinite(ex[:, :3]).all()           # ARM runs everything
        # accelerator column: finite only for FFT tasks
        fft_rows = [i for i, t in enumerate(app.tasks)
                    if t.task_type.startswith("fft")]
        assert np.isfinite(ex[fft_rows, 3]).all()
        non_fft = [i for i in range(app.num_tasks) if i not in fft_rows]
        assert np.isinf(ex[non_fft, 3]).all()


def test_dag_is_acyclic_and_connected():
    for name in ["RC", "TM", "PD", "TX"]:
        app = get_app(name)
        succ = app.successors()
        # topological order exists (Kahn)
        indeg = {i: len(t.deps) for i, t in enumerate(app.tasks)}
        q = [i for i, d in indeg.items() if d == 0]
        seen = 0
        while q:
            u = q.pop()
            seen += 1
            for v in succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    q.append(v)
        assert seen == app.num_tasks, name


# ---------------------------------------------------------------------------
# simulator — functional verification + performance trends (Figs 3–6)
# ---------------------------------------------------------------------------

def test_fig3_identical_mapping_decisions():
    """HW and SW schedulers must produce identical cumulative exec times."""
    pes = paper_soc_pe_types()
    arr = low_latency_arrivals(100, seed=1)
    r_sw = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(arr)
    r_hw = CedrSimulator(pes, overhead=HW_MODEL, seed=7).run(arr)
    assert r_sw.completed_apps == r_sw.num_apps
    assert r_sw.avg_cumulative_exec_time == pytest.approx(
        r_hw.avg_cumulative_exec_time, rel=1e-9)


def test_low_rate_equivalence_and_completion():
    pes = paper_soc_pe_types()
    arr = high_latency_arrivals(100, seed=2)
    for model in [SW_MODEL, HW_MODEL]:
        r = CedrSimulator(pes, overhead=model, seed=3).run(arr)
        assert r.completed_apps == r.num_apps
        assert r.achieved_frame_rate == pytest.approx(100, rel=0.1)


def test_fig6_hw_sustains_higher_saturated_rate():
    """Oversubscribed regime: HW scheduler achieves ≥15% higher frame rate."""
    pes = paper_soc_pe_types()
    arr = high_latency_arrivals(600, seed=1)
    r_sw = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(arr)
    r_hw = CedrSimulator(pes, overhead=HW_MODEL, seed=7).run(arr)
    assert r_hw.achieved_frame_rate > 1.15 * r_sw.achieved_frame_rate
    # Fig 5 companion: per-app execution time lower with HW
    assert r_hw.avg_app_exec_time < r_sw.avg_app_exec_time


def test_queue_sizes_grow_under_oversubscription():
    pes = paper_soc_pe_types()
    lo = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(
        high_latency_arrivals(100, seed=1))
    hi = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(
        high_latency_arrivals(600, seed=1))
    assert hi.max_queue_size > 2 * lo.max_queue_size


def test_heft_competitive_with_naive_dispatchers():
    """Schedule quality: on the paper's 4-PE SoC (one heterogeneity axis —
    the FFT accelerator) work-conserving baselines are near-optimal; HEFT_RT
    must stay competitive (the paper compares HW vs SW HEFT, not vs naive —
    the clear HEFT win on richly heterogeneous fleets is covered by
    test_sched_integration.py's serving tests)."""
    pes = paper_soc_pe_types()
    arr = high_latency_arrivals(400, seed=5)
    results = {}
    for name, factory in DISPATCHERS.items():
        r = CedrSimulator(pes, dispatch=factory(), seed=11).run(arr)
        assert r.completed_apps == r.num_apps
        results[name] = r.makespan
    best = min(results.values())
    assert results["heft_rt"] <= best * 1.20


def test_frames_per_second_conversion():
    # paper: >250 Mbps ≈ >241 frames/s at 1037 Kb/frame
    assert frames_per_second(250, 1037) == pytest.approx(241.08, rel=1e-3)
