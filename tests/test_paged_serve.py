"""Continuous batching / block-paged KV pool (serve/paging.py).

The tentpole contract, property-tested:

* **Admission-order bit-identity** — N mixed-length requests admitted in
  *random interleavings* (staggered admissions, pool exhaustion, page
  reuse) produce per-request token streams bit-identical to the dense
  single-request oracle ``ServeEngine.generate``.
* **Exhaustion queues, never drops** — a pool too small for the offered
  load refuses admission (``admit() -> None``); every refused request is
  eventually served, and ``freed == allocated`` at drain.
* **Pages as the migration unit** — ``snapshot_pages``/``restore_pages``
  moves one in-flight request between engines token-identically.
* **Simulator twin** — ``Replica.slots=1`` is bit-identical to the
  original single-chain ``simulate_serving``; ``slots>1`` only helps.
* **Sharded paged decode** — a mesh-backed paged engine matches the
  unmeshed oracle (subprocess, fake multi-device).
"""

import dataclasses

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from _subproc import run_sub as _run_sub

from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.sched_integration import (
    POLICIES,
    Replica,
    default_fleet,
    make_requests,
    pow2_bucket,
    simulate_serving,
)
from repro.serve import HeftFrontEnd, ReplicaHandle, ServeEngine

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

CFG = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=4, d_ff=64, vocab_size=64,
                  param_dtype="float32", compute_dtype="float32")

# Module-level lazy singletons instead of fixtures: the hypothesis fallback
# shim (no hypothesis in the image) wraps @given tests with a zero-arg
# signature, so fixtures can't be injected into property tests.
_CACHE: dict = {}


def _params():
    if "params" not in _CACHE:
        _CACHE["params"] = init_params(jax.random.key(0), CFG)
    return _CACHE["params"]


def _oracle():
    if "oracle" not in _CACHE:
        _CACHE["oracle"] = ServeEngine(CFG, _params(), max_len=32)
    return _CACHE["oracle"]


def _requests(n, rng, smax=32, nt_max=8):
    out = []
    for _ in range(n):
        nt = int(rng.integers(1, nt_max))
        s0 = int(rng.integers(2, smax - nt))
        out.append((rng.integers(1, CFG.vocab_size, size=s0).astype(np.int32),
                    nt))
    return out


def _drain(eng, reqs, order):
    """Admit ``reqs`` in ``order`` (FIFO, queue-on-refusal) and run the
    admission/decode/retire loop until every request retires."""
    pending = list(order)
    slot_req = {}
    out = {}
    guard = 0
    while len(out) < len(reqs):
        while pending:
            slot = eng.admit(*reqs[pending[0]])
            if slot is None:
                break
            slot_req[slot] = pending.pop(0)
        eng.decode_tick()
        for slot in eng.finished_slots():
            out[slot_req.pop(slot)] = eng.retire(slot)
        guard += 1
        assert guard < 10_000, "paged drain did not converge"
    return out


# ---------------------------------------------------------------------------
# tentpole: admission-order bit-identity vs the dense oracle
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_random_interleaving_bit_identical_to_dense(seed):
    """Any admission interleaving (driven by a tiny exhaustible pool forcing
    queueing + page reuse) reproduces the dense oracle token-for-token."""
    rng = np.random.default_rng(seed)
    reqs = _requests(5, rng)
    oracle = [_oracle().generate(p[None], nt)[0] for p, nt in reqs]
    eng = ServeEngine(CFG, _params(), max_len=32)
    eng.start_paged(max_batch=int(rng.integers(2, 5)), page_size=8)
    order = rng.permutation(len(reqs)).tolist()
    out = _drain(eng, reqs, order)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(out[i], oracle[i])
    pool = eng.paged.pool
    assert pool.allocated == pool.freed            # freed == allocated
    assert pool.free_pages == pool.num_pages       # fully drained


def test_exhaustion_queues_never_drops():
    """A pool with room for ONE sequence still serves everything (strictly
    serialized), token-identically; admit() refuses instead of dropping."""
    rng = np.random.default_rng(3)
    reqs = _requests(4, rng)
    eng = ServeEngine(CFG, _params(), max_len=32)
    eng.start_paged(max_batch=4, page_size=8, num_pages=4)   # 4 pages = 1 seq
    refused = 0
    pending = list(range(len(reqs)))
    slot_req, out = {}, {}
    while len(out) < len(reqs):
        while pending:
            slot = eng.admit(*reqs[pending[0]])
            if slot is None:
                refused += 1
                break
            slot_req[slot] = pending.pop(0)
        eng.decode_tick()
        for slot in eng.finished_slots():
            out[slot_req.pop(slot)] = eng.retire(slot)
    assert refused > 0                             # exhaustion actually hit
    for i, (p, nt) in enumerate(reqs):
        np.testing.assert_array_equal(out[i],
                                      _oracle().generate(p[None], nt)[0])
    assert eng.paged.pool.allocated == eng.paged.pool.freed


def test_admit_rejects_impossible_and_validates():
    eng = ServeEngine(CFG, _params(), max_len=32)
    eng.start_paged(max_batch=2, page_size=8)
    with pytest.raises(ValueError):                # S0+nt > max_len
        eng.admit(np.ones(30, dtype=np.int32), 8)
    with pytest.raises(ValueError):                # new_tokens < 1
        eng.admit(np.ones(4, dtype=np.int32), 0)
    with pytest.raises(ValueError):                # page_size ∤ max_len
        ServeEngine(CFG, _params(), max_len=32).start_paged(page_size=7)


def test_free_pages_accounting():
    eng = ServeEngine(CFG, _params(), max_len=32)
    eng.start_paged(max_batch=2, page_size=8)      # 8 pages total
    assert eng.free_pages() == 8
    slot = eng.admit(np.arange(1, 10, dtype=np.int32), 4)   # 13 tok → 2 pages
    assert eng.free_pages() == 6
    while not eng.finished_slots():
        eng.decode_tick()
    eng.retire(slot)
    assert eng.free_pages() == 8
    assert eng.paged.pool.allocated == eng.paged.pool.freed == 2


# ---------------------------------------------------------------------------
# pages as the migration / recovery unit
# ---------------------------------------------------------------------------

def test_snapshot_restore_moves_request_between_engines():
    """Kill-and-recover at page granularity: mid-decode snapshot on engine A
    restores on engine B and finishes token-identically."""
    rng = np.random.default_rng(7)
    (p, nt), = _requests(1, rng, nt_max=8)
    nt = max(nt, 4)                                # leave ticks to split
    oracle = _oracle().generate(p[None], nt)[0]
    a = ServeEngine(CFG, _params(), max_len=32)
    a.start_paged(max_batch=2, page_size=8)
    slot = a.admit(p, nt)
    a.decode_tick()                                # a couple of committed steps
    snap = a.snapshot_pages(slot)
    b = ServeEngine(CFG, _params(), max_len=32)
    b.start_paged(max_batch=2, page_size=8)
    slot_b = b.restore_pages(snap)
    assert slot_b is not None
    while not b.finished_slots():
        b.decode_tick()
    np.testing.assert_array_equal(b.retire(slot_b), oracle)


# ---------------------------------------------------------------------------
# front end: run_continuous drains its HEFT_RT-mapped queue
# ---------------------------------------------------------------------------

def test_run_continuous_matches_oracle_and_balances():
    rng = np.random.default_rng(11)
    reqs = _requests(6, rng)
    fleet = [ReplicaHandle(f"replica{i}",
                           ServeEngine(CFG, _params(), max_len=32), speed=s)
             for i, s in enumerate([1.0, 0.7])]
    front = HeftFrontEnd(fleet)
    outs, stats = front.run_continuous(
        reqs, arrival_ticks=[0, 0, 1, 2, 2, 5],
        max_batch=2, page_size=8, num_pages=8)
    for i, (p, nt) in enumerate(reqs):
        np.testing.assert_array_equal(outs[i],
                                      _oracle().generate(p[None], nt)[0])
    assert stats["allocated"] == stats["freed"]
    assert sum(stats["processed"].values()) == len(reqs)


# ---------------------------------------------------------------------------
# simulator twin: Replica.slots
# ---------------------------------------------------------------------------

def test_slots1_bit_identical_and_slots_help():
    load = lambda: make_requests(30.0, 6.0, seed=0)     # noqa: E731
    base = simulate_serving(default_fleet(), load(), POLICIES["heft_rt"](),
                            active_params=7e9)
    again = simulate_serving([dataclasses.replace(r, slots=1)
                              for r in default_fleet()], load(),
                             POLICIES["heft_rt"](), active_params=7e9)
    np.testing.assert_array_equal(base.finish_times, again.finish_times)
    np.testing.assert_array_equal(base.final_avail, again.final_avail)
    assert base.p99_latency == again.p99_latency
    multi = simulate_serving([dataclasses.replace(r, slots=4)
                              for r in default_fleet()], load(),
                             POLICIES["heft_rt"](), active_params=7e9)
    assert multi.p99_latency <= base.p99_latency + 1e-12


def test_multislot_straggler_remap_guard():
    """The controller's straggler remap can't re-attribute chain suffixes;
    it must fail loudly on multi-slot replicas, not corrupt horizons."""
    from repro.sched_integration import FleetController, FleetControllerConfig

    fleet = [dataclasses.replace(r, slots=2) for r in default_fleet()]
    from repro.sched_integration import grown_replica_factory

    ctl = FleetController(
        FleetControllerConfig(straggler_factor=1.01,
                              straggler_min_backlog_s=0.0),
        grown_replica_factory("g", (2, 2)))
    with pytest.raises(ValueError, match="multi-slot"):
        simulate_serving(fleet, make_requests(400.0, 4.0, seed=0),
                         POLICIES["heft_rt"](), active_params=7e9,
                         controller=ctl)


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pow2_bucket(1, min_bucket=8) == 8


# ---------------------------------------------------------------------------
# mesh-backed paged decode (subprocess: fake multi-device)
# ---------------------------------------------------------------------------

def test_sharded_paged_decode_matches_oracle():
    _run_sub("""
import numpy as np, jax
from repro.dist.sharding import MeshAxes
from repro.launch.mesh import make_debug_mesh
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import ServeEngine

cfg = ModelConfig(name='t', num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=4, d_ff=64, vocab_size=64,
                  param_dtype='float32', compute_dtype='float32')
params = init_params(jax.random.key(0), cfg)
oracle = ServeEngine(cfg, params, max_len=32)
rng = np.random.default_rng(0)
reqs = [(rng.integers(1, 64, size=s).astype(np.int32), nt)
        for s, nt in [(5, 4), (9, 6), (7, 3)]]
want = [oracle.generate(p[None], nt)[0] for p, nt in reqs]

mesh = make_debug_mesh((2, 2), ("data", "model"))
eng = ServeEngine(cfg, params, max_len=32, mesh=mesh, axes=MeshAxes())
eng.start_paged(max_batch=2, page_size=8)
pending = list(range(3)); slots = {}; out = {}
while len(out) < 3:
    while pending:
        s = eng.admit(*reqs[pending[0]])
        if s is None: break
        slots[s] = pending.pop(0)
    eng.decode_tick()
    for s in eng.finished_slots():
        out[slots.pop(s)] = eng.retire(s)
for i in range(3):
    np.testing.assert_array_equal(out[i], want[i])
print('SHARDED_PAGED_OK')
""", devices=8)
