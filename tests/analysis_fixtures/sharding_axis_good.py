"""Fixture: conforming PartitionSpec usage (rule stays silent)."""
from jax.sharding import PartitionSpec as P

pod_axis = "pod"


def good_specs(ax):
    a = P("pod", "data", "model")           # the three logical axes
    b = P(("pod", "data"), None)            # tuples of them
    c = P(pod_axis, None)                   # variables are policy-driven
    d = P(*ax)                              # starred: resolved elsewhere
    return a, b, c, d
