"""Fixture: donated buffers read after the donating call (rule fires 2x)."""
import jax

f = jax.jit(lambda a, b: a + b, donate_argnums=(1,))


def read_after_donation(x, y):
    out = f(x, y)
    return out + y          # y was donated: this read sees a deleted buffer


def donate_in_loop(x, y):
    for _ in range(4):
        out = f(x, y)       # y donated, never rebound in the loop body
    return out
