"""Fixture fleet-like module: dataclass/validator drift (fires 4x).

* ``severity`` field missing from the validator schema,
* ``factor`` schema key missing from the dataclass,
* ``_TIMELINE_REQUIRED`` naming a non-field,
* ``ResizeEvent`` missing the shared ``reason`` envelope field.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class ResizeEvent:
    t: float
    add: tuple = ()
    remove: tuple = ()
    # envelope field `reason` lost


@dataclass(frozen=True)
class FailureEvent:
    t: float
    kind: str
    target: str
    duration_s: float = 0.0
    severity: int = 0           # not in _TIMELINE_FIELDS
    reason: str = ""


_TIMELINE_FIELDS = {"t": (int, float), "kind": str, "target": str,
                    "duration_s": (int, float), "factor": (int, float),
                    "reason": str}
_TIMELINE_REQUIRED = ("t", "kind", "target", "factor")
