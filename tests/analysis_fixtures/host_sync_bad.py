"""Fixture: host round-trips inside hot-registered functions (fires 3x)."""
import jax.numpy as jnp
import numpy as np


def decode_tick(self, logits, loss):
    nxt = np.asarray(jnp.argmax(logits, axis=-1))   # eager op + transfer
    cur = float(loss)                               # scalar sync per tick
    return nxt, cur


def map_batch(self, finish):
    return finish.item()                            # blocking device scalar
