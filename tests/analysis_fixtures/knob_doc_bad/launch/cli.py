"""Fixture launcher: one documented flag, one undocumented (fires 1x)."""
import argparse


def build():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--secret-knob", type=float, default=0.5)  # not in docs
    return ap
