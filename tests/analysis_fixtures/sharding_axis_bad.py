"""Fixture: PartitionSpec literals off the pod/data/model grid (fires 3x)."""
from jax.sharding import PartitionSpec as P
from jax.sharding import PartitionSpec


def bad_specs():
    a = P("tp", None)                       # not a ROADMAP axis
    b = P(("pod", "dp"), None, "model")     # tuple entry off-grid
    c = PartitionSpec("expert")             # long-form spelling too
    return a, b, c
