"""Fixture: retrace-clean jit + bucketing idiom (rule stays silent)."""
import jax

from repro.sched_integration.fabric import MappingFabric, pow2_bucket

f = jax.jit(lambda a: a * 2)                # hoisted: one trace per shape


def jit_outside_loop(xs):
    return [f(x) for x in xs]


def reuse_module_fn(xs):
    out = []
    for x in xs:
        out.append(f(x))                    # cached callable inside the loop
    return out


def on_grid_buckets(exec_np, n, floor):
    fab = MappingFabric(exec_np, min_pe_bucket=8)    # pow2 literal
    return fab, pow2_bucket(n, 1), pow2_bucket(n, floor)
