"""Fixture: the sanctioned donation shapes (rule stays silent)."""
import jax

f = jax.jit(lambda a, b: a + b, donate_argnums=(1,))


class Runtime:
    def __init__(self):
        self.tick = jax.jit(lambda p, pools: (p, pools * 2),
                            donate_argnums=(1,))
        self.pools = None

    def step(self, p):
        # Rebind-in-the-same-statement: the paging/fabric tick pattern.
        out, self.pools = self.tick(p, self.pools)
        return out


def rebind_each_iteration(x, y):
    for _ in range(4):
        y = f(x, y)         # donated AND rebound every iteration
    return y


def last_use(x, y):
    return f(x, y)          # nothing reads y afterwards
