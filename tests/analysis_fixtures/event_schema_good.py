"""Fixture fleet-like module: dataclasses and validator in lockstep."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ResizeEvent:
    t: float
    add: tuple = ()
    remove: tuple = ()
    reason: str = ""


@dataclass(frozen=True)
class FailureEvent:
    t: float
    kind: str
    target: str
    duration_s: float = 0.0
    factor: float = 1.0
    reason: str = ""


_TIMELINE_FIELDS = {"t": (int, float), "kind": str, "target": str,
                    "duration_s": (int, float), "factor": (int, float),
                    "reason": str}
_TIMELINE_REQUIRED = ("t", "kind", "target")
