"""Fixture model: one rogue site, one non-literal site (fires 3x total)."""
from repro.dist.hints import shard_hint


def block(x, name):
    x = shard_hint(x, "layer_boundary")     # inventoried: fine
    x = shard_hint(x, "ffn_hidden")         # inventoried: fine
    x = shard_hint(x, "rogue_site")         # NOT in SITE_INVENTORY
    x = shard_hint(x, name)                 # non-literal defeats the inventory
    return x
