"""Fixture hints module: inventory with a dead site (``ghost_site``)."""

SITE_INVENTORY = (
    "layer_boundary",
    "ffn_hidden",
    "ghost_site",       # inventoried but never used by the models tree
)
