"""Fixture: hot functions with only the sanctioned transfer shapes."""
import numpy as np


def decode_tick(self, toks_dev):
    # One batched materialization of a value the jitted program computed.
    nxt = np.asarray(toks_dev)
    pos = np.zeros(4, dtype=np.int32)       # host-side bookkeeping is fine
    return [int(nxt[i]) + int(pos[i]) for i in range(4)]


def schedule(self, new_avail):
    new_avail = np.asarray(new_avail)       # marks the name host-side
    return [float(new_avail[i]) for i in range(new_avail.shape[0])]


def not_hot(self, loss):
    return float(loss)                      # cold path: not a design rule
