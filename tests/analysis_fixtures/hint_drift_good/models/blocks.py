"""Fixture model: every site literal and inventoried (rule stays silent)."""
from repro.dist.hints import shard_hint


def block(x):
    x = shard_hint(x, "layer_boundary")
    h = shard_hint(x, "ffn_hidden")
    return x + h
