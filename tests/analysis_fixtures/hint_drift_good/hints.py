"""Fixture hints module: inventory bijecting with the models tree."""

SITE_INVENTORY = (
    "layer_boundary",
    "ffn_hidden",
)
