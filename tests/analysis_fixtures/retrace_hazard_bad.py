"""Fixture: retrace traps (fires 4x: lambda, loop-local def, two buckets)."""
import jax

from repro.sched_integration.fabric import MappingFabric, pow2_bucket


def jit_in_loop(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda a: a * 2)        # fresh lambda every iteration
        out.append(f(x))
    return out


def local_def_in_loop(xs):
    out = []
    for x in xs:
        def body(a):
            return a + 1
        out.append(jax.jit(body)(x))        # fresh def every iteration
    return out


def off_grid_buckets(exec_np, n):
    fab = MappingFabric(exec_np, min_pe_bucket=12)   # not a power of two
    return fab, pow2_bucket(n, 3)                    # degenerate floor
