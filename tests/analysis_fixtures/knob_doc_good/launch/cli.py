"""Fixture launcher: every flag documented (rule stays silent)."""
import argparse


def build():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.5)
    return ap
