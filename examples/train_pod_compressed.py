"""Pod-compressed training with fault tolerance: int8 cross-pod gradients,
crash at step 30, resume bit-exactly — residual included.

The Trainer builds a (2, 2) ``(pod, data)`` mesh itself (4 fake CPU devices
here), reduces gradients cross-pod with the int8 error-feedback collective,
and checkpoints the per-pod residual next to params/opt; the restarted run
continues on the exact trajectory of an uninterrupted one.

  PYTHONPATH=src python examples/train_pod_compressed.py
"""

import os
import shutil

# must happen before jax initializes its backend
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.optim import AdamWConfig, warmup_cosine  # noqa: E402
from repro.train import Trainer, TrainerConfig  # noqa: E402

CKPT = "/tmp/repro_example_pod_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_smoke_config("deepseek-7b")
print(f"training {cfg.name}: {cfg.param_count()/1e6:.2f}M params "
      f"on {len(jax.devices())} devices, int8 pod-compressed gradients")


def make_trainer():
    return Trainer(
        cfg,
        AdamWConfig(learning_rate=warmup_cosine(3e-3, 10, 60), weight_decay=0.1),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8),
        TrainerConfig(total_steps=60, checkpoint_every=20,
                      checkpoint_dir=CKPT, log_every=20,
                      mesh_shape=(2, 2), compress_pods=True, microbatches=2),
    )


try:
    make_trainer().run(inject_failure_at=30)
except RuntimeError as e:
    print(f"!! {e} — restarting from latest checkpoint (residual restored)")

tr = make_trainer()
_, _, history = tr.run()   # resumes from step 20 exactly
for step, loss in history:
    print(f"  step {step:4d}  loss {loss:.4f}")
res_leaves = jax.tree.leaves(tr.last_residual)
print(f"error-feedback residual: {len(res_leaves)} leaves, "
      f"per-pod stacked {res_leaves[0].shape} — checkpointed with params")
print("restart was bitwise-exact (see tests/test_train_compress.py)")
