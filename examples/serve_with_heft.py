"""Serving with the HEFT_RT front end vs round-robin on a heterogeneous fleet.

  PYTHONPATH=src python examples/serve_with_heft.py

Real decode on CPU-scale replicas with speed factors (mixed pods), plus the
fleet-scale simulation (roofline exec-time estimates) comparing policies.
"""

import numpy as np

from repro.sched_integration import (
    POLICIES,
    default_fleet,
    make_requests,
    simulate_serving,
)

print("fleet-scale simulation: 4 heterogeneous replicas, 7B-class model")
fleet = default_fleet()
reqs = make_requests(rate_rps=800, duration_s=3.0, seed=0)
print(f"{'policy':>14} {'mean lat':>9} {'p99 lat':>9} {'achieved':>9}")
for name, factory in POLICIES.items():
    r = simulate_serving(fleet, reqs, factory(), active_params=7e9)
    print(f"{name:>14} {r.mean_latency*1e3:8.0f}ms {r.p99_latency*1e3:8.0f}ms "
          f"{r.achieved_rps:8.0f}/s")
print("\nutilization under heft_rt:",
      np.round(simulate_serving(fleet, reqs, POLICIES['heft_rt'](),
                                active_params=7e9).replica_util, 2))
