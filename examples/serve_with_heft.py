"""Serving with the HEFT_RT front end vs round-robin on a heterogeneous fleet.

  PYTHONPATH=src python examples/serve_with_heft.py

The fleet-scale simulation (roofline exec-time estimates) runs through the
fabric-batched mapping-event pipeline: the HEFT_RT policy is a
``MappingFabric`` front-end, the exec matrix comes from the vectorized
``service_time_matrix`` roofline op, and the simulator jumps between arrival
event horizons instead of spinning empty scheduler ticks.
"""

import numpy as np

from repro.sched_integration import (
    CostCell,
    CostModelRegistry,
    MappingFabric,
    POLICIES,
    default_fleet,
    make_requests,
    mesh_fleet,
    scaled_cell,
    service_time_matrix,
    simulate_serving,
)

print("fleet-scale simulation: 4 heterogeneous replicas, 7B-class model")
fleet = default_fleet()
reqs = make_requests(rate_rps=800, duration_s=3.0, seed=0)
print(f"{'policy':>14} {'mean lat':>9} {'p99 lat':>9} {'achieved':>9}")
for name, factory in POLICIES.items():
    r = simulate_serving(fleet, reqs, factory(), active_params=7e9)
    print(f"{name:>14} {r.mean_latency*1e3:8.0f}ms {r.p99_latency*1e3:8.0f}ms "
          f"{r.achieved_rps:8.0f}/s")
print("\nutilization under heft_rt:",
      np.round(simulate_serving(fleet, reqs, POLICIES['heft_rt'](),
                                active_params=7e9).replica_util, 2))

# The fabric backend knob: the same mapping events batched through the
# persistent jitted dispatch (or backend="pallas" for the fused overlay
# kernel), with T_avail device-resident across events.  Decisions are
# slot-for-slot identical to the numpy oracle.
print("\nfabric-batched mapping events (backend='jit'):")
fab = MappingFabric(len(fleet), backend="jit")
ex = service_time_matrix(reqs[:256], fleet, active_params=7e9).astype(np.float32)
B, P = 64, len(fleet)
batch_ex = ex[: B * 4].reshape(B, 4, P)                 # 64 events x 4-deep queues
batch_avg = batch_ex.mean(axis=2)
res = fab.map_batch(batch_avg, batch_ex, np.zeros((B, P), np.float32))
counts = np.bincount(np.asarray(res.assignment).ravel(), minlength=P)
print(f"  {B} events in one device dispatch; per-replica assignment counts: "
      f"{counts.tolist()}  (fabric events so far: {fab.events})")

# Mesh-backed fleet + dry-run cost models: replicas are mixed-size mesh
# slices of one chip generation, and Exec_TID columns come from measured
# (arch × shape × mesh) cost cells — here one measured cell projected onto
# the smaller slices (90% scaling efficiency) — with the analytic roofline
# as fallback for uncovered cells.
print("\nmesh-backed fleet with cost-model Exec_TID:")
sharded = mesh_fleet("deepseek-7b", ((16, 16), (16, 16), (4, 16), (4, 4)))
# "Measured" cells carry what the analytic 2·N·tokens roofline misses:
# the quadratic attention FLOPs in prefill (~+15% at 32k) and the KV-cache
# stream on top of weight bytes in decode (~+30%).
measured = [
    CostCell("deepseek-7b", "prefill", (16, 16), tokens_per_step=32 * 32768,
             flops_per_device=1.15 * 2.0 * 7e9 * 32 * 32768 / 256,
             bytes_per_device=6.1e10),
    CostCell("deepseek-7b", "decode", (16, 16), tokens_per_step=128,
             flops_per_device=2.0 * 7e9 * 128 / 256,
             bytes_per_device=1.30 * 2.0 * 7e9 * 128 / 256),
]
reg = CostModelRegistry(measured)
for cell in measured:
    for shape in ((4, 16), (4, 4)):
        reg.register(scaled_cell(cell, shape, efficiency=0.9))
print(f"  registry: {len(reg)} cells; "
      f"covered: {[reg.covers(r) for r in sharded]}")
r_cost = simulate_serving(sharded, reqs, POLICIES["heft_rt"](),
                          active_params=7e9, cost_registry=reg)
r_roof = simulate_serving(sharded, reqs, POLICIES["heft_rt"](),
                          active_params=7e9)
print(f"  cost-model Exec_TID: mean {r_cost.mean_latency*1e3:6.0f}ms  "
      f"p99 {r_cost.p99_latency*1e3:6.0f}ms  {r_cost.achieved_rps:5.0f}/s")
print(f"  roofline  Exec_TID: mean {r_roof.mean_latency*1e3:6.0f}ms  "
      f"p99 {r_roof.p99_latency*1e3:6.0f}ms  {r_roof.achieved_rps:5.0f}/s")
