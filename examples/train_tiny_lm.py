"""End-to-end training with fault tolerance: crash at step 60, resume, finish.

  PYTHONPATH=src python examples/train_tiny_lm.py
"""

import shutil

from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import Trainer, TrainerConfig

CKPT = "/tmp/repro_example_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_smoke_config("gemma2-9b")   # reduced gemma2: softcaps, local/global
print(f"training {cfg.name}: {cfg.param_count()/1e6:.2f}M params")


def make_trainer():
    return Trainer(
        cfg,
        AdamWConfig(learning_rate=warmup_cosine(3e-3, 10, 120), weight_decay=0.1),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8),
        TrainerConfig(total_steps=120, checkpoint_every=25,
                      checkpoint_dir=CKPT, log_every=20),
    )


try:
    make_trainer().run(inject_failure_at=60)
except RuntimeError as e:
    print(f"!! {e} — restarting from latest checkpoint")

_, _, history = make_trainer().run()   # resumes from step 50 exactly
for step, loss in history:
    print(f"  step {step:4d}  loss {loss:.4f}")
print("restart was bitwise-exact (see tests/test_substrates.py)")
