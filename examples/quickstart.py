"""Quickstart: one HEFT_RT mapping event, three ways — then serve with it.

  PYTHONPATH=src python examples/quickstart.py

1. software HEFT_RT (the paper's baseline scheduler),
2. the Pallas TPU overlay (odd-even sort + EFT min-tree), bit-identical,
3. the hardware cycle/latency model (3n+3 @ 3.048 ns → 9.144 ns/decision),
4. the paged serving API: two requests continuously batched through one
   ServeEngine's block-paged KV pool, token-identical to the dense oracle
   (docs/serving.md).
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PAPER_CRITICAL_PATH_NS,
    heft_rt,
    per_decision_latency_ns,
    simulate_mapping_event,
    worst_case_cycles,
)
from repro.kernels import heft_rt_hw

# A ready queue of 8 tasks on the paper's SoC: 3×ARM + 1×FFT accelerator.
# Tasks 0-3 are FFTs (fast on PE3), tasks 4-7 are DSP (ARM-only → inf).
exec_times = np.array([
    # ARM0   ARM1   ARM2   FFT
    [0.35,  0.35,  0.35,  0.035],
    [0.35,  0.35,  0.35,  0.035],
    [0.35,  0.35,  0.35,  0.035],
    [0.35,  0.35,  0.35,  0.035],
    [0.14,  0.14,  0.14,  np.inf],
    [0.21,  0.21,  0.21,  np.inf],
    [0.14,  0.14,  0.14,  np.inf],
    [0.08,  0.08,  0.08,  np.inf],
], dtype=np.float32)
avg = np.where(np.isfinite(exec_times), exec_times, np.nan)
avg = np.nanmean(avg, axis=1).astype(np.float32)
avail = np.zeros(4, dtype=np.float32)

print("=== software HEFT_RT ===")
res = heft_rt(jnp.array(avg), jnp.array(exec_times), jnp.array(avail))
for i in range(8):
    t, pe = int(res.order[i]), int(res.assignment[i])
    print(f"  priority {i}: task {t} -> PE{pe} "
          f"[{float(res.start_time[i]):.3f}, {float(res.finish_time[i]):.3f}] ms")
print(f"  makespan: {float(res.new_avail.max()):.3f} ms")

print("=== Pallas overlay (TPU dataplane, interpret-validated) ===")
order, pes, starts, fins, new_avail = heft_rt_hw(
    jnp.array(avg), jnp.array(exec_times), jnp.array(avail))
same = (np.asarray(order) == np.asarray(res.order)).all() and \
       (np.asarray(pes) == np.asarray(res.assignment)).all()
print(f"  decisions bit-identical to software: {same}  (paper Fig. 3)")

print("=== hardware latency model ===")
n = 8
rep = simulate_mapping_event(avg)
print(f"  cycles: {rep.total_cycles} (bound 3n+3 = {worst_case_cycles(n)})")
print(f"  mapping event: {worst_case_cycles(n) * PAPER_CRITICAL_PATH_NS:.1f} ns"
      f"  |  per decision (D=512 design): "
      f"{per_decision_latency_ns(512, PAPER_CRITICAL_PATH_NS, asymptotic=True):.3f} ns"
      f" (paper: 9.144 ns)")

print("=== paged serving (continuous batching, dense oracle verified) ===")
import jax  # noqa: E402

from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.serve import ServeEngine  # noqa: E402

cfg = ModelConfig(name="quickstart", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=4, d_ff=64, vocab_size=64,
                  param_dtype="float32", compute_dtype="float32")
params = init_params(jax.random.key(0), cfg)
eng = ServeEngine(cfg, params, max_len=32)
eng.start_paged(max_batch=2, page_size=8)      # admit/decode_tick/retire API
rng = np.random.default_rng(0)
reqs = [(rng.integers(1, 64, size=s).astype(np.int32), nt)
        for s, nt in [(6, 5), (11, 4)]]
slots = {eng.admit(p, nt): i for i, (p, nt) in enumerate(reqs)}
done = {}
while len(done) < len(reqs):
    eng.decode_tick()                          # one batched step, all slots
    for s in eng.finished_slots():
        done[slots.pop(s)] = eng.retire(s)
oracle = ServeEngine(cfg, params, max_len=32)
for i, (p, nt) in enumerate(reqs):
    same = np.array_equal(done[i], oracle.generate(p[None], nt)[0])
    print(f"  request {i}: {len(p)} prompt + {nt} new tokens -> "
          f"bit-identical to dense generate: {same}")
pool = eng.paged.pool
print(f"  pages allocated == freed: {pool.allocated} == {pool.freed}")
