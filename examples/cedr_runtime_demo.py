"""CEDR runtime demo: the paper's oversubscription experiment, end to end.

  PYTHONPATH=src python examples/cedr_runtime_demo.py

Sweeps injection rate over the high-latency workload (10×PulseDoppler +
10×WiFi-TX) on the 3×ARM + FFT SoC and compares the software scheduler
against the hardware scheduler (calibrated overhead models).
"""

from repro.runtime import HW_MODEL, SW_MODEL, CedrSimulator, paper_soc_pe_types
from repro.runtime.workload import high_latency_arrivals

print(f"{'target':>7} {'sw fps':>8} {'hw fps':>8} {'gain':>7} "
      f"{'sw exec':>9} {'hw exec':>9} {'maxQ':>6}")
pes = paper_soc_pe_types()
for rate in [100, 200, 300, 400, 500, 600]:
    arr = high_latency_arrivals(rate, seed=1)
    sw = CedrSimulator(pes, overhead=SW_MODEL, seed=7).run(arr)
    hw = CedrSimulator(pes, overhead=HW_MODEL, seed=7).run(arr)
    print(f"{rate:7d} {sw.achieved_frame_rate:8.1f} {hw.achieved_frame_rate:8.1f} "
          f"{(hw.achieved_frame_rate/sw.achieved_frame_rate-1)*100:6.1f}% "
          f"{sw.avg_app_exec_time*1e3:8.2f}ms {hw.avg_app_exec_time*1e3:8.2f}ms "
          f"{sw.max_queue_size:6d}")
print("\npaper (Fig 5/6): sw saturates ~161.5 fps, hw ~204.6 fps (+26.7%); "
      "hw per-app exec time 31.7% lower in saturation")
