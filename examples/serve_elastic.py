"""Elastic serving: a load spike grows the fleet, the drain merges it back.

  PYTHONPATH=src python examples/serve_elastic.py

Part 1 (fleet-scale simulation): a scripted load spike hits a 2-replica
base fleet.  The closed-loop ``FleetController`` watches the committed
backlog horizon each mapping event, carves two extra (4, 4) replicas out of
the spare pool while the spike lasts, and merges them back once the backlog
drains — printing its decision trace.  Compare against the static base
fleet (tail latency blows up) and the always-max fleet (wasteful between
spikes).

Part 2 (live engines): one real ``ServeEngine`` replica migrates between
mesh slices in memory via ``reshard`` — params and an in-flight KV cache
move to the new slice with token-for-token identical generation (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for real slices;
skipped with fewer devices).
"""

import numpy as np

from repro.sched_integration import (
    FleetController,
    FleetControllerConfig,
    POLICIES,
    grown_replica_factory,
    make_spike_requests,
    mesh_fleet,
    simulate_serving,
)

ACTIVE = 7e9

print("== elastic fleet vs static fleets under a load spike ==")
base = mesh_fleet("deepseek-7b", ((4, 4), (4, 4)))
always_max = mesh_fleet("deepseek-7b", ((4, 4),) * 4)
reqs = make_spike_requests(2.0, 30.0, spike_start=1.0, spike_end=2.0,
                           duration_s=8.0, seed=1)
print(f"{len(reqs)} requests; spike 30 rps in [1s, 2s), base 2 rps\n")

ctl = FleetController(
    FleetControllerConfig(grow_backlog_s=1.0, shrink_backlog_s=0.3,
                          cooldown_s=0.5, max_grown=2),
    grown_replica_factory("deepseek-7b", (4, 4)))
elastic = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                           active_params=ACTIVE, controller=ctl)
static = simulate_serving(base, reqs, POLICIES["heft_rt"](),
                          active_params=ACTIVE)
best = simulate_serving(always_max, reqs, POLICIES["heft_rt"](),
                        active_params=ACTIVE)

print("controller decision trace:")
for t, kind, why in ctl.trace:
    print(f"  t={t:6.2f}s  {kind:6s}  {why}")

print(f"\n{'fleet':>16} {'p50':>8} {'p99':>8} {'served':>7} {'devices':>14}")
for name, r, devs in (("static base", static, "32 always"),
                      ("elastic", elastic, "32 + 32@spike"),
                      ("always max", best, "64 always")):
    print(f"{name:>16} {r.p50_latency*1e3:7.0f}ms {r.p99_latency*1e3:7.0f}ms "
          f"{int(r.served_mask.sum()):6d}/{len(reqs)} {devs:>14}")

# ---------------------------------------------------------------------------
# Part 2: live replica migration (needs >= 6 local devices)
# ---------------------------------------------------------------------------

import jax  # noqa: E402

if jax.device_count() >= 6:
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    print("\n== live ServeEngine.reshard: (1,1) -> (2,2) -> (2,1) ==")
    cfg = get_smoke_config("deepseek-7b")
    params = init_params(jax.random.key(0), cfg)
    pool = jax.devices()
    eng = ServeEngine(cfg, params, max_len=64,
                      mesh=make_debug_mesh((1, 1), devices=pool[:1]))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    want = eng.generate(prompt[None, :], 8)
    for shape, devs in (((2, 2), pool[:4]), ((2, 1), pool[4:6])):
        eng.reshard(make_debug_mesh(shape, devices=devs))
        got = eng.generate(prompt[None, :], 8)
        ok = "bit-identical" if np.array_equal(got, want) else "MISMATCH"
        print(f"  resharded to {shape}: generation {ok}")
else:
    print(f"\n(live reshard demo skipped: {jax.device_count()} device(s); "
          f"run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
