"""repro.analysis — jax-aware static design rules, machine-checked.

The paper's argument is that correctness must be engineered into the
substrate, not hoped for: the FPGA overlay is correct-by-construction, and
HTS-style schedulers lean on hardware design-rule checking to stay sound at
scale.  This package is the software analogue for our jax stack: the
conventions the hot paths depend on (buffer donation discipline, no host
round-trips inside registered hot functions, the three mesh-axis names,
the ``shard_hint`` site inventory, retrace hygiene, event-schema /
knob-doc coherence) are enforced as AST-level lint rules instead of by
review.

Entry point::

    PYTHONPATH=src python -m repro.analysis src \
        --baseline tools/analysis_baseline.json

Findings print as ``path:line:col: rule: message``; a non-baselined,
non-suppressed finding exits 1 (the CI gate).  Per-line suppression is
``# repro: noqa[rule-name]`` with an optional reason after the bracket;
grandfathered findings live in the checked-in baseline file (matched on
``(rule, path, message)`` with counts, so they survive line drift but not
new instances).

Rule catalogue, examples, and the how-to-add-a-rule walkthrough:
``docs/analysis.md``.
"""

from repro.analysis.findings import (Finding, apply_baseline,  # noqa: F401
                                     load_baseline, suppressed,
                                     write_baseline)
from repro.analysis.registry import (AnalysisContext, Rule,  # noqa: F401
                                     all_rules, default_context, rule)
from repro.analysis.runner import run_analysis  # noqa: F401
