"""Finding record, ``# repro: noqa[rule]`` suppression, baseline files.

A finding's *baseline key* is ``(rule, path, message)`` — deliberately not
the line number, so grandfathered findings survive unrelated edits above
them, while a second instance of the same anti-pattern in the same file is
a new finding (counts are matched, not just membership).
"""

from __future__ import annotations

import json
import re
from collections import Counter
from dataclasses import asdict, dataclass

# `# repro: noqa[rule-a,rule-b]` with an optional free-form reason after the
# closing bracket (a reason is encouraged: the rule docs ask "why is this
# instance allowed?", and review reads it where the code lives).
NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([^\]]+)\]")

BASELINE_VERSION = 1


@dataclass(frozen=True, order=True)
class Finding:
    """One design-rule violation, anchored at a source location."""

    path: str       # repo-root-relative, posix separators
    line: int       # 1-indexed
    col: int        # 0-indexed (ast convention)
    rule: str
    message: str

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"

    def to_json(self) -> dict:
        return asdict(self)


def suppressed(finding: Finding, source_lines: list[str]) -> bool:
    """True when the finding's line carries ``# repro: noqa[<its rule>]``."""
    if not 1 <= finding.line <= len(source_lines):
        return False
    m = NOQA_RE.search(source_lines[finding.line - 1])
    if m is None:
        return False
    rules = {r.strip() for r in m.group(1).split(",")}
    return finding.rule in rules


def load_baseline(path) -> Counter:
    """Baseline file → Counter of finding keys (empty for a missing file,
    so a fresh checkout without the file just means 'no grandfathering')."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except FileNotFoundError:
        return Counter()
    if not isinstance(obj, dict) or "findings" not in obj:
        raise ValueError(f"baseline {path} is not a findings object")
    base: Counter = Counter()
    for entry in obj["findings"]:
        key = (entry["rule"], entry["path"], entry["message"])
        base[key] += int(entry.get("count", 1))
    return base


def apply_baseline(findings: list[Finding], baseline: Counter
                   ) -> tuple[list[Finding], int]:
    """Split findings into (new, grandfathered-count).

    Count-matched: a baseline entry with count 2 absorbs at most two live
    instances of that key — the third is new and gates.
    """
    budget = Counter(baseline)
    fresh = []
    absorbed = 0
    for f in findings:
        if budget[f.key()] > 0:
            budget[f.key()] -= 1
            absorbed += 1
        else:
            fresh.append(f)
    return fresh, absorbed


def write_baseline(path, findings: list[Finding]) -> None:
    """Serialize the current findings as the new grandfather baseline."""
    counts = Counter(f.key() for f in findings)
    entries = [{"rule": rule, "path": p, "message": msg, "count": n}
               for (rule, p, msg), n in sorted(counts.items())]
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION, "findings": entries}, f,
                  indent=1, sort_keys=False)
        f.write("\n")
