"""Repo-scope rules: cross-file inventories and schema/validator pairs.

These rules read their anchor paths from the :class:`AnalysisContext`
(``hints_path``/``models_dir``/``fleet_path``/``launch_dir``/``knobs_md``)
and skip silently when an anchor is absent — fixture trees exercise each
rule in isolation by populating only its anchors.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.registry import rule
from repro.analysis.rules_ast import _dotted


def _parse(path: Path):
    return ast.parse(path.read_text(), filename=str(path))


def _module_files(root: Path):
    if root.is_file():
        return [root]
    return sorted(p for p in root.rglob("*.py")
                  if "__pycache__" not in p.parts)


# ---------------------------------------------------------------------------
# hint-drift


def _find_assign(tree, name: str):
    """(value node, lineno) of a module-level ``NAME = ...`` assignment."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value, node.lineno
    return None, None


def _string_elts(node) -> list[str]:
    if not isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return []
    return [e.value for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)]


@rule("hint-drift", scope="repo")
def hint_drift(ctx):
    """The ``shard_hint`` call sites across ``models/`` must biject with the
    ``SITE_INVENTORY`` tuple in ``dist/hints.py``: a site used but not
    inventoried is invisible to every sharding policy (silently identity —
    the layout constraint never applies); an inventoried site never used is
    dead policy surface that rots.  Non-literal site names defeat the
    inventory entirely."""
    if ctx.hints_path is None or ctx.models_dir is None:
        return
    hints_rel = ctx.relpath(ctx.hints_path)
    tree = _parse(ctx.hints_path)
    value, inv_line = _find_assign(tree, "SITE_INVENTORY")
    if value is None:
        yield Finding(hints_rel, 1, 0, "hint-drift",
                      "dist/hints.py defines no SITE_INVENTORY tuple — the "
                      "hint-site inventory the models must biject with")
        return
    inventory = set(_string_elts(value))
    used: dict[str, tuple[str, int, int]] = {}
    for path in _module_files(ctx.models_dir):
        rel = ctx.relpath(path)
        for node in ast.walk(_parse(path)):
            if not (isinstance(node, ast.Call) and _dotted(node.func)
                    and _dotted(node.func).split(".")[-1] == "shard_hint"):
                continue
            if len(node.args) < 2:
                continue
            site = node.args[1]
            if not (isinstance(site, ast.Constant)
                    and isinstance(site.value, str)):
                yield Finding(
                    rel, site.lineno, site.col_offset, "hint-drift",
                    "shard_hint site name is not a string literal — the "
                    "site inventory (and every policy dict keyed on it) "
                    "cannot see this site")
                continue
            used.setdefault(site.value, (rel, site.lineno, site.col_offset))
    for name in sorted(set(used) - inventory):
        rel, line, col = used[name]
        yield Finding(
            rel, line, col, "hint-drift",
            f"shard_hint site {name!r} is not in dist/hints.py "
            f"SITE_INVENTORY — no sharding policy will ever constrain it "
            f"(add it to the inventory + activation_hint_policy)")
    for name in sorted(inventory - set(used)):
        yield Finding(
            hints_rel, inv_line, 0, "hint-drift",
            f"SITE_INVENTORY names {name!r} but no shard_hint call in "
            f"models/ uses it — dead policy surface (remove it or wire the "
            f"site)")


# ---------------------------------------------------------------------------
# event-schema-drift


def _dataclass_fields(tree, cls_name: str):
    """(field names, lineno) of a dataclass's annotated fields."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            fields = [s.target.id for s in node.body
                      if isinstance(s, ast.AnnAssign)
                      and isinstance(s.target, ast.Name)]
            return fields, node.lineno
    return None, None


@rule("event-schema-drift", scope="repo")
def event_schema_drift(ctx):
    """The chaos/elastic event dataclasses in ``fleet.py`` and their JSON
    timeline validators must agree: ``FailureEvent``'s fields must equal
    ``_TIMELINE_FIELDS``'s keys exactly (a field the validator doesn't know
    rejects every timeline that sets it; a validator key the dataclass
    lacks crashes ``FailureEvent(**ev)``), ``_TIMELINE_REQUIRED`` must be a
    subset, and both event dataclasses must keep the shared unified-heap
    envelope (``t`` + ``reason``)."""
    if ctx.fleet_path is None:
        return
    rel = ctx.relpath(ctx.fleet_path)
    tree = _parse(ctx.fleet_path)

    fields, cls_line = _dataclass_fields(tree, "FailureEvent")
    schema, schema_line = _find_assign(tree, "_TIMELINE_FIELDS")
    required, req_line = _find_assign(tree, "_TIMELINE_REQUIRED")
    if fields is None or schema is None:
        yield Finding(rel, 1, 0, "event-schema-drift",
                      "fleet.py must define both the FailureEvent dataclass "
                      "and its _TIMELINE_FIELDS JSON validator schema")
        return
    keys = ([k.value for k in schema.keys
             if isinstance(k, ast.Constant) and isinstance(k.value, str)]
            if isinstance(schema, ast.Dict) else [])
    for name in sorted(set(fields) - set(keys)):
        yield Finding(
            rel, cls_line, 0, "event-schema-drift",
            f"FailureEvent field {name!r} is missing from _TIMELINE_FIELDS "
            f"— validate_failure_timeline rejects every JSON timeline that "
            f"sets it")
    for name in sorted(set(keys) - set(fields)):
        yield Finding(
            rel, schema_line, 0, "event-schema-drift",
            f"_TIMELINE_FIELDS key {name!r} is not a FailureEvent field — "
            f"FailureEvent(**ev) crashes on any timeline that uses it")
    if required is not None:
        for name in sorted(set(_string_elts(required)) - set(fields)):
            yield Finding(
                rel, req_line, 0, "event-schema-drift",
                f"_TIMELINE_REQUIRED names {name!r}, which FailureEvent "
                f"does not define")
    for cls in ("ResizeEvent", "FailureEvent"):
        cfields, cline = _dataclass_fields(tree, cls)
        if cfields is None:
            continue
        for envelope in ("t", "reason"):
            if envelope not in cfields:
                yield Finding(
                    rel, cline, 0, "event-schema-drift",
                    f"{cls} lost the shared timeline envelope field "
                    f"{envelope!r} — the unified simulate_serving event "
                    f"heap sorts/reports on it")


# ---------------------------------------------------------------------------
# knob-doc-drift (tools/check_docs.py folded into the framework)


def _launcher_flags(tree) -> list[tuple[str, int, int]]:
    """Every ``--flag`` string passed to an ``add_argument`` call."""
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    out.append((arg.value, arg.lineno, arg.col_offset))
    return out


@rule("knob-doc-drift", scope="repo")
def knob_doc_drift(ctx):
    """Every launcher ``--flag`` (``add_argument`` calls under ``launch/``,
    parsed from the AST so commented-out flags don't count) must appear in
    ``docs/knobs.md`` — docs rot fails the build, not a reviewer.  The fix
    is always: document the flag in the same PR that adds it."""
    if ctx.launch_dir is None or ctx.knobs_md is None:
        return
    knobs = ctx.knobs_md.read_text()
    checked = 0
    for path in _module_files(ctx.launch_dir):
        rel = ctx.relpath(path)
        for flag, line, col in _launcher_flags(_parse(path)):
            checked += 1
            if f"`{flag}`" not in knobs and flag not in knobs:
                yield Finding(
                    rel, line, col, "knob-doc-drift",
                    f"launcher flag {flag} is not documented in "
                    f"{ctx.relpath(ctx.knobs_md)}")
    if not checked:
        yield Finding(
            ctx.relpath(ctx.launch_dir), 1, 0, "knob-doc-drift",
            "found no launcher flags at all under launch/ — wrong tree?")
