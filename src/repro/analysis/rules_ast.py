"""File-scope AST rules: donation, host sync, sharding axes, retrace.

Shared vocabulary:

* ``_dotted(node)`` renders ``Name``/``Attribute`` chains as their source
  spelling (``self.pool.pools``) — the unit both the donation tracker and
  the rebind scanner key on.
* "host-known" names (host-sync rule) are names every one of whose
  assignments inside the function produces a host value (numpy/math/len/
  literal/...).  Anything else — parameters, jit outputs, unpacked tuples —
  is conservatively treated as possibly device-resident.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.registry import rule

# ---------------------------------------------------------------------------
# shared helpers


def _dotted(node) -> str | None:
    """``a.b.c`` for a pure Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _contains_jax(node) -> bool:
    """Any ``jnp``/``jax`` reference anywhere in the subtree."""
    return any(isinstance(n, ast.Name) and n.id in ("jnp", "jax")
               for n in ast.walk(node))


def _root_name(node) -> str | None:
    """Leftmost Name of a Name/Attribute/Subscript chain (``a`` in
    ``a.b[i].c``)."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _functions(tree):
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield n


def _parent_map(tree) -> dict:
    parents = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _enclosing_statement(node, parents):
    while node in parents and not isinstance(node, ast.stmt):
        node = parents[node]
    return node if isinstance(node, ast.stmt) else None


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# donation-after-use


def _donate_positions(value) -> set[int]:
    """Donated positional indices if ``value`` is a ``jax.jit``/``jit`` call
    carrying ``donate_argnums`` (int or tuple of ints)."""
    if not isinstance(value, ast.Call):
        return set()
    if _dotted(value.func) not in ("jax.jit", "jit"):
        return set()
    for kw in value.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        consts = ([v] if isinstance(v, ast.Constant)
                  else list(v.elts) if isinstance(v, (ast.Tuple, ast.List))
                  else [])
        return {c.value for c in consts
                if isinstance(c, ast.Constant) and isinstance(c.value, int)}
    return set()


def _assign_targets(stmt) -> list[str]:
    """Dotted strings this statement rebinds (tuple targets flattened)."""
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    else:
        return []
    out = []
    for t in targets:
        elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
        for e in elts:
            d = _dotted(e)
            if d:
                out.append(d)
    return out


@rule("donation-after-use")
def donation_after_use(ctx, path, tree, lines):
    """A name passed in a ``donate_argnums`` position of a jitted callable
    is read again before being rebound — the donated buffer is deleted by
    XLA, so the later read sees garbage (or crashes).  The paging/fabric
    tick pattern ``x = f(..., x, ...)`` (rebind in the same statement) is
    the sanctioned shape; a donating call inside a loop must rebind the
    donated name somewhere in the loop body."""
    # Module-wide donation registry: assignment target → donated positions
    # (`self._tick = jax.jit(tick, donate_argnums=(1,))` in _bind, called
    # from decode_tick — same module, different methods).
    donated: dict[str, set[int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            pos = _donate_positions(node.value)
            if not pos:
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                d = _dotted(t)
                if d:
                    donated.setdefault(d, set()).update(pos)
    if not donated:
        return
    parents = _parent_map(tree)
    rel = ctx.relpath(path)

    for fn in _functions(tree):
        # All occurrences of each donated-arg spelling inside this function,
        # gathered lazily per argument expression.
        calls = [n for n in ast.walk(fn)
                 if isinstance(n, ast.Call) and _dotted(n.func) in donated]
        for call in calls:
            stmt = _enclosing_statement(call, parents)
            if stmt is None:
                continue
            rebound_here = set(_assign_targets(stmt))
            for p in donated[_dotted(call.func)]:
                if p >= len(call.args):
                    continue
                arg = _dotted(call.args[p])
                if arg is None:
                    continue          # fresh expression — nothing to reread
                if arg in rebound_here:
                    continue          # x = f(..., x, ...): the safe pattern
                # Occurrences of `arg` after the donating statement.
                occ = []
                for n in ast.walk(fn):
                    if _dotted(n) == arg and isinstance(
                            n, (ast.Name, ast.Attribute)):
                        occ.append(n)
                later = [n for n in occ if n.lineno > stmt.end_lineno]
                later.sort(key=lambda n: (n.lineno, n.col_offset))
                if later and isinstance(getattr(later[0], "ctx", None),
                                        ast.Load):
                    yield Finding(
                        rel, later[0].lineno, later[0].col_offset,
                        "donation-after-use",
                        f"{arg!r} is donated to {_dotted(call.func)}() at "
                        f"line {call.lineno} (donate_argnums position {p}) "
                        f"but read again before rebinding — the buffer is "
                        f"deleted by XLA")
                    continue
                # Donating call inside a loop: next iteration re-reads the
                # donated name at the call itself unless the body rebinds it.
                loop = stmt
                node = stmt
                loop = None
                while node in parents:
                    node = parents[node]
                    if isinstance(node, (ast.For, ast.While)):
                        loop = node
                        break
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        break
                if loop is not None:
                    rebinds = {t for s in ast.walk(loop)
                               if isinstance(s, ast.stmt)
                               for t in _assign_targets(s)}
                    if arg not in rebinds:
                        yield Finding(
                            rel, call.lineno, call.col_offset,
                            "donation-after-use",
                            f"{arg!r} is donated to {_dotted(call.func)}() "
                            f"inside a loop without being rebound in the "
                            f"loop body — the next iteration reads a "
                            f"deleted buffer")


# ---------------------------------------------------------------------------
# host-sync-in-hot-path

_SYNC_BUILTINS = ("float", "bool")
_HOST_FUNCS = {"len", "range", "sorted", "list", "tuple", "dict", "set",
               "min", "max", "sum", "abs", "int", "float", "bool", "str",
               "enumerate", "zip"}
_HOST_ROOTS = {"np", "numpy", "math"}


def _is_host_expr(e) -> bool:
    """Conservatively: does this expression produce a host value?"""
    if isinstance(e, (ast.Constant, ast.List, ast.Tuple, ast.Dict, ast.Set,
                      ast.ListComp, ast.DictComp, ast.SetComp,
                      ast.GeneratorExp, ast.JoinedStr)):
        return True
    if isinstance(e, ast.Call):
        f = e.func
        if isinstance(f, ast.Name) and f.id in _HOST_FUNCS:
            return True
        root = _root_name(f)
        return root in _HOST_ROOTS
    if isinstance(e, ast.BinOp):
        return _is_host_expr(e.left) and _is_host_expr(e.right)
    if isinstance(e, ast.UnaryOp):
        return _is_host_expr(e.operand)
    return False


def _host_known_names(fn) -> set[str]:
    """Names whose every assignment in ``fn`` is host-producing."""
    produced: dict[str, bool] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            host = _is_host_expr(node.value)
            produced[name] = produced.get(name, True) and host
        elif isinstance(node, ast.For) and isinstance(node.target, ast.Name):
            # `for i, s in enumerate(active)` handled below; plain
            # `for x in <host expr>` marks x host.
            produced[node.target.id] = (produced.get(node.target.id, True)
                                        and _is_host_expr(node.iter))
        elif isinstance(node, ast.For) and isinstance(node.target, ast.Tuple):
            host = _is_host_expr(node.iter)
            for e in node.target.elts:
                if isinstance(e, ast.Name):
                    produced[e.id] = produced.get(e.id, True) and host
    return {n for n, host in produced.items() if host}


@rule("host-sync-in-hot-path")
def host_sync_in_hot_path(ctx, path, tree, lines):
    """Inside a hot-registered function (``decode_tick``, ``map_batch``,
    ``step``, ``schedule``, ... — see ``AnalysisContext.hot_functions`` /
    ``REPRO_LINT_HOT``), a blocking device→host synchronization:
    ``x.item()``, ``float(x)`` / ``bool(x)`` on a possibly-device value, or
    ``np.asarray(<jnp expression>)`` (an eager op dispatched outside the
    jitted program *plus* a transfer).  The sanctioned shape is one batched
    ``np.asarray(out)`` of a value the jitted program already computed."""
    rel = ctx.relpath(path)
    for fn in _functions(tree):
        if fn.name not in ctx.hot_functions:
            continue
        host_known = _host_known_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            # x.item()
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield Finding(
                    rel, node.lineno, node.col_offset,
                    "host-sync-in-hot-path",
                    f".item() inside hot function {fn.name!r} blocks on a "
                    f"device scalar every call")
                continue
            # float(x) / bool(x) on a possibly-device value
            if isinstance(node.func, ast.Name) \
                    and node.func.id in _SYNC_BUILTINS and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Constant):
                    continue
                root = _root_name(arg)
                if root is not None and root in host_known:
                    continue
                if root is None and not _contains_jax(arg) \
                        and _is_host_expr(arg):
                    continue
                yield Finding(
                    rel, node.lineno, node.col_offset,
                    "host-sync-in-hot-path",
                    f"{node.func.id}() on a possibly-device value inside "
                    f"hot function {fn.name!r} — one blocking transfer per "
                    f"call; hoist to a single np.asarray() of the jitted "
                    f"output (or mark the name host-side)")
                continue
            # np.asarray(<expr containing jnp/jax>) — eager op + sync
            f = _dotted(node.func)
            if f in ("np.asarray", "np.array", "numpy.asarray",
                     "numpy.array") and node.args \
                    and _contains_jax(node.args[0]):
                yield Finding(
                    rel, node.lineno, node.col_offset,
                    "host-sync-in-hot-path",
                    f"{f}() over a jnp/jax expression inside hot function "
                    f"{fn.name!r} dispatches the op eagerly outside the "
                    f"jitted program and then blocks on the transfer — "
                    f"compute it inside the jitted step and transfer the "
                    f"(small) result instead")


# ---------------------------------------------------------------------------
# sharding-axis


def _spec_strings(node):
    """String constants appearing in a PartitionSpec argument (tuples of
    axis names count — ``P(("pod", "data"), None)``)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value, node
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            yield from _spec_strings(e)


@rule("sharding-axis")
def sharding_axis(ctx, path, tree, lines):
    """Every ``PartitionSpec``/``P(...)`` literal outside ``dist/`` must
    name only the ROADMAP's logical mesh axes (``pod``/``data``/``model``).
    Model and scheduler code consume layouts through named ``shard_hint``
    sites; a stray literal axis name bypasses the policy indirection and
    breaks on any mesh that doesn't spell that axis."""
    rel = ctx.relpath(path)
    if any(part in ctx.axis_exempt_parts for part in Path(rel).parts):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = _dotted(node.func)
        if f is None or not (f == "P" or f.split(".")[-1] == "PartitionSpec"):
            continue
        for s, anchor in (pair for a in node.args
                          for pair in _spec_strings(a)):
            if s not in ctx.axis_names:
                yield Finding(
                    rel, anchor.lineno, anchor.col_offset, "sharding-axis",
                    f"PartitionSpec axis {s!r} is not one of the mesh axes "
                    f"{tuple(sorted(ctx.axis_names))} (ROADMAP sharding "
                    f"conventions) — outside dist/, specs must use the "
                    f"logical axis names only")


# ---------------------------------------------------------------------------
# retrace-hazard

_BUCKET_KWARGS = ("min_bucket", "min_pe_bucket")


@rule("retrace-hazard")
def retrace_hazard(ctx, path, tree, lines):
    """Two retrace traps: (a) ``jax.jit`` applied to a lambda or a function
    defined inside the enclosing loop body — a fresh callable every
    iteration, so the jit cache never hits and every iteration retraces;
    (b) a non-power-of-two bucket literal (``min_bucket=``/``min_pe_bucket=``
    or ``pow2_bucket(n, k)``'s floor) — pool sizes that bypass the
    power-of-two bucketing retrace on every resize instead of
    ``log2``-many times."""
    rel = ctx.relpath(path)
    seen = set()
    for loop in ast.walk(tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        local_defs = {n.name for n in ast.walk(loop)
                      if isinstance(n, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))}
        for node in ast.walk(loop):
            if not (isinstance(node, ast.Call)
                    and _dotted(node.func) in ("jax.jit", "jit")
                    and node.args):
                continue
            anchor = (node.lineno, node.col_offset)
            if anchor in seen:
                continue
            target = node.args[0]
            fresh = isinstance(target, ast.Lambda) or (
                isinstance(target, ast.Name) and target.id in local_defs)
            if fresh:
                seen.add(anchor)
                yield Finding(
                    rel, node.lineno, node.col_offset, "retrace-hazard",
                    "jax.jit on a callable created inside the loop body — "
                    "a fresh function object every iteration defeats the "
                    "jit cache (hoist the jit out of the loop)")
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg in _BUCKET_KWARGS \
                    and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, int) \
                    and not _is_pow2(kw.value.value):
                yield Finding(
                    rel, kw.value.lineno, kw.value.col_offset,
                    "retrace-hazard",
                    f"{kw.arg}={kw.value.value} is not a power of two — "
                    f"buckets off the pow2 grid retrace per resize instead "
                    f"of log2-many times (see fabric.pow2_bucket)")
        f = _dotted(node.func)
        if f and f.split(".")[-1] == "pow2_bucket" and len(node.args) > 1:
            floor = node.args[1]
            if isinstance(floor, ast.Constant) \
                    and isinstance(floor.value, int) \
                    and not _is_pow2(floor.value):
                yield Finding(
                    rel, floor.lineno, floor.col_offset, "retrace-hazard",
                    f"pow2_bucket floor {floor.value} is not a power of "
                    f"two — the bucket grid degenerates and lane counts "
                    f"retrace per admission")
