"""Rule registry + the analysis context rules read their configuration from.

Two rule scopes:

* ``file``  — ``fn(ctx, path, tree, lines) -> Iterable[Finding]``, called
  once per parsed source file.
* ``repo``  — ``fn(ctx) -> Iterable[Finding]``, called once per run; these
  rules cross files (site inventories, schema/validator pairs, docs).

Every repo-structure assumption lives on :class:`AnalysisContext` (hot
function registry, axis names, the paths of the hint inventory / event
module / launchers / knob docs), so the test suite can point the same rules
at fixture trees under ``tests/analysis_fixtures/``.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable

# Functions registered *hot*: the steady-state serving/training inner loops
# whose latency budget the ROADMAP 9 ns item is chased against.  Inside
# these, host round-trips are design-rule violations (rule
# host-sync-in-hot-path), not style nits.  Extend per-run with
# REPRO_LINT_HOT=name1,name2.
DEFAULT_HOT_FUNCTIONS = frozenset({
    "decode_tick",      # serve/paging.py + serve/engine.py per-tick decode
    "decode_step",      # models/model.py traced decode
    "_decode",          # ServeEngine's jitted decode closure site
    "map_event",        # MappingFabric single-event dispatch
    "map_batch",        # MappingFabric batched dispatch
    "step",             # ServeEngine.step / train step bodies / scan steps
    "tick",             # PagedRuntime's jitted gather→decode→scatter body
    "schedule",         # HeftFrontEnd per-event mapping
    "tick_sched",           # fused tick: decode + in-program HEFT_RT decision
    "tick_sched_counted",   # fused tick variant with device counters
    "decision_ref",         # kernels/fused_decision traced decision body
    "tick_decision_inputs",  # fabric staging for the fused tick
    "commit_tick_decision",  # fabric adoption of fused-tick outputs
})

# The ROADMAP's three logical mesh axes — the only names a PartitionSpec
# literal outside dist/ may mention (rule sharding-axis).
DEFAULT_AXIS_NAMES = frozenset({"pod", "data", "model"})


@dataclass
class AnalysisContext:
    """Everything a rule needs to know about the tree under analysis."""

    root: Path                      # repo root (paths render relative to it)
    files: tuple[Path, ...]         # files file-scope rules run over
    hot_functions: frozenset = DEFAULT_HOT_FUNCTIONS
    axis_names: frozenset = DEFAULT_AXIS_NAMES
    # Path parts exempt from the sharding-axis rule (the distribution
    # substrate itself is where non-model axes are legitimately named).
    axis_exempt_parts: tuple = ("dist",)
    # Repo-scope rule anchors (None → that rule skips itself).
    hints_path: Path | None = None       # SITE_INVENTORY source
    models_dir: Path | None = None       # shard_hint call-site tree
    fleet_path: Path | None = None       # event dataclasses + validators
    launch_dir: Path | None = None       # argparse launchers
    knobs_md: Path | None = None         # docs/knobs.md
    _sources: dict = field(default_factory=dict)

    def relpath(self, path) -> str:
        p = Path(path).resolve()
        try:
            return p.relative_to(self.root).as_posix()
        except ValueError:
            return p.as_posix()

    def source_lines(self, path) -> list[str]:
        """Cached physical lines of ``path`` (for noqa + repo-scope rules)."""
        p = Path(path).resolve()
        if p not in self._sources:
            self._sources[p] = p.read_text().splitlines()
        return self._sources[p]


def _iter_py(paths) -> list[Path]:
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


def default_context(root, paths=None, *,
                    hot_extra: Iterable[str] = ()) -> AnalysisContext:
    """The context for THIS repo's layout (``src/repro/...``).

    ``paths`` narrows which files the file-scope rules visit (default:
    ``<root>/src``); the repo-scope anchors always resolve against ``root``
    and drop to None when absent, so the same builder works on fixture
    trees.
    """
    root = Path(root).resolve()
    scan = [Path(p) for p in paths] if paths else [root / "src"]
    hot = set(DEFAULT_HOT_FUNCTIONS) | set(hot_extra)
    hot |= {h.strip() for h in os.environ.get("REPRO_LINT_HOT", "").split(",")
            if h.strip()}

    def opt(p: Path):
        return p if p.exists() else None

    return AnalysisContext(
        root=root,
        files=tuple(_iter_py(scan)),
        hot_functions=frozenset(hot),
        hints_path=opt(root / "src/repro/dist/hints.py"),
        models_dir=opt(root / "src/repro/models"),
        fleet_path=opt(root / "src/repro/sched_integration/fleet.py"),
        launch_dir=opt(root / "src/repro/launch"),
        knobs_md=opt(root / "docs/knobs.md"),
    )


@dataclass(frozen=True)
class Rule:
    name: str
    scope: str                      # "file" | "repo"
    doc: str
    fn: Callable


_RULES: dict[str, Rule] = {}


def rule(name: str, scope: str = "file"):
    """Register a rule under ``name`` (its docstring becomes the catalogue
    entry printed by ``--list-rules``)."""
    if scope not in ("file", "repo"):
        raise ValueError(f"rule scope must be file|repo, got {scope!r}")

    def deco(fn):
        if name in _RULES:
            raise ValueError(f"duplicate rule name {name!r}")
        _RULES[name] = Rule(name, scope, (fn.__doc__ or "").strip(), fn)
        return fn
    return deco


def all_rules() -> dict[str, Rule]:
    """The registry, with the built-in rule modules imported."""
    from repro.analysis import rules_ast, rules_repo  # noqa: F401
    return dict(_RULES)
