"""Drive the registered rules over an :class:`AnalysisContext`.

Separated from the CLI so tests (and the benchmark) call
:func:`run_analysis` directly on fixture contexts.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.findings import Finding, suppressed
from repro.analysis.registry import AnalysisContext, all_rules


@dataclass
class AnalysisResult:
    findings: list[Finding] = field(default_factory=list)   # live, unsuppressed
    suppressed: list[Finding] = field(default_factory=list)  # noqa'd
    files: int = 0
    rules: tuple = ()


def run_analysis(ctx: AnalysisContext,
                 rule_names=None) -> AnalysisResult:
    """Run the selected rules (default: all) and fold in per-line noqa.

    A file that fails to parse yields one ``syntax-error`` finding — a
    design-rule checker that silently skips unparseable files would be a
    hole in the gate.
    """
    registry = all_rules()
    if rule_names:
        unknown = set(rule_names) - set(registry)
        if unknown:
            raise ValueError(
                f"unknown rule(s) {sorted(unknown)}; known: "
                f"{sorted(registry)}")
        selected = [registry[n] for n in rule_names]
    else:
        selected = list(registry.values())
    file_rules = [r for r in selected if r.scope == "file"]
    repo_rules = [r for r in selected if r.scope == "repo"]

    raw: list[Finding] = []
    # No file-scope rules selected → nothing needs parsing (repo-scope rules
    # read their anchors themselves); skip the per-file loop entirely.
    for path in (ctx.files if file_rules else ()):
        lines = ctx.source_lines(path)
        try:
            tree = ast.parse("\n".join(lines), filename=str(path))
        except SyntaxError as e:
            raw.append(Finding(ctx.relpath(path), e.lineno or 1, 0,
                               "syntax-error", f"file does not parse: "
                               f"{e.msg}"))
            continue
        for r in file_rules:
            raw.extend(r.fn(ctx, path, tree, lines))
    for r in repo_rules:
        raw.extend(r.fn(ctx))

    result = AnalysisResult(files=len(ctx.files),
                            rules=tuple(r.name for r in selected))
    for f in sorted(raw):
        try:
            lines = ctx.source_lines(ctx.root / f.path)
        except OSError:
            lines = []
        (result.suppressed if suppressed(f, lines)
         else result.findings).append(f)
    return result
