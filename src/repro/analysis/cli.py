"""``python -m repro.analysis`` — the CI lint gate.

Exit status: 0 when every finding is suppressed or baselined, 1 when any
new finding survives, 2 on usage errors.  ``--json`` writes the full run
(live + suppressed + baselined counts) as a machine-readable artifact so
CI regressions are diffable.

Environment knobs: ``REPRO_LINT_HOT`` extends the hot-function registry,
``REPRO_LINT_RULES`` pre-selects rules (same syntax as ``--rules``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

from repro.analysis.findings import (apply_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.registry import all_rules, default_context
from repro.analysis.runner import run_analysis


def _detect_root(paths) -> Path:
    """Nearest ancestor (of the first path, else cwd) with pyproject.toml."""
    start = Path(paths[0]).resolve() if paths else Path.cwd().resolve()
    if start.is_file():
        start = start.parent
    for cand in (start, *start.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return Path.cwd().resolve()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jax-aware static design-rule checker (docs/analysis.md)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: <root>/src)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths + repo-scope rule "
                         "anchors (default: auto-detect via pyproject.toml)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="grandfathered-findings file; matching findings "
                         "don't gate (tools/analysis_baseline.json in CI)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from the current findings and "
                         "exit 0")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the findings report as a JSON artifact")
    ap.add_argument("--rules", default=os.environ.get("REPRO_LINT_RULES"),
                    metavar="A,B",
                    help="comma-separated rule subset (default: all; env "
                         "REPRO_LINT_RULES)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="summary line only, no per-finding output")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in all_rules().values():
            head = r.doc.split("\n")[0] if r.doc else ""
            print(f"{r.name}  [{r.scope}]  {head}")
        return 0

    root = Path(args.root).resolve() if args.root else _detect_root(args.paths)
    ctx = default_context(root, paths=args.paths or None)
    rule_names = ([r.strip() for r in args.rules.split(",") if r.strip()]
                  if args.rules else None)
    t0 = time.perf_counter()
    try:
        result = run_analysis(ctx, rule_names)
    except ValueError as e:
        print(f"[repro.analysis] {e}", file=sys.stderr)
        return 2
    elapsed = time.perf_counter() - t0

    if args.update_baseline:
        if not args.baseline:
            print("[repro.analysis] --update-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        write_baseline(args.baseline, result.findings)
        print(f"[repro.analysis] baseline {args.baseline} <- "
              f"{len(result.findings)} finding(s)")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else None
    if baseline is not None:
        fresh, absorbed = apply_baseline(result.findings, baseline)
    else:
        fresh, absorbed = result.findings, 0

    if not args.quiet:
        for f in fresh:
            print(f.render())
    status = "FAIL" if fresh else "OK"
    print(f"[repro.analysis] {status} — {result.files} files, "
          f"{len(result.rules)} rules, {len(fresh)} new finding(s) "
          f"({absorbed} baselined, {len(result.suppressed)} noqa'd) "
          f"in {elapsed:.2f}s")

    if args.json:
        payload = {
            "version": 1,
            "root": str(root),
            "files": result.files,
            "rules": list(result.rules),
            "elapsed_s": round(elapsed, 4),
            "findings": [f.to_json() for f in fresh],
            "baselined": absorbed,
            "suppressed": [f.to_json() for f in result.suppressed],
        }
        with open(args.json, "w") as fp:
            json.dump(payload, fp, indent=1)
            fp.write("\n")
    return 1 if fresh else 0
