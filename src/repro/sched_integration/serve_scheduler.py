"""HEFT_RT as an LLM-serving request scheduler over heterogeneous replicas.

The paper's scenario — dynamically arriving jobs mapped onto PEs with
non-uniform speeds by a low-latency scheduler — is exactly the serving
front-end problem for a fleet of heterogeneous model replicas (mixed pod
sizes / chip generations / MFU profiles).  Requests are tasks; replicas are
PEs; ``Exec[r, p]`` is the roofline-model estimate of request r's service
time on replica p (prefill FLOPs / replica compute + decode bytes / replica
bandwidth); ``T_avail`` is each replica's queue horizon.

``simulate_serving`` runs the oversubscription experiment (paper Figs 5/6
transplanted): offered load sweeps past fleet capacity, and HEFT_RT is
compared against round-robin / least-loaded / random dispatch on achieved
throughput and latency.  The hot path is fabric-batched (see
:mod:`repro.sched_integration.fabric`): the (N, P) exec matrix comes from
one vectorized roofline op, the tick loop jumps to the next arrival's event
horizon instead of spinning empty scheduler ticks, and each mapping event
commits its assignments with vectorized per-replica chains.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core import heft_rt_numpy
from repro.sched_integration.fabric import make_policy_fabric, service_time_matrix
from repro.sched_integration.topology import migration_bytes, parse_link_target

_INF = float("inf")


@dataclass(frozen=True)
class Replica:
    """One PE of the serving fleet.

    ``compute_tflops`` / ``hbm_gbps`` are the replica's *aggregate* effective
    rates (per-chip rate × mesh size × MFU).  The optional mesh backing
    (``arch`` + ``mesh_shape``, a slice of one device pool — see
    ``repro.launch.mesh.slice_device_pool``) keys the replica into the
    dry-run cost-model registry so its Exec_TID column comes from measured
    FLOPs/bytes instead of the analytic roofline; ``ici_gbps`` > 0
    additionally charges the cell's collective wire bytes.

    ``slots`` is the continuous-batching twin of ``ServeEngine.start_paged``
    (``max_batch``): the replica serves up to ``slots`` requests
    concurrently, each on its own FIFO chain, and the scheduler-facing
    availability register is the *earliest-free chain*.  ``slots=1`` (the
    default) is bit-identical to the original single-chain simulator.
    """

    name: str
    compute_tflops: float      # effective bf16 throughput (MFU-adjusted)
    hbm_gbps: float            # effective memory bandwidth
    arch: str | None = None              # cost-model key: architecture name
    mesh_shape: tuple[int, ...] | None = None   # cost-model key: mesh slice
    ici_gbps: float = 0.0                # interconnect rate for wire bytes
    slots: int = 1                       # concurrent batch slots (paged serve)


@dataclass(frozen=True)
class Request:
    rid: int
    arrival: float
    prefill_tokens: int
    decode_tokens: int


def service_time_s(req: Request, rep: Replica, *, active_params: float) -> float:
    """Roofline estimate: prefill compute-bound, decode bandwidth-bound."""
    prefill_flops = 2.0 * active_params * req.prefill_tokens
    decode_bytes = 2.0 * active_params * req.decode_tokens  # weights/token
    return (prefill_flops / (rep.compute_tflops * 1e12)
            + decode_bytes / (rep.hbm_gbps * 1e9))


def make_requests(rate_rps, duration_s: float, seed: int = 0,
                  prefill_range=(128, 4096), decode_range=(16, 512)):
    """Poisson arrivals at ``rate_rps`` — a constant, or a ``rate(t)``
    callable for time-varying load (a constant draws identically to the
    pre-callable version; ``fleet.make_spike_requests`` builds spikes on
    top of this).  The rate must stay positive — model a quiet interval
    with a small positive rate, not zero (the exponential gap would be
    infinite)."""
    rate = rate_rps if callable(rate_rps) else (lambda t: rate_rps)
    rng = np.random.default_rng(seed)
    t, out, rid = 0.0, [], 0
    while True:
        r = float(rate(t))
        if r <= 0.0:
            raise ValueError(
                f"rate(t={t:.3f}) = {r} — arrival rates must be positive "
                f"(use a small rate for quiet intervals, not zero)")
        t += rng.exponential(1.0 / r)
        if t > duration_s:
            break
        out.append(Request(
            rid, t,
            int(rng.integers(*prefill_range)),
            int(rng.integers(*decode_range))))
        rid += 1
    return out


# ---------------------------------------------------------------------------
# dispatch policies: (exec_times (n,P), avail (P,)) -> assignment (n,)
# ---------------------------------------------------------------------------

def policy_heft_rt(exec_times, avail):
    """Reference HEFT_RT policy through the unbatched numpy oracle."""
    avg = exec_times.mean(axis=1)
    order, assignment, _, _, _ = heft_rt_numpy(avg, exec_times, avail)
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    out[order] = assignment
    return out


def make_policy_round_robin():
    state = {"next": 0}

    def policy(exec_times, avail):
        n, P = exec_times.shape
        out = (state["next"] + np.arange(n, dtype=np.int64)) % P
        state["next"] += n
        return out
    return policy


def policy_least_loaded(exec_times, avail):
    av = avail.copy()
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    for i in range(exec_times.shape[0]):
        p = int(np.argmin(av))
        out[i] = p
        av[p] += exec_times[i, p]
    return out


def make_policy_random(seed=0):
    rng = np.random.default_rng(seed)

    def policy(exec_times, avail):
        n, P = exec_times.shape
        return rng.integers(0, P, n).astype(np.int64)
    return policy


POLICIES = {
    "heft_rt": make_policy_fabric,   # fabric front-end, oracle-identical
    "round_robin": make_policy_round_robin,
    "least_loaded": lambda: policy_least_loaded,
    "random": make_policy_random,
}


@dataclass
class ServeResult:
    offered_rps: float
    achieved_rps: float
    p50_latency: float
    p99_latency: float
    mean_latency: float
    replica_util: np.ndarray
    served_mask: np.ndarray | None = None   # per-request served flags (N,)
    requeued: np.ndarray | None = None      # per-request re-queue counts (N,)
    finish_times: np.ndarray | None = None  # per-request finish (NaN: unserved)
    final_avail: np.ndarray | None = None   # final-roster T_avail horizons (P,)


def simulate_serving(replicas: list[Replica], requests: list[Request],
                     policy, *, active_params: float,
                     sched_tick_s: float = 0.005,
                     exec_matrix: np.ndarray | None = None,
                     cost_registry=None,
                     fleet_events=None,
                     failure_events=None,
                     topology=None,
                     retry_budget: int = 3,
                     controller=None,
                     tracer=None,
                     metrics=None) -> ServeResult:
    """Tick-based continuous dispatch, event-horizon-driven: at every tick
    with arrived work, the ready queue is mapped by ``policy`` onto replica
    queues and committed in one vectorized pass; ticks with no ready work
    fast-forward to the next arrival's tick.

    ``exec_matrix`` overrides the roofline estimates with an explicit (N, P)
    matrix aligned with ``requests`` (rows of ``+inf`` mark requests no
    replica can serve; those are reported unserved rather than committed).
    ``cost_registry`` (a
    :class:`~repro.sched_integration.cost_model.CostModelRegistry`) derives
    the Exec_TID matrix from dry-run cost cells for mesh-backed replicas,
    with the roofline as fallback for uncovered (arch × mesh) cells.

    Elastic fleet: ``fleet_events`` is a timeline of
    :class:`~repro.sched_integration.fleet.ResizeEvent`s (replicas join /
    leave / split / merge at their event times); ``controller`` (a
    :class:`~repro.sched_integration.fleet.FleetController`) closes the loop
    instead, observing (queue depth, p95 latency) at each mapping event and
    emitting resizes live.  Both recompute the Exec_TID columns for the new
    fleet mid-run — from ``cost_registry`` when given (joiners with never-
    dry-run shapes get ``scaled_cell``-projected cells via
    ``ensure_coverage``), roofline otherwise — and both are incompatible
    with a pinned ``exec_matrix``.  An empty/None timeline leaves every code
    path untouched: results are bit-identical to the fixed-fleet simulator.
    Removal is drain-then-leave (committed work finishes; no new
    assignments).  With an elastic fleet, ``replica_util`` covers the final
    roster.

    Chaos tier: ``failure_events`` is a timeline of
    :class:`~repro.sched_integration.fleet.FailureEvent`s beside the resize
    timeline — ``replica_loss`` kills a replica instantly (its unfinished
    work, mid-decode included, re-queues through the mapping policy with no
    budget check: losses are never dropped), ``straggler`` slows a replica
    ×factor for a window (exec column, queue horizon, and in-flight
    starts/finishes stretch around the event time, then restore bit-exact
    from the cost model at the window's end), and ``link_degrade`` /
    ``link_partition`` drive an attached
    :class:`~repro.sched_integration.topology.Topology` (partitioned
    replicas' columns mask to ``+inf`` for the window — in-flight work keeps
    running, new admissions divert).  Like resizes, failures apply lazily at
    the next mapping event at or after their ``t``; failures striking after
    the last dispatch are drained against in-flight work and their re-queues
    re-enter the dispatch loop.  ``topology`` additionally charges each
    joining replica's migration (``migration_bytes(active_params)`` from the
    gateway to its pod, with link contention) as its initial queue horizon.
    Straggler *remap* is controller-driven: a controller with a finite
    ``straggler_factor`` observes per-replica backlogs each mapping event
    and flagged replicas' not-yet-started work re-queues, bounded per
    request by ``retry_budget``.  An empty/None failure timeline leaves
    every code path untouched — bit-identical to the failure-free
    simulator.

    Recovery is *provable*, not assumed: the end-of-run invariant check
    raises unless ``commits - requeues == served`` and every unserved
    request holds no assignment — ``served_mask`` + ``requeued`` +
    unserved account for the request set exactly, so a silently dropped
    request is a crash, not a statistic.

    Observability: ``tracer`` (a :class:`repro.obs.Tracer`) gets a
    ``serve.queue_depth`` counter timeline stamped at each mapping event's
    *simulated* time plus ``serve.resize`` / ``serve.failure`` /
    ``serve.recovery`` / ``serve.requeue`` instants; ``metrics`` (a
    :class:`repro.obs.MetricsRegistry`) gets mapping-event / commit
    counters, ``serve.failures`` / ``serve.retries`` (labeled by kind /
    cause), and, at the end, per-replica busy/idle utilization gauges and
    served/unserved counts.  Both only *read* simulator state — the
    ``ServeResult`` is bit-identical with or without them.
    """
    replicas = list(replicas)
    P = len(replicas)
    N = len(requests)
    arrivals = np.array([r.arrival for r in requests])
    events = sorted(fleet_events, key=lambda e: e.t) if fleet_events else []
    fails = sorted(failure_events, key=lambda e: e.t) if failure_events else []
    elastic = bool(events) or controller is not None
    dynamic = elastic or bool(fails)
    if fails and topology is None and any(
            e.kind in ("link_degrade", "link_partition") for e in fails):
        raise ValueError(
            "link_degrade/link_partition failure events need a topology — "
            "pass simulate_serving(topology=...)")
    if exec_matrix is not None:
        if elastic:
            raise ValueError(
                "fleet_events/controller recompute Exec_TID columns as the "
                "fleet resizes — use cost_registry or the roofline, not a "
                "pinned exec_matrix")
        if any(e.kind != "replica_loss" for e in fails):
            raise ValueError(
                "straggler/link failure events restore Exec_TID columns "
                "from the cost model — use cost_registry or the roofline, "
                "not a pinned exec_matrix")
        ex_all = np.asarray(exec_matrix, dtype=np.float64)
    elif cost_registry is not None:
        ex_all = cost_registry.exec_tid_matrix(requests, replicas,
                                               active_params=active_params)
    else:
        ex_all = service_time_matrix(requests, replicas,
                                     active_params=active_params)
    by_arrival = np.argsort(arrivals, kind="stable")
    arr_sorted = arrivals[by_arrival]

    tick = sched_tick_s
    end = float(arrivals.max()) + 1.0
    guard_end = end + 3600.0                     # runaway-clock guard horizon

    # Per-replica slot chains (Replica.slots concurrent FIFO chains — the
    # simulator twin of the paged engine's batch slots).  ``free_at`` stays
    # the scheduler-facing availability register = min over the replica's
    # chains; at slots=1 every operation below degenerates to the original
    # single-chain arithmetic bit-for-bit.
    slot_free = [[0.0] * max(int(r.slots), 1) for r in replicas]
    free_at = [0.0] * P                          # per-replica queue horizon
    busy = [0.0] * P
    finish_all = np.full(N, np.nan)              # per-request finish (NaN: unserved)
    ready: list[int] = []                        # request indices awaiting dispatch
    done_lat: list[tuple[float, float]] = []     # (commit_t, latency) window
    # Only pay for the p95 signal when the controller consults it, and keep
    # it *windowed*: a cumulative percentile would latch "overloaded"
    # forever after one spike (and grow O(N log N) per mapping event).
    ctl_cfg = getattr(controller, "cfg", None)
    p95_enabled = (controller is not None
                   and np.isfinite(getattr(ctl_cfg, "grow_p95_s", np.inf)))
    p95_window_s = float(getattr(ctl_cfg, "p95_window_s", 5.0) or 5.0)
    idx = 0
    t = 0.0

    # Unified event queue: scripted resizes, the failure timeline, and the
    # recovery events windowed failures push at apply time, all popped in
    # (t, insertion) order — at equal t resizes apply before failures, and
    # both apply lazily at the next mapping event (commits only happen
    # there, so the timelines are equivalent).
    evq: list[tuple[float, int, str, object]] = []
    ev_seq = 0
    for e in events:
        heapq.heappush(evq, (float(e.t), ev_seq, "resize", e))
        ev_seq += 1
    for e in fails:
        heapq.heappush(evq, (float(e.t), ev_seq, "fail", e))
        ev_seq += 1

    # Per-request recovery accounting — the end-of-run invariant's books.
    assigned_name: list[str | None] = [None] * N   # committed-to replica
    start_all = np.full(N, np.nan)                 # committed start times
    requeued_ct = np.zeros(N, dtype=np.int64)      # per-request re-queues
    commits_total = 0
    requeues_total = 0
    strag_factors: dict[str, list[float]] = {}     # active straggler windows
    masked: set[str] = set()                       # partition-unreachable
    lost_at: dict[str, float] = {}                 # replica_loss instants

    def _exec_column(rep):
        # Exec_TID columns are independent per replica, so a resize only
        # touches the added/removed columns — bitwise identical to a full
        # recompute, without the O(N·P) cost per event.
        if cost_registry is not None:
            return cost_registry.exec_tid_matrix(
                requests, [rep], active_params=active_params)
        return service_time_matrix(requests, [rep],
                                   active_params=active_params)

    def _apply(e):
        nonlocal ex_all
        for name in e.remove:
            i = next((j for j, r in enumerate(replicas) if r.name == name),
                     None)
            if i is None:
                raise ValueError(
                    f"resize event at t={e.t}: no replica named {name!r} "
                    f"in {[r.name for r in replicas]}")
            replicas.pop(i)
            free_at.pop(i)
            slot_free.pop(i)
            busy.pop(i)
            ex_all = np.delete(ex_all, i, axis=1)
        for rep in e.add:
            if cost_registry is not None:
                cost_registry.ensure_coverage(rep)
            replicas.append(rep)
            horizon = 0.0
            if topology is not None and topology.gateway is not None:
                pod = topology.pod_of.get(rep.name)
                if pod is not None:
                    # Topology-derived join: the joiner's params migrate
                    # gateway → pod over contended links, so its horizon
                    # opens at the transfer's finish instead of instantly.
                    _, horizon = topology.transfer_s(
                        migration_bytes(active_params), topology.gateway,
                        pod, at=t)
            free_at.append(horizon)
            slot_free.append([horizon] * max(int(rep.slots), 1))
            busy.append(0.0)
            lost_at.pop(rep.name, None)    # a re-used name is a new replica
            ex_all = np.concatenate([ex_all, _exec_column(rep)], axis=1)
            if (topology is not None
                    and not topology.replica_reachable(rep.name, at=t)):
                masked.add(rep.name)
                ex_all[:, len(replicas) - 1] = _INF
        if not replicas:
            raise ValueError(f"resize event at t={e.t} left the fleet empty")
        if tracer is not None:
            tracer.instant("serve.resize", ts_us=t * 1e6,
                           add=[r.name for r in e.add], remove=list(e.remove),
                           fleet=len(replicas))

    def _rep_index(name):
        return next((j for j, r in enumerate(replicas) if r.name == name),
                    None)

    def _refresh_column(i):
        # Recompose replica i's Exec_TID column from its live chaos state:
        # cost-model base × active straggler factors, +inf while
        # partition-masked.  Bit-exact restore once all windows close.
        rep = replicas[i]
        if rep.name in masked:
            ex_all[:, i] = _INF
            return
        col = _exec_column(rep)[:, 0]
        for fac in strag_factors.get(rep.name, ()):
            col = col * fac
        ex_all[:, i] = col

    def _remask(at):
        # Re-derive the partition mask from topology reachability at `at`
        # and refresh only the columns whose masked state flipped.
        for i, rep in enumerate(replicas):
            want = not topology.replica_reachable(rep.name, at=at)
            if want == (rep.name in masked):
                continue
            (masked.add if want else masked.discard)(rep.name)
            _refresh_column(i)

    def _requeue(rids, cause):
        nonlocal requeues_total
        for rid in rids:
            finish_all[rid] = np.nan
            start_all[rid] = np.nan
            assigned_name[rid] = None
            requeued_ct[rid] += 1
            requeues_total += 1
            ready.append(rid)
        if metrics is not None:
            metrics.counter("serve.retries", cause=cause).inc(len(rids))
        if tracer is not None:
            tracer.instant("serve.requeue", ts_us=t * 1e6, cause=cause,
                           requests=len(rids))

    def _lose_replica(e):
        nonlocal ex_all
        name, tl = e.target, float(e.t)
        lost_at[name] = tl
        # Everything unfinished at the loss instant — mid-decode included,
        # and regardless of whether the replica is still in the roster or
        # already draining — re-queues.  No budget check: never dropped.
        lost = [rid for rid, an in enumerate(assigned_name)
                if an == name and finish_all[rid] > tl]
        if lost:
            _requeue(lost, "replica_loss")
        strag_factors.pop(name, None)
        masked.discard(name)
        i = _rep_index(name)
        if i is not None:
            if len(replicas) == 1:
                raise ValueError(
                    f"replica_loss at t={tl} left the fleet empty")
            replicas.pop(i)
            free_at.pop(i)
            slot_free.pop(i)
            busy.pop(i)
            ex_all = np.delete(ex_all, i, axis=1)
        grown = getattr(controller, "grown", None)
        if grown is not None and name in grown:
            grown.remove(name)      # the controller must not re-shrink it

    def _start_straggler(e):
        # Window active [e.t, e.t + duration): exec column ×factor for new
        # commits; in-flight starts/finishes and the queue horizon stretch
        # around the pivot (work past e.t runs ×factor slower).
        heapq.heappush(evq, (float(e.t) + e.duration_s, _push_seq(),
                             "recover", e))
        i = _rep_index(e.target)
        if i is None:
            return                   # target already left the roster: no-op
        k, pivot, name = e.factor, float(e.t), e.target
        strag_factors.setdefault(name, []).append(k)
        _refresh_column(i)
        for rid, an in enumerate(assigned_name):
            if an != name or not finish_all[rid] > pivot:
                continue
            busy[i] += (k - 1.0) * (finish_all[rid]
                                    - max(start_all[rid], pivot))
            finish_all[rid] = pivot + k * (finish_all[rid] - pivot)
            if start_all[rid] > pivot:
                start_all[rid] = pivot + k * (start_all[rid] - pivot)
        slot_free[i] = [pivot + k * (c - pivot) if c > pivot else c
                        for c in slot_free[i]]
        free_at[i] = min(slot_free[i])

    def _apply_failure(e):
        if tracer is not None:
            tracer.instant("serve.failure", ts_us=t * 1e6, kind=e.kind,
                           target=e.target, reason=e.reason)
        if metrics is not None:
            metrics.counter("serve.failures", kind=e.kind).inc()
        if e.kind == "replica_loss":
            _lose_replica(e)
        elif e.kind == "straggler":
            _start_straggler(e)
        else:
            a, b = parse_link_target(e.target)
            heapq.heappush(evq, (float(e.t) + e.duration_s, _push_seq(),
                                 "recover", e))
            if e.kind == "link_degrade":
                topology.degrade(a, b, e.factor)
            else:
                topology.set_down(a, b, float(e.t) + e.duration_s)
                _remask(at=float(e.t))

    def _apply_recovery(e):
        if tracer is not None:
            tracer.instant("serve.recovery", ts_us=t * 1e6, kind=e.kind,
                           target=e.target)
        tr = float(e.t) + e.duration_s
        if e.kind == "link_degrade":
            topology.restore(*parse_link_target(e.target))
            return
        if e.kind == "link_partition":
            _remask(at=tr)
            return
        # Straggler window closes: un-stretch the portion past tr and
        # restore the exec column bit-exact from the cost model.
        name, k = e.target, e.factor
        facs = strag_factors.get(name)
        if not facs or k not in facs:
            return                   # replica was lost mid-window
        facs.remove(k)
        if not facs:
            strag_factors.pop(name, None)
        i = _rep_index(name)
        if i is None:
            return                   # drained out of the roster mid-window
        for rid, an in enumerate(assigned_name):
            if an != name or not finish_all[rid] > tr:
                continue
            busy[i] -= (1.0 - 1.0 / k) * (finish_all[rid]
                                          - max(start_all[rid], tr))
            finish_all[rid] = tr + (finish_all[rid] - tr) / k
            if start_all[rid] > tr:
                start_all[rid] = tr + (start_all[rid] - tr) / k
        slot_free[i] = [tr + (c - tr) / k if c > tr else c
                        for c in slot_free[i]]
        free_at[i] = min(slot_free[i])
        _refresh_column(i)

    def _push_seq():
        nonlocal ev_seq
        ev_seq += 1
        return ev_seq

    def _apply_event(kind, e):
        if kind == "resize":
            _apply(e)
        elif kind == "fail":
            _apply_failure(e)
        else:
            _apply_recovery(e)

    def _remap_stragglers(flagged):
        # Controller-flagged stragglers: re-queue their *not-yet-started*
        # work (a FIFO-chain suffix — starts are nondecreasing along the
        # chain) onto the healthy fleet, bounded per request by the retry
        # budget; in-flight decode keeps running.
        for name in flagged:
            i = _rep_index(name)
            if i is None:
                continue
            moved = [rid for rid, an in enumerate(assigned_name)
                     if an == name and start_all[rid] > t
                     and requeued_ct[rid] < retry_budget]
            if not moved:
                continue
            mset = set(moved)
            for rid in moved:
                busy[i] -= finish_all[rid] - start_all[rid]
            keep = [finish_all[rid] for rid, an in enumerate(assigned_name)
                    if an == name and rid not in mset]
            _requeue(moved, "straggler")
            if len(slot_free[i]) > 1:
                # A multi-slot chain suffix can't be re-attributed to its
                # chains after the fact — the commit pass doesn't record
                # which chain a request ran on.  Fail loudly rather than
                # silently corrupting the horizon.
                raise ValueError(
                    f"straggler remap is not supported for multi-slot "
                    f"replica {name!r} (slots={len(slot_free[i])})")
            free_at[i] = max(keep, default=0.0)
            slot_free[i] = [free_at[i]]

    # With a failure timeline, the loop stays alive past the last dispatch
    # while timeline/recovery events remain: a loss can strike *in-flight*
    # work after the final commit, and its re-queues re-enter dispatch.
    pending_chaos = bool(fails)
    while idx < N or ready or (pending_chaos and evq):
        t += tick
        # Runaway-clock guard — hoisted so every tick (including empty-ready
        # ticks and stalled backlogs) hits it before any scheduling work.
        if t > guard_end:
            break
        if not ready and idx < N:
            # Event horizon: no backlog, so fast-forward to the next
            # arrival's tick.  The clock still *accumulates* tick-by-tick
            # (bit-identical to the seed simulator's timeline) but the empty
            # ticks do no scheduling work.
            nxt = arr_sorted[idx]
            while t < nxt and t <= guard_end:
                t += tick
            if t > guard_end:
                break
        j = int(np.searchsorted(arr_sorted, t, side="right"))
        if j > idx:
            ready.extend(by_arrival[idx:j].tolist())
            idx = j
        if not ready:
            if idx >= N:
                # Dispatch is done; only the pending chaos timeline keeps
                # the loop alive.  Jump to the next event and apply it
                # against in-flight work — a loss's re-queues repopulate
                # the ready queue and dispatch resumes.
                if not evq:
                    break
                t = max(t, float(evq[0][0]))
                while evq and evq[0][0] <= t:
                    _, _, kind, e = heapq.heappop(evq)
                    _apply_event(kind, e)
            continue

        if dynamic:
            # Scripted timeline first, then the closed-loop controller.
            # Resizes/failures between mapping events apply lazily at the
            # next one — commits only happen here, so the timelines are
            # equivalent.
            while evq and evq[0][0] <= t:
                _, _, kind, e = heapq.heappop(evq)
                _apply_event(kind, e)
            if controller is not None:
                if p95_enabled:
                    # commits arrive in time order: prune the stale prefix
                    cut = 0
                    while (cut < len(done_lat)
                           and done_lat[cut][0] < t - p95_window_s):
                        cut += 1
                    if cut:
                        del done_lat[:cut]
                p95 = (float(np.percentile([l for _, l in done_lat], 95))
                       if p95_enabled and done_lat else 0.0)
                backlog = float(np.mean(np.maximum(
                    np.asarray(free_at) - t, 0.0)))
                ev = controller.observe(t, queue_depth=len(ready),
                                        backlog_s=backlog, p95_s=p95)
                if ev is not None:
                    _apply(ev)
                if hasattr(controller, "observe_stragglers"):
                    # Per-replica backlog rail → controller straggler
                    # detection (threshold × fleet median, per-replica
                    # backoff) → re-queue the flagged replicas' queued work.
                    flagged = controller.observe_stragglers(
                        t, [r.name for r in replicas],
                        [max(f - t, 0.0) for f in free_at])
                    if flagged:
                        _remap_stragglers(flagged)

        if tracer is not None:
            # Queue-depth timeline on the *simulated* clock: Perfetto renders
            # "C" counter samples as a step chart, so one sample per mapping
            # event reconstructs the full backlog curve.
            tracer.counter("serve.queue_depth", ts_us=t * 1e6,
                           depth=len(ready),
                           backlog_s=float(np.mean(np.maximum(
                               np.asarray(free_at) - t, 0.0))))
        if metrics is not None:
            metrics.counter("serve.mapping_events").inc()

        ex = ex_all[ready]
        assignment = policy(ex, np.maximum(free_at, t))
        a_list = np.asarray(assignment).tolist()

        # Commit pass: per-replica FIFO chains in ready order, the same
        # scalar left-fold (max(free_at, t) then += dur) as the seed's
        # sequential loop — bit-identical finish times, no per-request numpy.
        ex_rows = ex.tolist()
        committed = False
        leftovers: list[int] = []
        for k, p in enumerate(a_list):
            # Unassigned (-1) or infinite-exec picks (baseline policies
            # don't check supportability) stay in the backlog instead of
            # permanently poisoning a replica's horizon.
            if p < 0 or ex_rows[k][p] == _INF:
                leftovers.append(ready[k])
                continue
            committed = True
            commits_total += 1
            # Earliest-free slot chain takes the request (first index on
            # ties — deterministic); the availability register becomes the
            # min over chains.  At slots=1 this is exactly the original
            # f = free_at[p]; ...; free_at[p] = fin left-fold.
            chains = slot_free[p]
            j = chains.index(min(chains))
            f = chains[j]
            start = f if f > t else t            # arrivals are all <= t
            fin = start + ex_rows[k][p]
            chains[j] = fin
            free_at[p] = min(chains)
            busy[p] += ex_rows[k][p]
            finish_all[ready[k]] = fin
            start_all[ready[k]] = start
            assigned_name[ready[k]] = replicas[p].name
            if p95_enabled:
                done_lat.append((t, fin - arrivals[ready[k]]))
        if metrics is not None:
            n_committed = len(a_list) - len(leftovers)
            if n_committed:
                metrics.counter("serve.committed").inc(n_committed)
        ready = leftovers

        if not committed:
            # Nothing schedulable this event.  With no arrivals left the
            # backlog can never drain by itself — but a pending scripted
            # resize (or a failure-window recovery unmasking the fleet) may
            # still make it schedulable, so jump to the next event's time
            # instead of giving up; with nothing pending, fast-forward into
            # the guard.  (With arrivals pending the next tick re-maps as
            # usual.)
            if idx >= N:
                if evq:
                    t = max(t, float(evq[0][0]))
                else:
                    t = guard_end
            continue

    served = np.isfinite(finish_all)
    offered = N / (arrivals.max() + 1e-9)

    # Recovery invariant — the "provable" in provable recovery.  Every
    # commit either ends served or was re-queued (so served + requeued +
    # unserved partition the request set exactly), no unserved request
    # still holds an assignment, and no served request outlived its
    # replica's loss instant.  A silently dropped request is a crash here,
    # not a statistic.
    n_served = int(served.sum())
    if commits_total - requeues_total != n_served:
        raise AssertionError(
            f"recovery invariant violated: {commits_total} commits - "
            f"{requeues_total} requeues != {n_served} served")
    orphans = [rid for rid in np.nonzero(~served)[0].tolist()
               if assigned_name[rid] is not None]
    if orphans:
        raise AssertionError(
            f"recovery invariant violated: unserved requests still hold "
            f"assignments: {orphans[:8]}")
    ghosts = [rid for rid in np.nonzero(served)[0].tolist()
              if assigned_name[rid] in lost_at
              and finish_all[rid] > lost_at[assigned_name[rid]]]
    if ghosts:
        raise AssertionError(
            f"recovery invariant violated: served requests outlive their "
            f"replica's loss: {ghosts[:8]}")

    def _final_metrics(util):
        if metrics is None:
            return
        metrics.counter("serve.served").inc(n_served)
        metrics.counter("serve.unserved").inc(N - n_served)
        for rep, u in zip(replicas, util):
            u = float(u)
            metrics.gauge("serve.replica_util", replica=rep.name).set(u)
            metrics.gauge("serve.replica_idle", replica=rep.name).set(1.0 - u)

    if not served.any():
        # Nothing ever scheduled (e.g. an all-+inf exec_matrix): report an
        # empty, well-defined result instead of NaN-percentile crashes.
        _final_metrics(np.zeros(len(replicas)))
        return ServeResult(offered_rps=offered, achieved_rps=0.0,
                           p50_latency=np.nan, p99_latency=np.nan,
                           mean_latency=np.nan,
                           replica_util=np.zeros(len(replicas)),
                           served_mask=served, requeued=requeued_ct,
                           finish_times=finish_all,
                           final_avail=np.asarray(free_at, dtype=float))
    lat = finish_all[served] - arrivals[served]
    span = np.nanmax(finish_all) - arrivals.min()
    _final_metrics(np.array(busy) / span)
    return ServeResult(
        offered_rps=offered,
        achieved_rps=n_served / span,
        p50_latency=float(np.percentile(lat, 50)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        replica_util=np.array(busy) / span,
        served_mask=served,
        requeued=requeued_ct,
        finish_times=finish_all,
        final_avail=np.asarray(free_at, dtype=float),
    )


def goodput(result: ServeResult, requests: list[Request],
            slo_s: float) -> int:
    """Requests served within their SLO deadline (``arrival + slo_s``).

    The chaos tier's acceptance metric: a re-queued request that still
    lands inside its deadline counts; one pushed past it (or never served)
    does not — so goodput under a failure trace measures recovery quality,
    not just liveness.
    """
    arr = np.array([r.arrival for r in requests])
    lat = result.finish_times - arr
    with np.errstate(invalid="ignore"):          # NaN finish = not served
        return int(np.sum(result.served_mask & (lat <= slo_s)))


def default_fleet() -> list[Replica]:
    """A heterogeneous fleet: two v5e pods, one older-gen pod, one small pod.

    Effective rates assume ~50% MFU prefill / ~60% of HBM streaming decode
    (per-chip 197 TF, 819 GB/s scaled by pod size).
    """
    return [
        Replica("v5e-256", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v5e-256b", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v4-128", 128 * 275e0 * 0.4, 128 * 1200 * 0.5),
        Replica("v5e-64", 64 * 197e0 * 0.5, 64 * 819 * 0.6),
    ]


def mesh_fleet(arch: str = "deepseek-7b",
               mesh_shapes=((16, 16), (16, 16), (4, 16), (4, 4)),
               *, chip_tflops: float = 197.0, chip_hbm_gbps: float = 819.0,
               ici_gbps: float = 0.0, slots: int = 1,
               mfu: float = 0.5, hbm_eff: float = 0.6) -> list[Replica]:
    """A heterogeneous *mesh-backed* fleet: same-generation chips carved into
    mixed mesh slices (the serving analogue of the paper's non-uniform PEs).
    Aggregate rates scale with slice size; ``arch`` + each slice shape key
    the replicas into the cost-model registry.  ``slots`` gives every
    replica that many concurrent batch slots (continuous batching twin).
    """
    import math

    fleet = []
    for i, shape in enumerate(mesh_shapes):
        shape = tuple(int(d) for d in shape)
        n = math.prod(shape)
        fleet.append(Replica(
            f"{arch}@{'x'.join(map(str, shape))}#{i}",
            n * chip_tflops * mfu, n * chip_hbm_gbps * hbm_eff,
            arch=arch, mesh_shape=shape, ici_gbps=ici_gbps, slots=slots))
    return fleet
