"""HEFT_RT as an LLM-serving request scheduler over heterogeneous replicas.

The paper's scenario — dynamically arriving jobs mapped onto PEs with
non-uniform speeds by a low-latency scheduler — is exactly the serving
front-end problem for a fleet of heterogeneous model replicas (mixed pod
sizes / chip generations / MFU profiles).  Requests are tasks; replicas are
PEs; ``Exec[r, p]`` is the roofline-model estimate of request r's service
time on replica p (prefill FLOPs / replica compute + decode bytes / replica
bandwidth); ``T_avail`` is each replica's queue horizon.

``simulate_serving`` runs the oversubscription experiment (paper Figs 5/6
transplanted): offered load sweeps past fleet capacity, and HEFT_RT is
compared against round-robin / least-loaded / random dispatch on achieved
throughput and latency.  The hot path is fabric-batched (see
:mod:`repro.sched_integration.fabric`): the (N, P) exec matrix comes from
one vectorized roofline op, the tick loop jumps to the next arrival's event
horizon instead of spinning empty scheduler ticks, and each mapping event
commits its assignments with vectorized per-replica chains.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import heft_rt_numpy
from repro.sched_integration.fabric import make_policy_fabric, service_time_matrix

_INF = float("inf")


@dataclass(frozen=True)
class Replica:
    """One PE of the serving fleet.

    ``compute_tflops`` / ``hbm_gbps`` are the replica's *aggregate* effective
    rates (per-chip rate × mesh size × MFU).  The optional mesh backing
    (``arch`` + ``mesh_shape``, a slice of one device pool — see
    ``repro.launch.mesh.slice_device_pool``) keys the replica into the
    dry-run cost-model registry so its Exec_TID column comes from measured
    FLOPs/bytes instead of the analytic roofline; ``ici_gbps`` > 0
    additionally charges the cell's collective wire bytes.
    """

    name: str
    compute_tflops: float      # effective bf16 throughput (MFU-adjusted)
    hbm_gbps: float            # effective memory bandwidth
    arch: str | None = None              # cost-model key: architecture name
    mesh_shape: tuple[int, ...] | None = None   # cost-model key: mesh slice
    ici_gbps: float = 0.0                # interconnect rate for wire bytes


@dataclass(frozen=True)
class Request:
    rid: int
    arrival: float
    prefill_tokens: int
    decode_tokens: int


def service_time_s(req: Request, rep: Replica, *, active_params: float) -> float:
    """Roofline estimate: prefill compute-bound, decode bandwidth-bound."""
    prefill_flops = 2.0 * active_params * req.prefill_tokens
    decode_bytes = 2.0 * active_params * req.decode_tokens  # weights/token
    return (prefill_flops / (rep.compute_tflops * 1e12)
            + decode_bytes / (rep.hbm_gbps * 1e9))


def make_requests(rate_rps, duration_s: float, seed: int = 0,
                  prefill_range=(128, 4096), decode_range=(16, 512)):
    """Poisson arrivals at ``rate_rps`` — a constant, or a ``rate(t)``
    callable for time-varying load (a constant draws identically to the
    pre-callable version; ``fleet.make_spike_requests`` builds spikes on
    top of this).  The rate must stay positive — model a quiet interval
    with a small positive rate, not zero (the exponential gap would be
    infinite)."""
    rate = rate_rps if callable(rate_rps) else (lambda t: rate_rps)
    rng = np.random.default_rng(seed)
    t, out, rid = 0.0, [], 0
    while True:
        r = float(rate(t))
        if r <= 0.0:
            raise ValueError(
                f"rate(t={t:.3f}) = {r} — arrival rates must be positive "
                f"(use a small rate for quiet intervals, not zero)")
        t += rng.exponential(1.0 / r)
        if t > duration_s:
            break
        out.append(Request(
            rid, t,
            int(rng.integers(*prefill_range)),
            int(rng.integers(*decode_range))))
        rid += 1
    return out


# ---------------------------------------------------------------------------
# dispatch policies: (exec_times (n,P), avail (P,)) -> assignment (n,)
# ---------------------------------------------------------------------------

def policy_heft_rt(exec_times, avail):
    """Reference HEFT_RT policy through the unbatched numpy oracle."""
    avg = exec_times.mean(axis=1)
    order, assignment, _, _, _ = heft_rt_numpy(avg, exec_times, avail)
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    out[order] = assignment
    return out


def make_policy_round_robin():
    state = {"next": 0}

    def policy(exec_times, avail):
        n, P = exec_times.shape
        out = (state["next"] + np.arange(n, dtype=np.int64)) % P
        state["next"] += n
        return out
    return policy


def policy_least_loaded(exec_times, avail):
    av = avail.copy()
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    for i in range(exec_times.shape[0]):
        p = int(np.argmin(av))
        out[i] = p
        av[p] += exec_times[i, p]
    return out


def make_policy_random(seed=0):
    rng = np.random.default_rng(seed)

    def policy(exec_times, avail):
        n, P = exec_times.shape
        return rng.integers(0, P, n).astype(np.int64)
    return policy


POLICIES = {
    "heft_rt": make_policy_fabric,   # fabric front-end, oracle-identical
    "round_robin": make_policy_round_robin,
    "least_loaded": lambda: policy_least_loaded,
    "random": make_policy_random,
}


@dataclass
class ServeResult:
    offered_rps: float
    achieved_rps: float
    p50_latency: float
    p99_latency: float
    mean_latency: float
    replica_util: np.ndarray
    served_mask: np.ndarray | None = None   # per-request served flags (N,)


def simulate_serving(replicas: list[Replica], requests: list[Request],
                     policy, *, active_params: float,
                     sched_tick_s: float = 0.005,
                     exec_matrix: np.ndarray | None = None,
                     cost_registry=None,
                     fleet_events=None,
                     controller=None,
                     tracer=None,
                     metrics=None) -> ServeResult:
    """Tick-based continuous dispatch, event-horizon-driven: at every tick
    with arrived work, the ready queue is mapped by ``policy`` onto replica
    queues and committed in one vectorized pass; ticks with no ready work
    fast-forward to the next arrival's tick.

    ``exec_matrix`` overrides the roofline estimates with an explicit (N, P)
    matrix aligned with ``requests`` (rows of ``+inf`` mark requests no
    replica can serve; those are reported unserved rather than committed).
    ``cost_registry`` (a
    :class:`~repro.sched_integration.cost_model.CostModelRegistry`) derives
    the Exec_TID matrix from dry-run cost cells for mesh-backed replicas,
    with the roofline as fallback for uncovered (arch × mesh) cells.

    Elastic fleet: ``fleet_events`` is a timeline of
    :class:`~repro.sched_integration.fleet.ResizeEvent`s (replicas join /
    leave / split / merge at their event times); ``controller`` (a
    :class:`~repro.sched_integration.fleet.FleetController`) closes the loop
    instead, observing (queue depth, p95 latency) at each mapping event and
    emitting resizes live.  Both recompute the Exec_TID columns for the new
    fleet mid-run — from ``cost_registry`` when given (joiners with never-
    dry-run shapes get ``scaled_cell``-projected cells via
    ``ensure_coverage``), roofline otherwise — and both are incompatible
    with a pinned ``exec_matrix``.  An empty/None timeline leaves every code
    path untouched: results are bit-identical to the fixed-fleet simulator.
    Removal is drain-then-leave (committed work finishes; no new
    assignments).  With an elastic fleet, ``replica_util`` covers the final
    roster.

    Observability: ``tracer`` (a :class:`repro.obs.Tracer`) gets a
    ``serve.queue_depth`` counter timeline stamped at each mapping event's
    *simulated* time plus ``serve.resize`` instants; ``metrics`` (a
    :class:`repro.obs.MetricsRegistry`) gets mapping-event / commit counters
    and, at the end, per-replica busy/idle utilization gauges and
    served/unserved counts.  Both only *read* simulator state — the
    ``ServeResult`` is bit-identical with or without them.
    """
    replicas = list(replicas)
    P = len(replicas)
    N = len(requests)
    arrivals = np.array([r.arrival for r in requests])
    events = sorted(fleet_events, key=lambda e: e.t) if fleet_events else []
    elastic = bool(events) or controller is not None
    if exec_matrix is not None:
        if elastic:
            raise ValueError(
                "fleet_events/controller recompute Exec_TID columns as the "
                "fleet resizes — use cost_registry or the roofline, not a "
                "pinned exec_matrix")
        ex_all = np.asarray(exec_matrix, dtype=np.float64)
    elif cost_registry is not None:
        ex_all = cost_registry.exec_tid_matrix(requests, replicas,
                                               active_params=active_params)
    else:
        ex_all = service_time_matrix(requests, replicas,
                                     active_params=active_params)
    by_arrival = np.argsort(arrivals, kind="stable")
    arr_sorted = arrivals[by_arrival]

    tick = sched_tick_s
    end = float(arrivals.max()) + 1.0
    guard_end = end + 3600.0                     # runaway-clock guard horizon

    free_at = [0.0] * P                          # per-replica queue horizon
    busy = [0.0] * P
    finish_all = np.full(N, np.nan)              # per-request finish (NaN: unserved)
    ready: list[int] = []                        # request indices awaiting dispatch
    done_lat: list[tuple[float, float]] = []     # (commit_t, latency) window
    # Only pay for the p95 signal when the controller consults it, and keep
    # it *windowed*: a cumulative percentile would latch "overloaded"
    # forever after one spike (and grow O(N log N) per mapping event).
    ctl_cfg = getattr(controller, "cfg", None)
    p95_enabled = (controller is not None
                   and np.isfinite(getattr(ctl_cfg, "grow_p95_s", np.inf)))
    p95_window_s = float(getattr(ctl_cfg, "p95_window_s", 5.0) or 5.0)
    idx = 0
    t = 0.0
    ev_i = 0

    def _exec_column(rep):
        # Exec_TID columns are independent per replica, so a resize only
        # touches the added/removed columns — bitwise identical to a full
        # recompute, without the O(N·P) cost per event.
        if cost_registry is not None:
            return cost_registry.exec_tid_matrix(
                requests, [rep], active_params=active_params)
        return service_time_matrix(requests, [rep],
                                   active_params=active_params)

    def _apply(e):
        nonlocal ex_all
        for name in e.remove:
            i = next((j for j, r in enumerate(replicas) if r.name == name),
                     None)
            if i is None:
                raise ValueError(
                    f"resize event at t={e.t}: no replica named {name!r} "
                    f"in {[r.name for r in replicas]}")
            replicas.pop(i)
            free_at.pop(i)
            busy.pop(i)
            ex_all = np.delete(ex_all, i, axis=1)
        for rep in e.add:
            if cost_registry is not None:
                cost_registry.ensure_coverage(rep)
            replicas.append(rep)
            free_at.append(0.0)
            busy.append(0.0)
            ex_all = np.concatenate([ex_all, _exec_column(rep)], axis=1)
        if not replicas:
            raise ValueError(f"resize event at t={e.t} left the fleet empty")
        if tracer is not None:
            tracer.instant("serve.resize", ts_us=t * 1e6,
                           add=[r.name for r in e.add], remove=list(e.remove),
                           fleet=len(replicas))

    while idx < N or ready:
        t += tick
        # Runaway-clock guard — hoisted so every tick (including empty-ready
        # ticks and stalled backlogs) hits it before any scheduling work.
        if t > guard_end:
            break
        if not ready and idx < N:
            # Event horizon: no backlog, so fast-forward to the next
            # arrival's tick.  The clock still *accumulates* tick-by-tick
            # (bit-identical to the seed simulator's timeline) but the empty
            # ticks do no scheduling work.
            nxt = arr_sorted[idx]
            while t < nxt and t <= guard_end:
                t += tick
            if t > guard_end:
                break
        j = int(np.searchsorted(arr_sorted, t, side="right"))
        if j > idx:
            ready.extend(by_arrival[idx:j].tolist())
            idx = j
        if not ready:
            continue

        if elastic:
            # Scripted timeline first, then the closed-loop controller.
            # Resizes between mapping events apply lazily at the next one —
            # commits only happen here, so the timelines are equivalent.
            while ev_i < len(events) and events[ev_i].t <= t:
                _apply(events[ev_i])
                ev_i += 1
            if controller is not None:
                if p95_enabled:
                    # commits arrive in time order: prune the stale prefix
                    cut = 0
                    while (cut < len(done_lat)
                           and done_lat[cut][0] < t - p95_window_s):
                        cut += 1
                    if cut:
                        del done_lat[:cut]
                p95 = (float(np.percentile([l for _, l in done_lat], 95))
                       if p95_enabled and done_lat else 0.0)
                backlog = float(np.mean(np.maximum(
                    np.asarray(free_at) - t, 0.0)))
                ev = controller.observe(t, queue_depth=len(ready),
                                        backlog_s=backlog, p95_s=p95)
                if ev is not None:
                    _apply(ev)

        if tracer is not None:
            # Queue-depth timeline on the *simulated* clock: Perfetto renders
            # "C" counter samples as a step chart, so one sample per mapping
            # event reconstructs the full backlog curve.
            tracer.counter("serve.queue_depth", ts_us=t * 1e6,
                           depth=len(ready),
                           backlog_s=float(np.mean(np.maximum(
                               np.asarray(free_at) - t, 0.0))))
        if metrics is not None:
            metrics.counter("serve.mapping_events").inc()

        ex = ex_all[ready]
        assignment = policy(ex, np.maximum(free_at, t))
        a_list = np.asarray(assignment).tolist()

        # Commit pass: per-replica FIFO chains in ready order, the same
        # scalar left-fold (max(free_at, t) then += dur) as the seed's
        # sequential loop — bit-identical finish times, no per-request numpy.
        ex_rows = ex.tolist()
        committed = False
        leftovers: list[int] = []
        for k, p in enumerate(a_list):
            # Unassigned (-1) or infinite-exec picks (baseline policies
            # don't check supportability) stay in the backlog instead of
            # permanently poisoning a replica's horizon.
            if p < 0 or ex_rows[k][p] == _INF:
                leftovers.append(ready[k])
                continue
            committed = True
            f = free_at[p]
            start = f if f > t else t            # arrivals are all <= t
            fin = start + ex_rows[k][p]
            free_at[p] = fin
            busy[p] += ex_rows[k][p]
            finish_all[ready[k]] = fin
            if p95_enabled:
                done_lat.append((t, fin - arrivals[ready[k]]))
        if metrics is not None:
            n_committed = len(a_list) - len(leftovers)
            if n_committed:
                metrics.counter("serve.committed").inc(n_committed)
        ready = leftovers

        if not committed:
            # Nothing schedulable this event.  With no arrivals left the
            # backlog can never drain by itself — but a pending scripted
            # resize may still make it schedulable, so jump to the next
            # event's time instead of giving up; with nothing pending,
            # fast-forward into the guard.  (With arrivals pending the next
            # tick re-maps as usual.)
            if idx >= N:
                if ev_i < len(events):
                    t = max(t, float(events[ev_i].t))
                else:
                    t = guard_end
            continue

    served = np.isfinite(finish_all)
    offered = N / (arrivals.max() + 1e-9)

    def _final_metrics(util):
        if metrics is None:
            return
        n_served = int(served.sum())
        metrics.counter("serve.served").inc(n_served)
        metrics.counter("serve.unserved").inc(N - n_served)
        for rep, u in zip(replicas, util):
            u = float(u)
            metrics.gauge("serve.replica_util", replica=rep.name).set(u)
            metrics.gauge("serve.replica_idle", replica=rep.name).set(1.0 - u)

    if not served.any():
        # Nothing ever scheduled (e.g. an all-+inf exec_matrix): report an
        # empty, well-defined result instead of NaN-percentile crashes.
        _final_metrics(np.zeros(len(replicas)))
        return ServeResult(offered_rps=offered, achieved_rps=0.0,
                           p50_latency=np.nan, p99_latency=np.nan,
                           mean_latency=np.nan,
                           replica_util=np.zeros(len(replicas)),
                           served_mask=served)
    lat = finish_all[served] - arrivals[served]
    span = np.nanmax(finish_all) - arrivals.min()
    _final_metrics(np.array(busy) / span)
    return ServeResult(
        offered_rps=offered,
        achieved_rps=int(served.sum()) / span,
        p50_latency=float(np.percentile(lat, 50)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        replica_util=np.array(busy) / span,
        served_mask=served,
    )


def default_fleet() -> list[Replica]:
    """A heterogeneous fleet: two v5e pods, one older-gen pod, one small pod.

    Effective rates assume ~50% MFU prefill / ~60% of HBM streaming decode
    (per-chip 197 TF, 819 GB/s scaled by pod size).
    """
    return [
        Replica("v5e-256", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v5e-256b", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v4-128", 128 * 275e0 * 0.4, 128 * 1200 * 0.5),
        Replica("v5e-64", 64 * 197e0 * 0.5, 64 * 819 * 0.6),
    ]


def mesh_fleet(arch: str = "deepseek-7b",
               mesh_shapes=((16, 16), (16, 16), (4, 16), (4, 4)),
               *, chip_tflops: float = 197.0, chip_hbm_gbps: float = 819.0,
               ici_gbps: float = 0.0,
               mfu: float = 0.5, hbm_eff: float = 0.6) -> list[Replica]:
    """A heterogeneous *mesh-backed* fleet: same-generation chips carved into
    mixed mesh slices (the serving analogue of the paper's non-uniform PEs).
    Aggregate rates scale with slice size; ``arch`` + each slice shape key
    the replicas into the cost-model registry.
    """
    import math

    fleet = []
    for i, shape in enumerate(mesh_shapes):
        shape = tuple(int(d) for d in shape)
        n = math.prod(shape)
        fleet.append(Replica(
            f"{arch}@{'x'.join(map(str, shape))}#{i}",
            n * chip_tflops * mfu, n * chip_hbm_gbps * hbm_eff,
            arch=arch, mesh_shape=shape, ici_gbps=ici_gbps))
    return fleet
