"""HEFT_RT as an LLM-serving request scheduler over heterogeneous replicas.

The paper's scenario — dynamically arriving jobs mapped onto PEs with
non-uniform speeds by a low-latency scheduler — is exactly the serving
front-end problem for a fleet of heterogeneous model replicas (mixed pod
sizes / chip generations / MFU profiles).  Requests are tasks; replicas are
PEs; ``Exec[r, p]`` is the roofline-model estimate of request r's service
time on replica p (prefill FLOPs / replica compute + decode bytes / replica
bandwidth); ``T_avail`` is each replica's queue horizon.

``simulate_serving`` runs the oversubscription experiment (paper Figs 5/6
transplanted): offered load sweeps past fleet capacity, and HEFT_RT is
compared against round-robin / least-loaded / random dispatch on achieved
throughput and latency.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass

import numpy as np

from repro.core import heft_rt_numpy


@dataclass(frozen=True)
class Replica:
    name: str
    compute_tflops: float      # effective bf16 throughput (MFU-adjusted)
    hbm_gbps: float            # effective memory bandwidth


@dataclass(frozen=True)
class Request:
    rid: int
    arrival: float
    prefill_tokens: int
    decode_tokens: int


def service_time_s(req: Request, rep: Replica, *, active_params: float) -> float:
    """Roofline estimate: prefill compute-bound, decode bandwidth-bound."""
    prefill_flops = 2.0 * active_params * req.prefill_tokens
    decode_bytes = 2.0 * active_params * req.decode_tokens  # weights/token
    return (prefill_flops / (rep.compute_tflops * 1e12)
            + decode_bytes / (rep.hbm_gbps * 1e9))


def make_requests(rate_rps: float, duration_s: float, seed: int = 0,
                  prefill_range=(128, 4096), decode_range=(16, 512)):
    rng = np.random.default_rng(seed)
    t, out, rid = 0.0, [], 0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t > duration_s:
            break
        out.append(Request(
            rid, t,
            int(rng.integers(*prefill_range)),
            int(rng.integers(*decode_range))))
        rid += 1
    return out


# ---------------------------------------------------------------------------
# dispatch policies: (exec_times (n,P), avail (P,)) -> assignment (n,)
# ---------------------------------------------------------------------------

def policy_heft_rt(exec_times, avail):
    avg = exec_times.mean(axis=1)
    order, assignment, _, _, _ = heft_rt_numpy(avg, exec_times, avail)
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    out[order] = assignment
    return out


def make_policy_round_robin():
    c = itertools.count()

    def policy(exec_times, avail):
        n, P = exec_times.shape
        return np.array([next(c) % P for _ in range(n)], dtype=np.int64)
    return policy


def policy_least_loaded(exec_times, avail):
    av = avail.copy()
    out = np.empty(exec_times.shape[0], dtype=np.int64)
    for i in range(exec_times.shape[0]):
        p = int(np.argmin(av))
        out[i] = p
        av[p] += exec_times[i, p]
    return out


def make_policy_random(seed=0):
    rng = np.random.default_rng(seed)

    def policy(exec_times, avail):
        n, P = exec_times.shape
        return rng.integers(0, P, n).astype(np.int64)
    return policy


POLICIES = {
    "heft_rt": lambda: policy_heft_rt,
    "round_robin": make_policy_round_robin,
    "least_loaded": lambda: policy_least_loaded,
    "random": make_policy_random,
}


@dataclass
class ServeResult:
    offered_rps: float
    achieved_rps: float
    p50_latency: float
    p99_latency: float
    mean_latency: float
    replica_util: np.ndarray


def simulate_serving(replicas: list[Replica], requests: list[Request],
                     policy, *, active_params: float,
                     sched_tick_s: float = 0.005) -> ServeResult:
    """Tick-based continuous dispatch: every tick, the ready queue of arrived
    requests is mapped by ``policy`` onto replica queues (exec-time matrix
    from the roofline model) and committed."""
    P = len(replicas)
    exec_cache = {}

    def ex_row(req):
        if req.rid not in exec_cache:
            exec_cache[req.rid] = np.array([
                service_time_s(req, r, active_params=active_params)
                for r in replicas])
        return exec_cache[req.rid]

    pending = sorted(requests, key=lambda r: r.arrival)
    idx = 0
    ready: list[Request] = []
    free_at = np.zeros(P)
    busy = np.zeros(P)
    finish_times = {}
    t = 0.0
    end = max(r.arrival for r in requests) + 1.0
    while idx < len(pending) or ready:
        t += sched_tick_s
        while idx < len(pending) and pending[idx].arrival <= t:
            ready.append(pending[idx])
            idx += 1
        if not ready:
            continue
        ex = np.stack([ex_row(r) for r in ready])
        assignment = policy(ex, np.maximum(free_at, t))
        for r, p in zip(ready, assignment):
            start = max(free_at[p], r.arrival, t)
            dur = ex_row(r)[p]
            free_at[p] = start + dur
            busy[p] += dur
            finish_times[r.rid] = free_at[p]
        ready.clear()
        if t > end + 3600:
            break

    lat = np.array([finish_times[r.rid] - r.arrival for r in requests
                    if r.rid in finish_times])
    span = max(finish_times.values()) - min(r.arrival for r in requests)
    offered = len(requests) / (max(r.arrival for r in requests) + 1e-9)
    return ServeResult(
        offered_rps=offered,
        achieved_rps=len(finish_times) / span,
        p50_latency=float(np.percentile(lat, 50)),
        p99_latency=float(np.percentile(lat, 99)),
        mean_latency=float(lat.mean()),
        replica_util=busy / span,
    )


def default_fleet() -> list[Replica]:
    """A heterogeneous fleet: two v5e pods, one older-gen pod, one small pod.

    Effective rates assume ~50% MFU prefill / ~60% of HBM streaming decode
    (per-chip 197 TF, 819 GB/s scaled by pod size).
    """
    return [
        Replica("v5e-256", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v5e-256b", 256 * 197e0 * 0.5, 256 * 819 * 0.6),
        Replica("v4-128", 128 * 275e0 * 0.4, 128 * 1200 * 0.5),
        Replica("v5e-64", 64 * 197e0 * 0.5, 64 * 819 * 0.6),
    ]
