"""MappingFabric — batched, device-resident HEFT_RT dispatch pipeline.

The paper's core observation is that once tasks arrive dynamically, the
*scheduler's own latency* — not schedule quality — gates throughput, which is
why HEFT_RT moves into the FPGA fabric (9.144 ns/decision).  This module is
the TPU-side analogue for the serve/runtime layers: instead of one host
round-trip per mapping event (build a Python exec matrix, call
``heft_rt_numpy``, scatter the result), mapping events are *batched through
the fabric*:

* **Bucketed shapes.**  Ready queues are padded to power-of-two M-buckets
  (``bucket_size``) so the persistent jitted dispatch compiles O(log D_max)
  variants instead of one per queue length.  The PE axis gets the same
  treatment (``p_bucket``): P is *state*, not a constant — ``grow`` /
  ``shrink`` / ``remap`` resize the pool mid-stream carrying committed
  ``T_avail`` bit-exact, and resizes inside a P bucket reuse every compiled
  variant.
* **Device-resident availability registers.**  The jitted dispatch is built
  with ``donate_argnums`` on ``T_avail``, so the availability registers live
  on device across mapping events (the paper's PE-handler register file) and
  the event stream never bounces them through host memory.
* **Selectable backend.**  ``backend="jit"`` runs :func:`repro.core.heft_rt`
  (vmapped for batches); ``backend="pallas"`` runs the fused overlay kernel
  :func:`repro.kernels.heft_rt_hw` (compiled on TPU/GPU, interpret-mode
  fallback elsewhere — logged once and visible via
  :attr:`MappingFabric.backend_effective`); ``backend="fused"`` keeps the
  PE mask device-resident too and exposes its registers to the paged decode
  tick (see :meth:`MappingFabric.tick_decision_inputs`), so the HEFT_RT
  decision can run *inside* the serving tick's compiled program with zero
  host scheduling round-trips (docs/scheduling.md); ``backend="numpy"`` is
  the oracle-exact host fast path used by the discrete-event simulators,
  where events are tiny and sequential.
* **Vectorized roofline front-end.**  :func:`service_time_matrix` computes
  the full (N, P) exec-time matrix in one vectorized op, replacing the
  per-request Python row loop (and unbounded per-rid cache) in the serving
  simulator.

Decision fidelity: all backends make mapping decisions *slot-for-slot
identical* to the :func:`repro.core.heft_rt_numpy` oracle (the repo's Fig. 3
claim) provided exec/avg values are exactly representable in float32 for the
device backends (the numpy backend is exact in float64).  Exec times must lie
in ``[0, +inf]``; an all-``inf`` row marks a task no PE supports (assignment
-1).  ``avg`` entries may be NaN (e.g. ``nanmean`` of an all-inf row): like
the oracle's ``argsort``, NaN-keyed tasks sort behind every finite key, and
always ahead of padding slots.
"""

from __future__ import annotations

import math
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.heft_rt import ScheduleResult, heft_rt
from repro.kernels import decision_hw, heft_rt_hw
from repro.kernels import interpret_default as _interpret_default
from repro.kernels.fused_decision import decision_ref, unpack_decision
from repro.obs.device import (
    NUM_COUNTERS,
    accumulate_counters,
    accumulate_counters_np,
    counters_dict,
    zero_counters,
)
from repro.obs.log import get_logger

_INF = float("inf")

BACKENDS = ("numpy", "jit", "pallas", "fused")

# Pallas-path fabrics warn exactly once per process when the kernels run in
# interpret mode — the fallback is correct but ~1000x slower, and it used to
# be silent (benchmarks "comparing" pallas were really timing the
# interpreter).  ``backend_effective`` exposes the same fact queryably.
_interp_warned = False


def _warn_interpret_once(backend: str) -> None:
    global _interp_warned
    if _interp_warned:
        return
    _interp_warned = True
    get_logger("fabric").warning(
        "%s backend: no compiled pallas lowering on jax backend %r — "
        "kernels run in interpret mode (correct, not fast); see "
        "MappingFabric.backend_effective", backend, jax.default_backend())


def _env_backend() -> str | None:
    """Validated ``REPRO_FABRIC_BACKEND`` value, or None when unset."""
    env = os.environ.get("REPRO_FABRIC_BACKEND", "").strip().lower()
    if env and env not in BACKENDS:
        raise ValueError(
            f"REPRO_FABRIC_BACKEND must be one of {BACKENDS}, got {env!r}")
    return env or None


def default_backend() -> str:
    """Resolve ``backend="auto"``: the ``REPRO_FABRIC_BACKEND`` env knob
    wins (the CI backend matrix pins ``pallas`` with interpret fallback);
    otherwise numpy on CPU hosts, jit when an accelerator is attached."""
    env = _env_backend()
    if env:
        return env
    return "numpy" if jax.default_backend() == "cpu" else "jit"


def pow2_bucket(n: int, min_bucket: int = 1) -> int:
    """Next power of two ≥ ``max(n, min_bucket, 1)``.

    The one bucketing idiom every retrace-bounded dispatch in the repo
    shares: the fabric's ready-queue/PE padding (:meth:`MappingFabric
    .bucket_size`) and the paged serve runtime's active-lane padding
    (``serve.paging``) both compile O(log n_max) shape variants instead of
    one per dynamic size.
    """
    b = max(int(n), int(min_bucket), 1)
    return 1 << (b - 1).bit_length()


# ---------------------------------------------------------------------------
# Vectorized roofline front-end
# ---------------------------------------------------------------------------

def service_time_matrix(requests, replicas, *, active_params: float) -> np.ndarray:
    """Full (N, P) roofline exec-time matrix in one vectorized op.

    Bitwise-identical to looping ``service_time_s`` over (request, replica)
    pairs: prefill is compute-bound, decode is weight-streaming-bound, and
    the elementwise float64 operations associate exactly as the scalar code.
    """
    prefill = np.array([r.prefill_tokens for r in requests], dtype=np.float64)
    decode = np.array([r.decode_tokens for r in requests], dtype=np.float64)
    compute = np.array([r.compute_tflops for r in replicas], dtype=np.float64) * 1e12
    hbm = np.array([r.hbm_gbps for r in replicas], dtype=np.float64) * 1e9
    with np.errstate(divide="ignore"):
        return ((2.0 * active_params * prefill)[:, None] / compute[None, :]
                + (2.0 * active_params * decode)[:, None] / hbm[None, :])


# ---------------------------------------------------------------------------
# Oracle-exact numpy fast paths (the host side of the fabric)
# ---------------------------------------------------------------------------

def _priority_order_np(avg) -> np.ndarray:
    """Stable descending argsort, exactly as ``heft_rt_numpy`` computes it."""
    key = np.asarray(avg, dtype=np.float64)
    return np.argsort(-key, kind="stable")


def _eft_chain(rows, av):
    """The sequential EFT argmin recurrence over plain Python floats.

    ``rows``: exec times in priority order (list of lists), ``av``: the
    availability registers (mutated in place).  For the handful-of-PEs
    regime the per-step cost of the numpy version is dispatch overhead, so
    the chain runs scalar (same IEEE float64 operations, same first-minimum
    tie-break as ``np.argmin``) — bit-identical decisions.  The single
    implementation shared by :func:`heft_rt_fast` and
    :meth:`MappingFabric.assign`.
    """
    P = len(av)
    assignment, start, finish = [], [], []
    for row in rows:
        best_pe = 0
        best = av[0] + row[0]
        for p in range(1, P):
            f = av[p] + row[p]
            if f < best:
                best, best_pe = f, p
        if best < _INF:  # NaN and +inf both fail this, like np.isfinite
            assignment.append(best_pe)
            start.append(av[best_pe])
            finish.append(best)
            av[best_pe] = best
        else:
            assignment.append(-1)
            start.append(_INF)
            finish.append(_INF)
    return assignment, start, finish


def heft_rt_fast(avg, exec_times, avail):
    """Drop-in twin of :func:`repro.core.heft_rt_numpy`, ~5x faster at small P."""
    ex = np.asarray(exec_times, dtype=np.float64)
    order = _priority_order_np(avg)
    av = np.asarray(avail, dtype=np.float64).tolist()
    assignment, start, finish = _eft_chain(ex[order].tolist(), av)
    return (order, np.array(assignment, dtype=np.int64),
            np.array(start), np.array(finish), np.array(av))


def eft_dispatch_numpy(avg, exec_times, avail, capacity):
    """Early-exit HEFT_RT commit: the runtime simulator's dispatch contract.

    Follows the full priority order + EFT availability chain but only
    *commits* tasks to PEs with free worker-queue capacity, stopping once no
    capacity remains.  Prefix-identical to running :func:`heft_rt_fast` /
    ``heft_rt_numpy`` in full and committing, per PE, the first
    ``capacity[pe]`` tasks assigned to it.
    """
    ex = np.asarray(exec_times, dtype=np.float64)
    order = _priority_order_np(avg)
    av = [float(a) for a in np.asarray(avail, dtype=np.float64)]
    P = len(av)
    cap = [int(c) for c in capacity]
    remaining = sum(cap)
    out: list[tuple[int, int]] = []
    for t in order:
        if remaining == 0:
            break
        row = ex[t].tolist()
        best_pe = 0
        best = av[0] + row[0]
        for p in range(1, P):
            f = av[p] + row[p]
            if f < best:
                best, best_pe = f, p
        if not (best < _INF):
            continue
        av[best_pe] = best
        if cap[best_pe] > 0:
            out.append((int(t), best_pe))
            cap[best_pe] -= 1
            remaining -= 1
    return out


# ---------------------------------------------------------------------------
# The fabric
# ---------------------------------------------------------------------------

class MappingFabric:
    """Persistent HEFT_RT dispatch pipeline with bucketed shapes and
    device-resident availability registers.

    The P axis is *state*, not a constant: :meth:`grow` / :meth:`shrink` /
    :meth:`remap` resize or relabel the PE pool mid-stream while carrying
    the committed ``T_avail`` registers across the resize (the paper's PE
    pool whose effective composition changes at runtime).  Device backends
    pad P to a power-of-two bucket (``+inf`` exec columns, exactly like the
    queue-depth bucketing), so resize events inside a bucket reuse the
    compiled dispatch — no re-trace per event.

    Parameters
    ----------
    num_pes:
        Initial number of PEs / replicas (the variable P axis).
    backend:
        ``"numpy"`` (oracle-exact host fast path), ``"jit"`` (persistent
        jitted ``heft_rt``), ``"pallas"`` (fused overlay kernel — compiled
        on TPU/GPU, interpret-mode elsewhere), ``"fused"`` (device-resident
        PE mask + registers shareable with the paged decode tick; overlay
        kernel when a compiled lowering exists, the jnp twin otherwise), or
        ``"auto"`` — numpy on CPU hosts, jit when an accelerator backend is
        attached.
    min_bucket / max_bucket:
        Ready queues are padded to the next power of two in
        ``[min_bucket, max_bucket]``; exceeding ``max_bucket`` raises.
    min_pe_bucket:
        Smallest P bucket for the device backends (padding headroom so
        small grows stay inside one compiled variant).
    interpret:
        Force the Pallas interpret mode on/off (None: on iff not on TPU).
    avail:
        Initial availability registers (default zeros).
    tracer / metrics:
        Optional :class:`repro.obs.Tracer` / :class:`repro.obs.
        MetricsRegistry`.  When attached, every ``map_event``/``map_batch``
        records a span plus backend/bucket-labelled latency histograms
        ("fabric.event_s" per event, "fabric.decision_s" per decision — the
        paper's per-decision scheduling-latency axis), resizes emit instant
        events, and compiled-variant cache misses count as retraces.  When
        ``None`` (default) the dispatch path is exactly the uninstrumented
        code (gated by ``benchmarks/bench_obs_overhead.py``).
    device_counters:
        Accumulate scheduler counters (decisions, bucket occupancy, T_avail
        spread — see :mod:`repro.obs.device`) as extra donated registers
        *inside* the jitted dispatch; :meth:`drain_counters` reads them on
        demand with zero per-event host sync.  Decisions stay bit-identical
        to the uninstrumented oracle.
    """

    def __init__(self, num_pes: int, *, backend: str = "auto",
                 min_bucket: int = 8, max_bucket: int = 1 << 16,
                 min_pe_bucket: int = 4,
                 interpret: bool | None = None, avail=None,
                 tracer=None, metrics=None, device_counters: bool = False):
        if backend == "auto":
            backend = default_backend()
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.num_pes = int(num_pes)
        self.backend = backend
        self.min_bucket = int(min_bucket)
        self.max_bucket = int(max_bucket)
        self.min_pe_bucket = int(min_pe_bucket)
        self._interpret = interpret
        self._event_fn_cached = None
        self._batch_fn_cached = None
        self._events = 0
        self._resizes = 0
        self._tracer = tracer
        self._metrics = metrics
        self._device_counters = bool(device_counters)
        self._counters = None            # device registers / host accumulator
        self._p_valid = None             # real-lane mask at the P bucket
        self._pe_mask = None             # chaos-tier unreachable-lane mask
        self._mask_dev = None            # fused backend: device mask register
        self._stage_cache = {}           # fused tick staging buffer reuse
        self._shapes_seen: set = set()   # compiled-variant keys → retraces
        self._retraces = 0
        if self._device_counters:
            self._counters = (np.zeros(NUM_COUNTERS)
                              if backend == "numpy" else zero_counters())
        if backend == "pallas" and self._interpret_resolved():
            _warn_interpret_once(backend)
        self.reset(avail)

    def _interpret_resolved(self) -> bool:
        """Whether pallas kernels dispatched by this fabric interpret."""
        if self._interpret is not None:
            return bool(self._interpret)
        return _interpret_default()

    @property
    def backend_effective(self) -> str:
        """The path that actually runs, for benchmarks/tests to assert on.

        ``"pallas-interpret"`` when the pallas backend has no compiled
        lowering on this host (the previously *silent* fallback);
        ``"fused-jnp"`` when the fused backend's decision runs as the
        traced jnp twin instead of the overlay kernel; otherwise the
        configured backend name.
        """
        if self.backend == "pallas" and self._interpret_resolved():
            return "pallas-interpret"
        if self.backend == "fused" and self._interpret_resolved():
            return "fused-jnp"
        return self.backend

    # -- availability registers ---------------------------------------------

    def reset(self, avail=None) -> None:
        """(Re)load the T_avail registers (host values → device residency)."""
        a = (np.zeros(self.num_pes) if avail is None
             else np.asarray(avail, dtype=np.float64))
        if a.shape != (self.num_pes,):
            raise ValueError(f"avail must have shape ({self.num_pes},)")
        if self.backend == "numpy":
            self._avail = a.copy()
        else:
            # Registers live padded to the P bucket on device; padded lanes
            # carry +inf exec columns in every event, so they are never
            # selected and their register values are inert.
            self._avail = jnp.asarray(self._pad_avail(a))
            # Real-lane mask for the device counters' T_avail-spread lane
            # (padded registers are inert, not meaningful load); cached on
            # device so counted dispatches do not re-upload it per event.
            self._p_valid = jnp.asarray(
                np.arange(self.p_bucket) < self.num_pes)
            if self.backend == "fused":
                # The PE mask is a device register too (padded lanes False —
                # their exec columns are already +inf), so masked dispatch
                # needs no host-side matrix copy and the mask can ride into
                # the paged decode tick's compiled program.
                self._mask_dev = jnp.asarray(self._pad_mask())

    def _pad_mask(self) -> np.ndarray:
        m = np.zeros(self.p_bucket, dtype=bool)
        if self._pe_mask is not None:
            m[: self.num_pes] = self._pe_mask
        return m

    def _pad_avail(self, a) -> np.ndarray:
        pad = np.zeros(self.p_bucket, dtype=np.float32)
        pad[: self.num_pes] = a
        return pad

    @property
    def avail(self) -> np.ndarray:
        """Current availability registers as host values (logical P only)."""
        return np.asarray(self._avail)[: self.num_pes]

    @property
    def events(self) -> int:
        """Mapping events dispatched through this fabric (single + batched)."""
        return self._events

    @property
    def resizes(self) -> int:
        """Resize events (grow/shrink/remap/resize) applied to the PE pool."""
        return self._resizes

    @property
    def retraces(self) -> int:
        """Distinct compiled-dispatch shape variants entered (device
        backends; each is one XLA trace+compile).  0 for numpy."""
        return self._retraces

    # -- observability -------------------------------------------------------

    def attach_obs(self, tracer=None, metrics=None) -> None:
        """Attach (or replace) the tracer / metrics registry after
        construction — e.g. onto the fabric a policy factory built lazily."""
        if tracer is not None:
            self._tracer = tracer
        if metrics is not None:
            self._metrics = metrics

    def drain_counters(self, *, reset: bool = True) -> dict[str, float]:
        """Read the device-resident scheduler counters (one host transfer —
        the AXI counter-file read of the paper's overlay).  ``reset`` zeroes
        the registers for the next window.  Requires
        ``device_counters=True``."""
        if not self._device_counters:
            raise ValueError(
                "fabric was built without device_counters=True")
        out = counters_dict(np.asarray(self._counters))
        if reset:
            self._counters = (np.zeros(NUM_COUNTERS)
                              if self.backend == "numpy" else zero_counters())
        return out

    @staticmethod
    def _pow2_label(n: int) -> int:
        """Power-of-two ceiling for histogram bucket labels (the numpy
        backend has no shape buckets; labelling by raw n would mint one
        histogram per queue length)."""
        return 1 << (max(int(n), 1) - 1).bit_length()

    def _note_dispatch(self, kind: str, t0: float, dt: float,
                       n: int, bucket: int) -> None:
        """Record one dispatch's latency into the attached tracer/metrics
        (called only when one is attached)."""
        if self._metrics is not None:
            self._metrics.histogram(
                "fabric.event_s", backend=self.backend,
                bucket=bucket).record(dt)
            if n > 0:
                # the paper's per-decision scheduling latency: one measured
                # event amortized over its decisions
                self._metrics.histogram(
                    "fabric.decision_s", backend=self.backend).record(
                        dt / n, n=n)
        if self._tracer is not None:
            self._tracer.complete(f"fabric.{kind}", t0, dt, n=n,
                                  bucket=bucket, backend=self.backend)

    def _note_shape(self, key: tuple) -> None:
        """Count compiled-variant cache misses (a new bucketed shape on a
        device backend is one retrace/compile)."""
        if key in self._shapes_seen:
            return
        self._shapes_seen.add(key)
        if self.backend == "numpy":
            return
        self._retraces += 1
        if self._metrics is not None:
            self._metrics.counter("fabric.retraces").inc()
        if self._tracer is not None:
            self._tracer.instant("fabric.retrace", shape=str(key),
                                 backend=self.backend)

    # -- variable-P resize events -------------------------------------------

    def grow(self, new_p: int, *, avail: float = 0.0) -> None:
        """Extend the PE pool to ``new_p`` lanes; joiners start at ``avail``.

        Existing registers are carried bit-exact; a grow inside the current
        P bucket reuses every compiled dispatch variant (the resize costs one
        host→device register reload, never a re-trace).
        """
        new_p = int(new_p)
        if new_p < self.num_pes:
            raise ValueError(
                f"grow target {new_p} < current num_pes={self.num_pes} "
                f"(use shrink(keep_idx) to drop PEs)")
        joined = np.full(new_p - self.num_pes, float(avail))
        self._set_registers(np.concatenate([self.avail, joined]), new_p)

    def shrink(self, keep_idx) -> None:
        """Drop PEs, keeping (and reordering to) ``keep_idx``.

        ``keep_idx`` lists the surviving PE indices in their new order; the
        survivors' committed availability is carried bit-exact.
        """
        keep = np.asarray(keep_idx, dtype=np.int64)
        if keep.ndim != 1 or len(keep) == 0:
            raise ValueError("keep_idx must be a non-empty 1-D index list")
        if len(np.unique(keep)) != len(keep):
            raise ValueError(f"keep_idx has duplicates: {keep.tolist()}")
        if keep.min() < 0 or keep.max() >= self.num_pes:
            raise ValueError(
                f"keep_idx {keep.tolist()} out of range for num_pes="
                f"{self.num_pes}")
        self._set_registers(self.avail[keep], len(keep))

    def remap(self, old_to_new) -> None:
        """Relabel PEs: register at old index ``i`` moves to ``old_to_new[i]``.

        ``old_to_new`` must be a permutation of ``range(num_pes)`` (replicas
        migrating between fleet slots without changing P).
        """
        perm = np.asarray(old_to_new, dtype=np.int64)
        if (perm.shape != (self.num_pes,)
                or not np.array_equal(np.sort(perm), np.arange(self.num_pes))):
            raise ValueError(
                f"old_to_new must be a permutation of range({self.num_pes}), "
                f"got {perm.tolist()}")
        new = np.empty(self.num_pes, dtype=np.float64)
        new[perm] = self.avail
        self._set_registers(new, self.num_pes)

    def resize(self, new_p: int) -> None:
        """Convenience: grow to ``new_p`` (joiners at 0) or shrink keeping
        the first ``new_p`` lanes — the policy-facing P change."""
        if new_p > self.num_pes:
            self.grow(new_p)
        elif new_p < self.num_pes:
            self.shrink(np.arange(new_p))

    def set_pe_mask(self, mask) -> None:
        """Mask PE lanes out of dispatch (the chaos tier's partition mask).

        ``mask`` is a ``(num_pes,)`` bool array — ``True`` lanes' exec
        columns dispatch as ``+inf``, so no new work maps onto them while
        their committed ``T_avail`` registers stay resident for recovery;
        ``None`` clears the mask.  Decisions with a mask are exactly the
        oracle's on the masked matrix; with no mask the dispatch path is
        untouched.  Resizes (grow/shrink/remap) clear the mask — lane
        indices change meaning, so the caller re-derives reachability.
        """
        if mask is None:
            self._pe_mask = None
        else:
            m = np.asarray(mask, dtype=bool)
            if m.shape != (self.num_pes,):
                raise ValueError(
                    f"pe mask must have shape ({self.num_pes},), got {m.shape}")
            self._pe_mask = m
        if self.backend == "fused":
            self._mask_dev = jnp.asarray(self._pad_mask())

    def _masked(self, exec_times):
        """Apply the PE mask (+inf columns); the unmasked path returns the
        input untouched — no copy, bit-identical dispatch.  The fused
        backend never host-masks: its mask is a device register applied
        inside the compiled dispatch (``where(mask, +inf, exec)``, the same
        values this copy would produce)."""
        if self._pe_mask is None or self.backend == "fused":
            return exec_times
        ex = np.array(exec_times, copy=True)
        ex[..., self._pe_mask] = _INF
        return ex

    def _set_registers(self, host_avail, new_p: int) -> None:
        old_p = self.num_pes
        self.num_pes = int(new_p)
        self._resizes += 1
        self._pe_mask = None
        self.reset(host_avail)
        if self._metrics is not None:
            self._metrics.counter("fabric.resizes").inc()
            self._metrics.gauge("fabric.num_pes").set(self.num_pes)
        if self._tracer is not None:
            self._tracer.instant("fabric.resize", old_p=old_p,
                                 new_p=self.num_pes,
                                 p_bucket=self.p_bucket)

    # -- bucketing -----------------------------------------------------------

    def bucket_size(self, n: int) -> int:
        """Next power-of-two bucket ≥ max(n, min_bucket)."""
        b = pow2_bucket(n, self.min_bucket)
        if b > self.max_bucket:
            raise ValueError(f"queue length {n} exceeds max_bucket={self.max_bucket}")
        return b

    @property
    def p_bucket(self) -> int:
        """Power-of-two P bucket the device backends pad the PE axis to."""
        b = max(self.num_pes, self.min_pe_bucket, 1)
        return 1 << (b - 1).bit_length()

    def _check_p(self, exec_times) -> None:
        if exec_times.shape[-1] != self.num_pes:
            raise ValueError(
                f"exec_times has {exec_times.shape[-1]} PE columns but the "
                f"fabric's pool is num_pes={self.num_pes} — resize the "
                f"fabric (grow/shrink) before dispatching")

    def _pad_event(self, avg, exec_times):
        """Pad one event to its buckets: sanitized keys, +inf exec (both for
        padded queue slots and padded PE lanes), valid mask."""
        n, P = exec_times.shape
        D = self.bucket_size(n)
        # NaN keys (nanmean of an all-inf row) must sort behind every finite
        # key but ahead of padding; mapping them to -inf keeps that order
        # because the stable sort breaks the tie by slot index (< n).
        a = np.full(D, -_INF, dtype=np.float32)
        a[:n] = np.where(np.isnan(avg), -_INF, np.asarray(avg, dtype=np.float32))
        # Padded PE lanes carry +inf exec: argmin's first-minimum tie-break
        # means a padded lane can never beat a real lane (finite beats inf,
        # and an all-inf row resolves to the first — real — lane, which the
        # valid/finite guard then maps to assignment -1 exactly like the
        # oracle).
        ex = np.full((D, self.p_bucket), _INF, dtype=np.float32)
        ex[:n, :P] = exec_times
        valid = np.arange(D) < n
        return a, ex, valid

    # -- compiled dispatch cache --------------------------------------------

    def _event_fn(self):
        # One callable serves every bucket: jit specializes per shape
        # internally, and the pallas wrapper is shape-agnostic.  With
        # device_counters the compiled program carries the counter registers
        # as an extra donated argument and folds the decision outputs into
        # them in the same dispatch (see repro.obs.device) — the schedule
        # outputs are untouched.
        if self._event_fn_cached is None:
            counted = self._device_counters
            if self.backend == "fused":
                decide = self._fused_decide()

                if counted:
                    def counted_fused(avg, ex, avail, valid, mask, counters,
                                      p_valid):
                        res = decide(avg, ex, avail, valid, mask)
                        return res, accumulate_counters(
                            counters, res.assignment, res.new_avail, valid,
                            p_valid)

                    fn = jax.jit(counted_fused, donate_argnums=(2, 5))
                else:
                    fn = jax.jit(decide, donate_argnums=(2,))
            elif self.backend == "pallas":
                interp = self._interpret

                if counted:
                    def fn(avg, ex, avail, valid, counters, p_valid):
                        res = ScheduleResult(*heft_rt_hw(avg, ex, avail,
                                                         interpret=interp))
                        return res, accumulate_counters(
                            counters, res.assignment, res.new_avail,
                            valid, p_valid)
                else:
                    def fn(avg, ex, avail, valid):  # valid baked into padding
                        return ScheduleResult(*heft_rt_hw(avg, ex, avail,
                                                          interpret=interp))
            elif counted:
                def counted_event(avg, ex, avail, valid, counters, p_valid):
                    res = heft_rt(avg, ex, avail, valid)
                    return res, accumulate_counters(
                        counters, res.assignment, res.new_avail, valid,
                        p_valid)

                fn = jax.jit(counted_event, donate_argnums=(2, 4))
            else:
                # donate_argnums keeps T_avail device-resident: the register
                # file buffer is reused for new_avail instead of copied.
                fn = jax.jit(heft_rt, donate_argnums=(2,))
            self._event_fn_cached = fn
        return self._event_fn_cached

    def _fused_decide(self):
        """The fused backend's per-event decision body: the overlay kernel
        (:func:`repro.kernels.decision_hw`, in-kernel mask row) when a
        compiled pallas lowering exists on this host; otherwise the
        bit-identical jnp twin :func:`repro.kernels.fused_decision
        .decision_ref` — interpret-mode pallas would be a latency own-goal,
        and the twin traces straight into the decode tick's program."""
        if not self._interpret_resolved():
            def decide(avg, ex, avail, valid, mask):
                del valid  # baked into the -inf-key / +inf-exec padding
                return ScheduleResult(*decision_hw(avg, ex, avail, mask,
                                                   interpret=False))
            return decide
        return decision_ref

    def _batch_fn(self):
        if self._batch_fn_cached is None:
            counted = self._device_counters
            if self.backend == "fused":
                decide = self._fused_decide()
                inner = jax.vmap(decide, in_axes=(0, 0, 0, 0, None))

                if counted:
                    def counted_fused_b(avg, ex, avail, valid, mask, counters,
                                        p_valid):
                        res = inner(avg, ex, avail, valid, mask)
                        return res, accumulate_counters(
                            counters, res.assignment, res.new_avail, valid,
                            p_valid)

                    fn = jax.jit(counted_fused_b, donate_argnums=(2, 5))
                else:
                    fn = jax.jit(inner, donate_argnums=(2,))
            elif self.backend == "pallas":
                interp = self._interpret
                inner = jax.vmap(
                    lambda a, e, v: ScheduleResult(*heft_rt_hw(a, e, v,
                                                               interpret=interp)))

                if counted:
                    def fn(avg, ex, avail, valid, counters, p_valid):
                        res = inner(avg, ex, avail)
                        return res, accumulate_counters(
                            counters, res.assignment, res.new_avail,
                            valid, p_valid)
                else:
                    def fn(avg, ex, avail, valid):
                        return inner(avg, ex, avail)
            elif counted:
                def counted_batch(avg, ex, avail, valid, counters, p_valid):
                    res = jax.vmap(heft_rt)(avg, ex, avail, valid)
                    return res, accumulate_counters(
                        counters, res.assignment, res.new_avail, valid,
                        p_valid)

                fn = jax.jit(counted_batch, donate_argnums=(2, 4))
            else:
                fn = jax.jit(jax.vmap(heft_rt), donate_argnums=(2,))
            self._batch_fn_cached = fn
        return self._batch_fn_cached

    def _dispatch_event(self, fn, a_p, ex_p, av_in, valid):
        """Run one compiled dispatch, threading the device counter
        registers (and, for the fused backend, the device mask register)
        through when enabled."""
        if self.backend == "fused":
            if self._device_counters:
                res, self._counters = fn(a_p, ex_p, av_in, valid,
                                         self._mask_dev, self._counters,
                                         self._p_valid)
                return res
            # Exclusive branches: exactly one dispatch runs per event, so
            # av_in is donated exactly once (and the mask register is never
            # in this jit's donate set).
            return fn(a_p, ex_p, av_in, valid,  # repro: noqa[donation-after-use]
                      self._mask_dev)  # repro: noqa[donation-after-use]
        if self._device_counters:
            res, self._counters = fn(a_p, ex_p, av_in, valid,  # repro: noqa[donation-after-use]
                                     self._counters, self._p_valid)
            return res
        # Exclusive else-branch of the counted call above — only one of the
        # two dispatches runs, so av_in is donated exactly once.
        return fn(a_p, ex_p, av_in, valid)  # repro: noqa[donation-after-use]

    # -- mapping events ------------------------------------------------------

    def map_event(self, avg, exec_times, avail=None, *, update: bool | None = None):
        """One HEFT_RT mapping event.

        ``avail=None`` uses (and by default updates) the fabric's resident
        availability registers; passing ``avail`` explicitly leaves the
        registers untouched unless ``update=True``.

        Returns ``(order, assignment, start, finish, new_avail)`` as host
        arrays trimmed to the real queue length — the ``heft_rt_numpy``
        contract, in priority order.
        """
        exec_times = self._masked(np.asarray(exec_times))
        avg = np.asarray(avg)
        self._check_p(exec_times)
        n = exec_times.shape[0]
        use_resident = avail is None
        if update is None:
            update = use_resident
        self._events += 1
        obs_on = self._metrics is not None or self._tracer is not None
        t0 = time.perf_counter() if obs_on else 0.0
        if self.backend == "numpy":
            av_in = self._avail if use_resident else np.asarray(avail)
            out = heft_rt_fast(avg, exec_times, av_in)
            if update:
                self._avail = out[4].copy()
            if self._device_counters:
                accumulate_counters_np(self._counters, out[1], out[4])
            if obs_on:
                self._note_dispatch("map_event", t0,
                                    time.perf_counter() - t0, n,
                                    self._pow2_label(n))
            return out
        a_p, ex_p, valid = self._pad_event(avg, exec_times)
        self._note_shape(("event", len(a_p), self.p_bucket))
        if use_resident:
            # The register file is donated to the call; when the caller wants
            # the registers left alone, donate a copy instead.
            av_in = self._avail if update else jnp.array(self._avail, copy=True)
        else:
            av_in = jnp.asarray(
                self._pad_avail(np.asarray(avail, dtype=np.float64)))
        res = self._dispatch_event(self._event_fn(), a_p, ex_p, av_in, valid)
        if update:
            self._avail = res.new_avail
        out = (np.asarray(res.order)[:n], np.asarray(res.assignment)[:n],
               np.asarray(res.start_time)[:n], np.asarray(res.finish_time)[:n],
               np.asarray(res.new_avail)[: self.num_pes])
        if obs_on:
            self._note_dispatch("map_event", t0, time.perf_counter() - t0,
                                n, len(a_p))
        return out

    def map_batch(self, avg, exec_times, avail) -> ScheduleResult:
        """Batched mapping events: one device dispatch for B independent
        ready queues (the fabric-batched pipeline).

        ``avg``: (B, D), ``exec_times``: (B, D, P), ``avail``: (B, P).
        Returns a device-resident :class:`ScheduleResult` with leading batch
        dimension, trimmed to the input D.  With the numpy backend this
        loops the host oracle (useful as a reference, not for speed).
        """
        avg = np.asarray(avg)
        exec_times = self._masked(np.asarray(exec_times))
        avail_np = np.asarray(avail)
        self._check_p(exec_times)
        B, D = avg.shape
        self._events += B
        obs_on = self._metrics is not None or self._tracer is not None
        t0 = time.perf_counter() if obs_on else 0.0
        if self.backend == "numpy":
            outs = [heft_rt_fast(avg[i], exec_times[i], avail_np[i])
                    for i in range(B)]
            out = ScheduleResult(*(np.stack(cols) for cols in zip(*outs)))
            if self._device_counters:
                accumulate_counters_np(self._counters, out.assignment,
                                       out.new_avail)
            if obs_on:
                self._note_dispatch("map_batch", t0,
                                    time.perf_counter() - t0, B * D,
                                    self._pow2_label(D))
            return out
        Db = self.bucket_size(D)
        Bb = self.bucket_size(B)
        Pb = self.p_bucket
        self._note_shape(("batch", Bb, Db, Pb))
        a_p = np.full((Bb, Db), -_INF, dtype=np.float32)
        a_p[:B, :D] = np.where(np.isnan(avg), -_INF, avg)
        ex_p = np.full((Bb, Db, Pb), _INF, dtype=np.float32)
        ex_p[:B, :D, : self.num_pes] = exec_times
        av_p = np.zeros((Bb, Pb), dtype=np.float32)
        av_p[:B, : self.num_pes] = avail_np
        valid = np.zeros((Bb, Db), dtype=bool)
        valid[:B, :D] = True
        res = self._dispatch_event(self._batch_fn(), a_p, ex_p,
                                   jnp.asarray(av_p), valid)
        out = ScheduleResult(res.order[:B, :D], res.assignment[:B, :D],
                             res.start_time[:B, :D], res.finish_time[:B, :D],
                             res.new_avail[:B, : self.num_pes])
        if obs_on:
            self._note_dispatch("map_batch", t0, time.perf_counter() - t0,
                                B * D, Db)
        return out

    # -- consumer-facing contracts ------------------------------------------

    def assign(self, exec_times, avail) -> np.ndarray:
        """Serving-policy contract: ready-order replica assignment (n,).

        ``avg`` is the mean exec time across replicas (the serving
        scheduler's Avg_TID), exactly as ``policy_heft_rt`` computes it.
        (The key must be the *mean*, not the row sum: float division is not
        injective, so distinct sums can collide into one mean — tie sets
        would differ from the oracle's.  ``sum/P`` is bitwise ``np.mean``
        — same pairwise sum, same divide — minus the reduction-machinery
        overhead.)
        """
        exec_times = self._masked(np.asarray(exec_times))
        self._check_p(exec_times)
        n, P = exec_times.shape
        if self.backend == "numpy":
            ex = np.asarray(exec_times, dtype=np.float64)
            self._events += 1
            obs_on = self._metrics is not None or self._tracer is not None
            t0 = time.perf_counter() if obs_on else 0.0
            order = np.argsort(-(ex.sum(axis=1) / P), kind="stable")
            av = np.asarray(avail, dtype=np.float64).tolist()
            assignment, _, _ = _eft_chain(ex[order].tolist(), av)
            if self._device_counters:
                accumulate_counters_np(self._counters,
                                       np.asarray(assignment),
                                       np.asarray(av))
            if obs_on:
                self._note_dispatch("assign", t0, time.perf_counter() - t0,
                                    n, self._pow2_label(n))
        else:
            order, assignment, _, _, _ = self.map_event(
                exec_times=exec_times, avg=exec_times.mean(axis=1),
                avail=avail, update=False)
        out = np.empty(n, dtype=np.int64)
        out[order] = assignment
        return out

    def dispatch(self, avg, exec_times, avail, capacity) -> list[tuple[int, int]]:
        """Runtime-simulator contract: early-exit capacity-limited commit.

        Identical decisions to :func:`eft_dispatch_numpy` (and hence to the
        seed ``dispatch_heft_rt``): the device backends run the full mapping
        event and commit, per PE, the first ``capacity[pe]`` tasks in
        priority order until total capacity is exhausted.
        """
        if self.backend == "numpy":
            return eft_dispatch_numpy(avg, self._masked(np.asarray(exec_times)),
                                      avail, capacity)
        order, assignment, _, _, _ = self.map_event(avg, exec_times, avail,
                                                    update=False)
        cap = [int(c) for c in capacity]
        remaining = sum(cap)
        out: list[tuple[int, int]] = []
        for qid, pe in zip(order, assignment):
            if remaining == 0:
                break
            if pe >= 0 and cap[pe] > 0:
                out.append((int(qid), int(pe)))
                cap[pe] -= 1
                remaining -= 1
        return out


    # -- fused-tick register sharing ----------------------------------------
    #
    # The paged decode tick (serve/paging.py) inlines the HEFT_RT decision
    # into its own compiled program; these two methods are the fabric's side
    # of that contract.  The device registers (T_avail, PE mask, counter
    # file) stay owned by the fabric — the tick borrows them for one
    # dispatch and hands the donated results back — so every resident-state
    # contract (resize carries registers bit-exact, set_pe_mask, drain_
    # counters) keeps working unchanged while decisions ride the tick.

    def tick_decision_inputs(self, avg, exec_times):
        """Stage one mapping event for a fused decode tick.

        Pads ``(avg, exec_times)`` to this fabric's buckets and returns
        ``(a_p, ex_p, valid, avail, mask, counters, p_valid)`` — the padded
        operands plus the live device registers for the tick's compiled
        program to consume.  ``avail`` (and ``counters``) are the resident
        buffers and will be *donated* to the tick: the caller must follow
        up with :meth:`commit_tick_decision` on the tick's outputs before
        the next dispatch.  ``counters``/``p_valid`` are ``None`` when the
        fabric was built without ``device_counters``.  Fused backend only.
        """
        if self.backend != "fused":
            raise ValueError(
                f"tick fusion requires backend='fused', got {self.backend!r}")
        avg = np.asarray(avg)
        exec_times = np.asarray(exec_times)
        self._check_p(exec_times)
        n, P = exec_times.shape
        D = self.bucket_size(n)
        # Steady-state fast path: the padded staging buffers are reused
        # across ticks (the jit boundary copies them into device memory
        # synchronously at dispatch, so in-place refills are safe).  Only
        # the live region changes between events of the same shape; the
        # padding lanes were written once by _pad_event and are invariant.
        cached = self._stage_cache.get((D, self.p_bucket))
        if cached is None or cached[3] != (n, P):
            a_p, ex_p, valid = self._pad_event(avg, exec_times)
            self._stage_cache[(D, self.p_bucket)] = [a_p, ex_p, valid, (n, P)]
        else:
            a_p, ex_p, valid, _ = cached
            a_p[:n] = np.where(np.isnan(avg),
                               -_INF, np.asarray(avg, dtype=np.float32))
            ex_p[:n, :P] = exec_times
        self._note_shape(("event", D, self.p_bucket))
        counted = self._device_counters
        return (a_p, ex_p, valid, self._avail, self._mask_dev,
                self._counters if counted else None,
                self._p_valid if counted else None)

    def commit_tick_decision(self, n: int, buf, new_avail, counters=None):
        """Adopt a fused tick's decision outputs back into the fabric.

        ``buf`` is the *host* copy of the tick's packed decision lanes —
        :func:`repro.kernels.fused_decision.pack_tick_outputs`' layout with
        the token prefix already sliced off (``order | assignment | start |
        finish | new_avail`` as raw int32, float lanes bitcast).
        ``new_avail`` is the program's *device-resident* register output
        (it reuses the donated buffer, so residency is preserved with zero
        copies) and becomes the live register file; ``counters``, when
        given, the accumulated counter registers.  Returns the host-trimmed
        ``(order, assignment, start, finish, new_avail)`` tuple — the
        :meth:`map_event` contract for the ``n`` real queue slots,
        recovered by zero-copy ``.view`` (bit-identical, no extra device
        sync).
        """
        if self.backend != "fused":
            raise ValueError(
                f"tick fusion requires backend='fused', got {self.backend!r}")
        self._events += 1
        self._avail = new_avail
        if counters is not None:
            self._counters = counters
        order, assignment, start, finish, avail = unpack_decision(
            buf, self.p_bucket)
        return (order[:n], assignment[:n], start[:n], finish[:n],
                avail[: self.num_pes])


def make_policy_fabric(backend: str | None = None, *, tracer=None,
                       metrics=None, device_counters: bool = False):
    """Serving-policy factory backed by a :class:`MappingFabric`.

    The returned policy matches ``policy_heft_rt`` decision-for-decision;
    the fabric is created lazily so one factory works for any fleet size,
    and a *fleet-size change mid-stream* (elastic resize events) resizes the
    live fabric instead of rebuilding it — the compiled dispatch variants
    survive every resize inside a P bucket.  ``backend=None`` honours
    ``REPRO_FABRIC_BACKEND`` (the CI backend matrix) and defaults to the
    oracle-exact numpy host path otherwise.

    ``tracer``/``metrics``/``device_counters`` thread the observability
    layer into the lazily built fabric (see :class:`MappingFabric`); the
    fabric is reachable afterwards via the policy's ``fabric()`` attribute
    (None until the first mapping event).
    """
    if backend is None:
        backend = _env_backend() or "numpy"
    fab: MappingFabric | None = None

    def policy(exec_times, avail):
        nonlocal fab
        if fab is None:
            fab = MappingFabric(exec_times.shape[1], backend=backend,
                                tracer=tracer, metrics=metrics,
                                device_counters=device_counters)
        elif fab.num_pes != exec_times.shape[1]:
            # registers are irrelevant here (the policy passes avail
            # explicitly), so the prefix-keeping resize is safe
            fab.resize(exec_times.shape[1])
        return fab.assign(exec_times, avail)

    policy.fabric = lambda: fab
    return policy
