"""Elastic fleet subsystem: resize + failure events and the fleet controller.

The paper schedules *dynamically arriving* work onto a PE pool whose
availability state lives in fabric registers — and on real SoCs the pool
itself is dynamic too: PEs are power-gated, reclaimed, or re-partitioned at
runtime (HTS, arXiv:1907.00271; Mack et al., arXiv:2112.08980).  The serving
analogue is an *elastic fleet*: replicas join/leave mid-run and mesh slices
split/merge as load shifts.  This module is the control plane for that:

* :class:`ResizeEvent` — one timeline entry: at time ``t``, remove replicas
  by name and/or add new :class:`~repro.sched_integration.serve_scheduler.
  Replica`s.  ``simulate_serving(fleet_events=[...])`` replays a scripted
  timeline; an empty timeline is bit-identical to the fixed-fleet simulator.
* :class:`FailureEvent` — the chaos-tier timeline entry beside it: replica
  loss mid-decode, straggler windows (PE speed degraded ×k), and link
  degrade/partition windows on an attached
  :class:`~repro.sched_integration.topology.Topology`.
  ``simulate_serving(failure_events=[...])`` consumes them; an empty
  timeline is bit-identical to the failure-free simulator.
* :func:`split_event` / :func:`merge_event` — re-carve a replica's devices
  into smaller slices (or several replicas into one bigger slice), the
  simulator-side mirror of ``launch.mesh.slice_device_pool`` re-carving.
  Device counts must balance exactly; rates re-aggregate per device.
* :class:`FleetController` — the closed loop: consumes load signals (ready-
  queue depth, p95 latency) each mapping event, and emits grow/shrink
  ``ResizeEvent``s with a cooldown, recording a human-readable decision
  trace.  ``simulate_serving(controller=...)`` drives it from the simulator;
  the live-engine side drives :meth:`HeftFrontEnd.add_replica` /
  ``remove_replica`` (whose attached ``MappingFabric`` grows/shrinks its
  T_avail registers in place) plus ``ServeEngine.reshard`` for migrations.
  The same controller owns *straggler remap*: per-replica backlog signals
  (the serving twin of ``repro.obs``'s ``serve.replica_util`` /
  ``fabric.decision_s`` rails) feed :meth:`FleetController.
  observe_stragglers`, which flags replicas whose queue horizon runs
  ``straggler_factor``× past the fleet median — under a per-replica
  exponential backoff — and the simulator re-queues their not-yet-started
  work onto the healthy fleet (bounded by the per-request retry budget).

Recovery contract (enforced by ``simulate_serving``'s end-of-run invariant):
work committed to a replica that is *lost* — whether still in the roster or
already in its drain-then-leave window — is re-queued through the mapping
policy, never silently dropped; every request ends exactly served or
unserved, with its re-queue count in ``ServeResult.requeued``; a served
request's finish never postdates its replica's loss instant.

Cost-model coupling: a replica added with a mesh shape that was never
dry-run gets its Exec_TID cells projected from the arch's largest measured
cell (``CostModelRegistry.ensure_coverage`` → ``scaled_cell``), so mid-run
joiners are scheduled from calibrated estimates, not the blank roofline.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass

import numpy as np

from repro.obs.trace import TraceEvent
from repro.sched_integration.serve_scheduler import Replica, Request


@dataclass(frozen=True)
class ResizeEvent:
    """One fleet-resize step: at ``t``, drop ``remove`` (names), then join
    ``add`` (Replica objects).  Removal stops *new* assignments; work already
    committed to a removed replica finishes undisturbed (drain-then-leave)."""

    t: float
    add: tuple = ()
    remove: tuple = ()
    reason: str = ""


# Chaos-tier failure kinds and their knobs:
#   replica_loss   target=replica name.  The replica dies instantly (no
#                  drain): unfinished committed work re-queues, the roster
#                  shrinks.  A loss may also target a replica already in a
#                  drain-then-leave window — its in-flight work re-queues
#                  the same way.
#   straggler      target=replica name, factor (>1: exec ×factor),
#                  duration_s window.  Exec column, queue horizon, and
#                  in-flight finishes stretch for the window, then restore
#                  bit-exact from the cost model.
#   link_degrade   target="podA:podB", factor in (0,1) scales bandwidth,
#                  duration_s window.  Needs simulate_serving(topology=...).
#   link_partition target="podA:podB", duration_s window: the link is down;
#                  replicas cut off from the gateway are masked (+inf exec)
#                  for the window, transfers wait the window out.
FAILURE_KINDS = ("replica_loss", "straggler", "link_degrade",
                 "link_partition")
_WINDOWED_KINDS = ("straggler", "link_degrade", "link_partition")


@dataclass(frozen=True)
class FailureEvent:
    """One chaos-timeline entry: at ``t``, ``kind`` strikes ``target``.

    See :data:`FAILURE_KINDS` for the kind/knob inventory.  Windowed kinds
    (everything but ``replica_loss``) recover automatically at
    ``t + duration_s``; the simulator emits ``serve.failure`` /
    ``serve.recovery`` tracer instants and ``serve.failures`` /
    ``serve.retries`` counters for both edges.
    """

    t: float
    kind: str
    target: str
    duration_s: float = 0.0
    factor: float = 1.0
    reason: str = ""

    def __post_init__(self):
        if self.kind not in FAILURE_KINDS:
            raise ValueError(
                f"failure kind must be one of {FAILURE_KINDS}, "
                f"got {self.kind!r}")
        if not self.target:
            raise ValueError(f"failure event at t={self.t} has no target")
        if self.kind in _WINDOWED_KINDS and not self.duration_s > 0:
            raise ValueError(
                f"{self.kind} at t={self.t} needs duration_s > 0, "
                f"got {self.duration_s}")
        if self.kind == "straggler" and not self.factor > 1.0:
            raise ValueError(
                f"straggler factor must be > 1 (a slowdown), "
                f"got {self.factor}")
        if self.kind == "link_degrade" and not (0.0 < self.factor < 1.0):
            raise ValueError(
                f"link_degrade factor must be in (0, 1), got {self.factor}")


_TIMELINE_FIELDS = {"t": (int, float), "kind": str, "target": str,
                    "duration_s": (int, float), "factor": (int, float),
                    "reason": str}
_TIMELINE_REQUIRED = ("t", "kind", "target")


def validate_failure_timeline(obj) -> list[FailureEvent]:
    """Schema-validate a chaos-trace object (the ``--chaos TRACE.json``
    payload) and build the :class:`FailureEvent` timeline.

    Same style as ``repro.obs.check``: loud ``ValueError`` on any schema
    violation — unknown keys, missing required fields, wrong types, or
    per-kind knob violations (delegated to ``FailureEvent.__post_init__``).
    Schema::

        {"events": [{"t": 0.5, "kind": "replica_loss", "target": "r0",
                     "duration_s": 1.0, "factor": 4.0, "reason": "..."}]}
    """
    if not isinstance(obj, dict):
        raise ValueError(f"chaos trace root must be an object, "
                         f"got {type(obj).__name__}")
    events = obj.get("events")
    if not isinstance(events, list):
        raise ValueError("chaos trace has no 'events' list")
    out = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"events[{i}] is not an object")
        unknown = set(ev) - set(_TIMELINE_FIELDS)
        if unknown:
            raise ValueError(f"events[{i}] has unknown keys {sorted(unknown)} "
                             f"(schema keys: {sorted(_TIMELINE_FIELDS)})")
        for key in _TIMELINE_REQUIRED:
            if key not in ev:
                raise ValueError(f"events[{i}] missing required {key!r}")
        for key, want in _TIMELINE_FIELDS.items():
            if key in ev and not isinstance(ev[key], want):
                raise ValueError(
                    f"events[{i}].{key} must be "
                    f"{getattr(want, '__name__', want)}, got {ev[key]!r}")
        out.append(FailureEvent(**ev))
    return out


def load_failure_timeline(path: str) -> list[FailureEvent]:
    """Load + schema-validate a ``--chaos TRACE.json`` failure timeline."""
    with open(path) as f:
        obj = json.load(f)
    return validate_failure_timeline(obj)


def _unit_rates(rep: Replica) -> tuple[float, float]:
    """Per-device (compute, hbm) rates from a mesh-backed replica's
    aggregates."""
    if rep.mesh_shape is None:
        raise ValueError(
            f"replica {rep.name!r} has no mesh_shape — split/merge re-carve "
            f"devices, so only mesh-backed replicas can resize")
    n = math.prod(rep.mesh_shape)
    return rep.compute_tflops / n, rep.hbm_gbps / n


def split_event(t: float, rep: Replica, shapes, *, reason: str = "") -> ResizeEvent:
    """Re-carve one replica's devices into smaller slices.

    ``shapes`` must tile the replica's device count exactly (the
    ``slice_device_pool`` contract); aggregate rates redistribute
    per-device.
    """
    ct, hb = _unit_rates(rep)          # validates mesh backing first
    shapes = [tuple(int(d) for d in s) for s in shapes]
    n = math.prod(rep.mesh_shape)
    need = sum(math.prod(s) for s in shapes)
    if need != n:
        raise ValueError(
            f"split of {rep.name!r}: shapes {shapes} use {need} devices but "
            f"the replica's {rep.mesh_shape} slice has {n}")
    adds = tuple(
        Replica(f"{rep.name}/s{i}", math.prod(s) * ct, math.prod(s) * hb,
                arch=rep.arch, mesh_shape=s, ici_gbps=rep.ici_gbps,
                slots=rep.slots)
        for i, s in enumerate(shapes))
    return ResizeEvent(t, add=adds, remove=(rep.name,),
                       reason=reason or f"split {rep.name} -> {shapes}")


def merge_event(t: float, reps, shape, *, name: str | None = None,
                reason: str = "") -> ResizeEvent:
    """Merge several replicas' devices into one bigger slice.

    The merged slice's device count must equal the sum of the parts; all
    parts must share per-device rates (one chip generation per merge, the
    ``slice_device_pool`` pool contract) — mixing generations would credit
    the merged slice the wrong aggregate capacity.
    """
    reps = list(reps)
    rates = [_unit_rates(r) for r in reps]          # validates mesh backing
    (ct, hb), *rest = rates
    if any(not (math.isclose(c, ct, rel_tol=1e-9)
                and math.isclose(h, hb, rel_tol=1e-9)) for c, h in rest):
        raise ValueError(
            f"merge of {[r.name for r in reps]}: parts have mixed "
            f"per-device rates {rates} — one chip generation per merge")
    shape = tuple(int(d) for d in shape)
    total = sum(math.prod(r.mesh_shape) for r in reps)
    if math.prod(shape) != total:
        raise ValueError(
            f"merge of {[r.name for r in reps]}: target {shape} has "
            f"{math.prod(shape)} devices but the parts hold {total}")
    n = math.prod(shape)
    merged = Replica(name or f"{reps[0].name}/m{'x'.join(map(str, shape))}",
                     n * ct, n * hb, arch=reps[0].arch, mesh_shape=shape,
                     ici_gbps=reps[0].ici_gbps, slots=reps[0].slots)
    return ResizeEvent(t, add=(merged,), remove=tuple(r.name for r in reps),
                       reason=reason or
                       f"merge {[r.name for r in reps]} -> {shape}")


@dataclass
class FleetControllerConfig:
    """Thresholds for the grow/shrink loop.

    Grow when ANY enabled signal crosses its threshold (``inf`` disables
    one): ``grow_backlog_s`` — mean committed-but-unfinished work per
    replica, in seconds of queue horizon (the serving analogue of the
    paper's ``T_avail`` registers running ahead of the clock);
    ``grow_queue_depth`` — ready requests awaiting dispatch;
    ``grow_p95_s`` — p95 latency over requests *committed in the last*
    ``p95_window_s`` *seconds* (their estimated completion; in the
    simulator a commit pins the finish time).  The window matters: a
    cumulative p95 would latch "overloaded" forever after one spike.
    Shrink (retire the most recent grown replica) when the backlog AND
    queue are both at or under their shrink thresholds and no grow signal
    is firing — shrinking while overloaded would just oscillate against
    the next grow.  ``cooldown_s`` rate-limits decisions; ``max_grown``
    bounds concurrently grown replicas (the spare-device budget).
    """

    grow_backlog_s: float = 2.0
    grow_queue_depth: float = float("inf")
    grow_p95_s: float = float("inf")
    p95_window_s: float = 5.0
    shrink_backlog_s: float = 0.25
    shrink_queue_depth: float = 2.0
    cooldown_s: float = 0.5
    max_grown: int = 4
    # Straggler remap (chaos tier; inf disables).  A replica is flagged when
    # its committed-but-unfinished backlog runs straggler_factor× past the
    # fleet median (with straggler_min_backlog_s as an absolute floor, so a
    # near-idle fleet never flags noise).  Re-flagging the same replica backs
    # off exponentially from straggler_cooldown_s — a persistent straggler is
    # remapped less and less often, bounding remap churn alongside the
    # per-request retry budget.
    straggler_factor: float = float("inf")
    straggler_min_backlog_s: float = 0.5
    straggler_cooldown_s: float = 0.5


class FleetController:
    """Load signals → :class:`ResizeEvent`s, with a decision trace.

    ``make_replica(idx)`` is the grow factory — it returns the Replica a
    grow decision adds (e.g. a ``(2, 2)`` slice carved from the spare
    device pool; see :func:`grown_replica_factory`).  The controller owns
    the lifecycle of what it adds: shrink decisions retire its own grown
    replicas (most recent first) and never touch the base fleet.

    The decision trace is structured: ``events`` is a list of
    :class:`repro.obs.TraceEvent` instants (``fleet.grow`` /
    ``fleet.shrink``, stamped at *simulated* time, with the decision's
    ``t``/``kind``/``why`` in args), mirrored into an attached ``tracer``
    so controller decisions land on the same exported timeline as the
    fabric/serve spans.  The legacy ``trace`` list of ``(t, kind, why)``
    tuples is preserved as a derived view.
    """

    def __init__(self, cfg: FleetControllerConfig, make_replica, *,
                 tracer=None):
        self.cfg = cfg
        self._make = make_replica
        self.grown: list[str] = []
        self.events: list[TraceEvent] = []   # structured decision trace
        self._tracer = tracer
        self._last_t = -float("inf")
        self._next_id = 0
        # Straggler-remap backoff state: next allowed flag time and current
        # backoff width, per replica name.
        self._straggler_next: dict[str, float] = {}
        self._straggler_backoff: dict[str, float] = {}

    @property
    def trace(self) -> list[tuple[float, str, str]]:
        """Decision log as ``(t, kind, why)`` tuples (compat view over
        :attr:`events`)."""
        return [(e.args["t"], e.args["kind"], e.args["why"])
                for e in self.events]

    def _note(self, t: float, kind: str, why: str) -> None:
        ev = TraceEvent(f"fleet.{kind}", "i", t * 1e6,
                        args={"t": t, "kind": kind, "why": why})
        self.events.append(ev)
        if self._tracer is not None:
            self._tracer.record(ev)

    def observe(self, t: float, *, queue_depth: int = 0,
                backlog_s: float = 0.0,
                p95_s: float = 0.0) -> ResizeEvent | None:
        """One control tick.  Returns the resize to apply now, or None."""
        cfg = self.cfg
        if t - self._last_t < cfg.cooldown_s:
            return None
        overloaded = (backlog_s >= cfg.grow_backlog_s
                      or queue_depth >= cfg.grow_queue_depth
                      or p95_s >= cfg.grow_p95_s)
        if overloaded and len(self.grown) < cfg.max_grown:
            rep = self._make(self._next_id)
            self._next_id += 1
            self.grown.append(rep.name)
            self._last_t = t
            p95 = f" p95={p95_s * 1e3:.0f}ms" if p95_s > 0 else ""
            why = (f"backlog={backlog_s:.2f}s queue={queue_depth}{p95} "
                   f"-> +{rep.name}")
            self._note(t, "grow", why)
            return ResizeEvent(t, add=(rep,), reason=why)
        drained = (backlog_s <= cfg.shrink_backlog_s
                   and queue_depth <= cfg.shrink_queue_depth)
        if drained and not overloaded and self.grown:
            name = self.grown.pop()
            self._last_t = t
            why = f"backlog={backlog_s:.2f}s queue={queue_depth} -> -{name}"
            self._note(t, "shrink", why)
            return ResizeEvent(t, remove=(name,), reason=why)
        return None

    def observe_stragglers(self, t: float, names, backlogs) -> list[str]:
        """Flag replicas whose backlog runs ``straggler_factor``× past the
        fleet median — the controller-driven remap trigger.

        ``backlogs[i]`` is replica ``names[i]``'s committed-but-unfinished
        queue horizon in seconds (``T_avail - t``, clamped at 0) — the same
        signal ``repro.obs`` exposes as per-replica utilization.  Flagged
        names are re-queued by the simulator (their not-yet-started work goes
        back through the mapping policy); each flag doubles that replica's
        personal backoff window starting from ``straggler_cooldown_s``, and
        a replica observed healthy again resets its backoff.  Returns the
        flagged names (possibly empty); detection disabled while
        ``straggler_factor`` is ``inf``.
        """
        cfg = self.cfg
        if not math.isfinite(cfg.straggler_factor) or len(names) < 2:
            return []
        backlogs = [float(b) for b in backlogs]
        med = float(np.median(backlogs))
        bar = max(cfg.straggler_factor * med, cfg.straggler_min_backlog_s)
        flagged = []
        for name, b in zip(names, backlogs):
            if b < bar:
                # Healthy again: forgive the backoff history.
                self._straggler_backoff.pop(name, None)
                self._straggler_next.pop(name, None)
                continue
            if t < self._straggler_next.get(name, -float("inf")):
                continue
            backoff = self._straggler_backoff.get(
                name, cfg.straggler_cooldown_s)
            self._straggler_next[name] = t + backoff
            self._straggler_backoff[name] = 2.0 * backoff
            why = (f"backlog={b:.2f}s median={med:.2f}s "
                   f"backoff={backoff:.2f}s -> remap {name}")
            self._note(t, "remap", why)
            flagged.append(name)
        return flagged


def grown_replica_factory(arch: str, shape, *, chip_tflops: float = 197.0,
                          chip_hbm_gbps: float = 819.0, mfu: float = 0.5,
                          hbm_eff: float = 0.6, ici_gbps: float = 0.0):
    """``make_replica`` factory for :class:`FleetController`: each grow adds
    one ``shape``-slice replica of the given chip generation (the same rate
    model as ``mesh_fleet``)."""
    shape = tuple(int(d) for d in shape)
    n = math.prod(shape)

    def make(idx: int) -> Replica:
        return Replica(f"{arch}@{'x'.join(map(str, shape))}+g{idx}",
                       n * chip_tflops * mfu, n * chip_hbm_gbps * hbm_eff,
                       arch=arch, mesh_shape=shape, ici_gbps=ici_gbps)

    return make


def make_spike_requests(base_rps: float, spike_rps: float, *,
                        spike_start: float, spike_end: float,
                        duration_s: float, seed: int = 0,
                        prefill_range=(128, 4096),
                        decode_range=(16, 512)) -> list[Request]:
    """Poisson arrivals with a rate spike in ``[spike_start, spike_end)`` —
    the scripted-load workload the elastic example/benchmark replay.  One
    ``make_requests`` rate function, not a second arrival loop."""
    from repro.sched_integration.serve_scheduler import make_requests

    return make_requests(
        lambda t: spike_rps if spike_start <= t < spike_end else base_rps,
        duration_s, seed=seed,
        prefill_range=prefill_range, decode_range=decode_range)
