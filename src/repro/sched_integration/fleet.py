"""Elastic fleet subsystem: resize events + a load-driven fleet controller.

The paper schedules *dynamically arriving* work onto a PE pool whose
availability state lives in fabric registers — and on real SoCs the pool
itself is dynamic too: PEs are power-gated, reclaimed, or re-partitioned at
runtime (HTS, arXiv:1907.00271; Mack et al., arXiv:2112.08980).  The serving
analogue is an *elastic fleet*: replicas join/leave mid-run and mesh slices
split/merge as load shifts.  This module is the control plane for that:

* :class:`ResizeEvent` — one timeline entry: at time ``t``, remove replicas
  by name and/or add new :class:`~repro.sched_integration.serve_scheduler.
  Replica`s.  ``simulate_serving(fleet_events=[...])`` replays a scripted
  timeline; an empty timeline is bit-identical to the fixed-fleet simulator.
* :func:`split_event` / :func:`merge_event` — re-carve a replica's devices
  into smaller slices (or several replicas into one bigger slice), the
  simulator-side mirror of ``launch.mesh.slice_device_pool`` re-carving.
  Device counts must balance exactly; rates re-aggregate per device.
* :class:`FleetController` — the closed loop: consumes load signals (ready-
  queue depth, p95 latency) each mapping event, and emits grow/shrink
  ``ResizeEvent``s with a cooldown, recording a human-readable decision
  trace.  ``simulate_serving(controller=...)`` drives it from the simulator;
  the live-engine side drives :meth:`HeftFrontEnd.add_replica` /
  ``remove_replica`` (whose attached ``MappingFabric`` grows/shrinks its
  T_avail registers in place) plus ``ServeEngine.reshard`` for migrations.

Cost-model coupling: a replica added with a mesh shape that was never
dry-run gets its Exec_TID cells projected from the arch's largest measured
cell (``CostModelRegistry.ensure_coverage`` → ``scaled_cell``), so mid-run
joiners are scheduled from calibrated estimates, not the blank roofline.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.obs.trace import TraceEvent
from repro.sched_integration.serve_scheduler import Replica, Request


@dataclass(frozen=True)
class ResizeEvent:
    """One fleet-resize step: at ``t``, drop ``remove`` (names), then join
    ``add`` (Replica objects).  Removal stops *new* assignments; work already
    committed to a removed replica finishes undisturbed (drain-then-leave)."""

    t: float
    add: tuple = ()
    remove: tuple = ()
    reason: str = ""


def _unit_rates(rep: Replica) -> tuple[float, float]:
    """Per-device (compute, hbm) rates from a mesh-backed replica's
    aggregates."""
    if rep.mesh_shape is None:
        raise ValueError(
            f"replica {rep.name!r} has no mesh_shape — split/merge re-carve "
            f"devices, so only mesh-backed replicas can resize")
    n = math.prod(rep.mesh_shape)
    return rep.compute_tflops / n, rep.hbm_gbps / n


def split_event(t: float, rep: Replica, shapes, *, reason: str = "") -> ResizeEvent:
    """Re-carve one replica's devices into smaller slices.

    ``shapes`` must tile the replica's device count exactly (the
    ``slice_device_pool`` contract); aggregate rates redistribute
    per-device.
    """
    ct, hb = _unit_rates(rep)          # validates mesh backing first
    shapes = [tuple(int(d) for d in s) for s in shapes]
    n = math.prod(rep.mesh_shape)
    need = sum(math.prod(s) for s in shapes)
    if need != n:
        raise ValueError(
            f"split of {rep.name!r}: shapes {shapes} use {need} devices but "
            f"the replica's {rep.mesh_shape} slice has {n}")
    adds = tuple(
        Replica(f"{rep.name}/s{i}", math.prod(s) * ct, math.prod(s) * hb,
                arch=rep.arch, mesh_shape=s, ici_gbps=rep.ici_gbps)
        for i, s in enumerate(shapes))
    return ResizeEvent(t, add=adds, remove=(rep.name,),
                       reason=reason or f"split {rep.name} -> {shapes}")


def merge_event(t: float, reps, shape, *, name: str | None = None,
                reason: str = "") -> ResizeEvent:
    """Merge several replicas' devices into one bigger slice.

    The merged slice's device count must equal the sum of the parts; all
    parts must share per-device rates (one chip generation per merge, the
    ``slice_device_pool`` pool contract) — mixing generations would credit
    the merged slice the wrong aggregate capacity.
    """
    reps = list(reps)
    rates = [_unit_rates(r) for r in reps]          # validates mesh backing
    (ct, hb), *rest = rates
    if any(not (math.isclose(c, ct, rel_tol=1e-9)
                and math.isclose(h, hb, rel_tol=1e-9)) for c, h in rest):
        raise ValueError(
            f"merge of {[r.name for r in reps]}: parts have mixed "
            f"per-device rates {rates} — one chip generation per merge")
    shape = tuple(int(d) for d in shape)
    total = sum(math.prod(r.mesh_shape) for r in reps)
    if math.prod(shape) != total:
        raise ValueError(
            f"merge of {[r.name for r in reps]}: target {shape} has "
            f"{math.prod(shape)} devices but the parts hold {total}")
    n = math.prod(shape)
    merged = Replica(name or f"{reps[0].name}/m{'x'.join(map(str, shape))}",
                     n * ct, n * hb, arch=reps[0].arch, mesh_shape=shape,
                     ici_gbps=reps[0].ici_gbps)
    return ResizeEvent(t, add=(merged,), remove=tuple(r.name for r in reps),
                       reason=reason or
                       f"merge {[r.name for r in reps]} -> {shape}")


@dataclass
class FleetControllerConfig:
    """Thresholds for the grow/shrink loop.

    Grow when ANY enabled signal crosses its threshold (``inf`` disables
    one): ``grow_backlog_s`` — mean committed-but-unfinished work per
    replica, in seconds of queue horizon (the serving analogue of the
    paper's ``T_avail`` registers running ahead of the clock);
    ``grow_queue_depth`` — ready requests awaiting dispatch;
    ``grow_p95_s`` — p95 latency over requests *committed in the last*
    ``p95_window_s`` *seconds* (their estimated completion; in the
    simulator a commit pins the finish time).  The window matters: a
    cumulative p95 would latch "overloaded" forever after one spike.
    Shrink (retire the most recent grown replica) when the backlog AND
    queue are both at or under their shrink thresholds and no grow signal
    is firing — shrinking while overloaded would just oscillate against
    the next grow.  ``cooldown_s`` rate-limits decisions; ``max_grown``
    bounds concurrently grown replicas (the spare-device budget).
    """

    grow_backlog_s: float = 2.0
    grow_queue_depth: float = float("inf")
    grow_p95_s: float = float("inf")
    p95_window_s: float = 5.0
    shrink_backlog_s: float = 0.25
    shrink_queue_depth: float = 2.0
    cooldown_s: float = 0.5
    max_grown: int = 4


class FleetController:
    """Load signals → :class:`ResizeEvent`s, with a decision trace.

    ``make_replica(idx)`` is the grow factory — it returns the Replica a
    grow decision adds (e.g. a ``(2, 2)`` slice carved from the spare
    device pool; see :func:`grown_replica_factory`).  The controller owns
    the lifecycle of what it adds: shrink decisions retire its own grown
    replicas (most recent first) and never touch the base fleet.

    The decision trace is structured: ``events`` is a list of
    :class:`repro.obs.TraceEvent` instants (``fleet.grow`` /
    ``fleet.shrink``, stamped at *simulated* time, with the decision's
    ``t``/``kind``/``why`` in args), mirrored into an attached ``tracer``
    so controller decisions land on the same exported timeline as the
    fabric/serve spans.  The legacy ``trace`` list of ``(t, kind, why)``
    tuples is preserved as a derived view.
    """

    def __init__(self, cfg: FleetControllerConfig, make_replica, *,
                 tracer=None):
        self.cfg = cfg
        self._make = make_replica
        self.grown: list[str] = []
        self.events: list[TraceEvent] = []   # structured decision trace
        self._tracer = tracer
        self._last_t = -float("inf")
        self._next_id = 0

    @property
    def trace(self) -> list[tuple[float, str, str]]:
        """Decision log as ``(t, kind, why)`` tuples (compat view over
        :attr:`events`)."""
        return [(e.args["t"], e.args["kind"], e.args["why"])
                for e in self.events]

    def _note(self, t: float, kind: str, why: str) -> None:
        ev = TraceEvent(f"fleet.{kind}", "i", t * 1e6,
                        args={"t": t, "kind": kind, "why": why})
        self.events.append(ev)
        if self._tracer is not None:
            self._tracer.record(ev)

    def observe(self, t: float, *, queue_depth: int = 0,
                backlog_s: float = 0.0,
                p95_s: float = 0.0) -> ResizeEvent | None:
        """One control tick.  Returns the resize to apply now, or None."""
        cfg = self.cfg
        if t - self._last_t < cfg.cooldown_s:
            return None
        overloaded = (backlog_s >= cfg.grow_backlog_s
                      or queue_depth >= cfg.grow_queue_depth
                      or p95_s >= cfg.grow_p95_s)
        if overloaded and len(self.grown) < cfg.max_grown:
            rep = self._make(self._next_id)
            self._next_id += 1
            self.grown.append(rep.name)
            self._last_t = t
            p95 = f" p95={p95_s * 1e3:.0f}ms" if p95_s > 0 else ""
            why = (f"backlog={backlog_s:.2f}s queue={queue_depth}{p95} "
                   f"-> +{rep.name}")
            self._note(t, "grow", why)
            return ResizeEvent(t, add=(rep,), reason=why)
        drained = (backlog_s <= cfg.shrink_backlog_s
                   and queue_depth <= cfg.shrink_queue_depth)
        if drained and not overloaded and self.grown:
            name = self.grown.pop()
            self._last_t = t
            why = f"backlog={backlog_s:.2f}s queue={queue_depth} -> -{name}"
            self._note(t, "shrink", why)
            return ResizeEvent(t, remove=(name,), reason=why)
        return None


def grown_replica_factory(arch: str, shape, *, chip_tflops: float = 197.0,
                          chip_hbm_gbps: float = 819.0, mfu: float = 0.5,
                          hbm_eff: float = 0.6, ici_gbps: float = 0.0):
    """``make_replica`` factory for :class:`FleetController`: each grow adds
    one ``shape``-slice replica of the given chip generation (the same rate
    model as ``mesh_fleet``)."""
    shape = tuple(int(d) for d in shape)
    n = math.prod(shape)

    def make(idx: int) -> Replica:
        return Replica(f"{arch}@{'x'.join(map(str, shape))}+g{idx}",
                       n * chip_tflops * mfu, n * chip_hbm_gbps * hbm_eff,
                       arch=arch, mesh_shape=shape, ici_gbps=ici_gbps)

    return make


def make_spike_requests(base_rps: float, spike_rps: float, *,
                        spike_start: float, spike_end: float,
                        duration_s: float, seed: int = 0,
                        prefill_range=(128, 4096),
                        decode_range=(16, 512)) -> list[Request]:
    """Poisson arrivals with a rate spike in ``[spike_start, spike_end)`` —
    the scripted-load workload the elastic example/benchmark replay.  One
    ``make_requests`` rate function, not a second arrival loop."""
    from repro.sched_integration.serve_scheduler import make_requests

    return make_requests(
        lambda t: spike_rps if spike_start <= t < spike_end else base_rps,
        duration_s, seed=seed,
        prefill_range=prefill_range, decode_range=decode_range)
