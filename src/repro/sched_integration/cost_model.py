"""Exec_TID cost-model registry: dry-run cells → per-replica service times.

The paper's HEFT_RT quality hinges on the accuracy of the per-PE execution
time table (``Exec_TID``) fed to the EFT selector — HTS and DS3 both couple
the hardware scheduler to *measured* per-resource cost tables rather than
analytic guesses.  This module is the serving-layer analogue: the compiled
cost analyses produced by :func:`repro.launch.dryrun.dryrun_cell` (XLA FLOPs,
bytes accessed, collective wire bytes per (arch × shape × mesh) cell) are
materialized into :class:`CostCell` entries, and :class:`CostModelRegistry`
turns them into the (N, P) Exec_TID matrix the
:class:`~repro.sched_integration.fabric.MappingFabric` consumes.

Per-request estimate for a replica whose (arch, mesh) is covered::

    prefill_s = prefill_tokens · cell_p.flops_per_token  / (compute_tflops·1e12)
    decode_s  = decode_tokens  · cell_d.bytes_per_token  / (hbm_gbps·1e9)
    wire_s    = Σ tokens · cell.wire_bytes_per_token     / (ici_gbps·1e9)

where ``*_per_token`` are the cell's *global* per-token costs (per-device
cost × mesh devices ÷ tokens the cell's step processes).  Replicas whose
(arch, kind, mesh_shape) cells are missing fall back to the analytic roofline
(:func:`~repro.sched_integration.fabric.service_time_matrix`) — bitwise
identical to the registry-free path, so a partially-populated registry only
ever *refines* columns of the exec matrix.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, replace

import numpy as np

from repro.sched_integration.fabric import service_time_matrix

_SERVE_KINDS = ("prefill", "decode")


def _mesh_shape_of(mesh) -> tuple[int, ...]:
    """Normalize a mesh descriptor to a tuple of ints.

    Accepts a tuple/list of ints, an ``AxBxC`` string (the dry-run artifact
    form), or a ``jax.sharding.Mesh`` (via ``devices.shape``).
    """
    if mesh is None:
        raise ValueError("mesh shape is required")
    if isinstance(mesh, str):
        return tuple(int(d) for d in mesh.lower().split("x"))
    if hasattr(mesh, "devices"):
        return tuple(mesh.devices.shape)
    return tuple(int(d) for d in mesh)


@dataclass(frozen=True)
class CostCell:
    """One (arch × kind × mesh) dry-run cost cell, per-token normalized.

    ``flops_per_device`` / ``bytes_per_device`` / ``wire_bytes_per_device``
    are one compiled step's per-device costs (the dry-run convention);
    ``tokens_per_step`` is how many *global* tokens that step processes
    (batch × seq for prefill, batch for a one-token decode step).
    """

    arch: str
    kind: str                       # 'prefill' | 'decode'
    mesh_shape: tuple[int, ...]
    tokens_per_step: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float = 0.0
    projected: bool = False         # scaled_cell output, not a measurement

    def __post_init__(self):
        if self.kind not in _SERVE_KINDS:
            raise ValueError(f"kind must be one of {_SERVE_KINDS}, "
                             f"got {self.kind!r}")
        if self.tokens_per_step <= 0:
            raise ValueError("tokens_per_step must be positive")
        object.__setattr__(self, "mesh_shape", _mesh_shape_of(self.mesh_shape))

    @property
    def num_devices(self) -> int:
        return math.prod(self.mesh_shape)

    # global cost per token of the workload this cell models
    @property
    def flops_per_token(self) -> float:
        return self.flops_per_device * self.num_devices / self.tokens_per_step

    @property
    def bytes_per_token(self) -> float:
        return self.bytes_per_device * self.num_devices / self.tokens_per_step

    @property
    def wire_bytes_per_token(self) -> float:
        return (self.wire_bytes_per_device * self.num_devices
                / self.tokens_per_step)

    @classmethod
    def from_dryrun(cls, cell: dict) -> "CostCell | None":
        """Build a cell from one ``dryrun_cell`` result dict (a ``cell_path``
        JSON artifact).  Returns None for cells the serving path cannot use
        (train shapes, failed compiles)."""
        if "error" in cell:
            return None
        from repro.models.config import SHAPES  # lazy: keep import light

        shape = SHAPES.get(cell.get("shape"))
        if shape is None or shape.kind not in _SERVE_KINDS:
            return None
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "prefill"
                                       else 1)
        coll = cell.get("collectives") or {}
        return cls(
            arch=cell["arch"],
            kind=shape.kind,
            mesh_shape=_mesh_shape_of(cell["mesh"]),
            tokens_per_step=tokens,
            flops_per_device=float(cell.get("flops_per_device", 0.0)),
            bytes_per_device=float(cell.get("bytes_accessed_per_device", 0.0)),
            wire_bytes_per_device=float(
                coll.get("total_wire_bytes_per_device", 0.0)),
        )


class CostModelRegistry:
    """(arch × kind × mesh_shape) → :class:`CostCell` lookup table.

    Populated from live :func:`~repro.launch.dryrun.dryrun_cell` results,
    their ``cell_path`` JSON artifacts, or hand-built cells (tests /
    benchmarks).  Consumed by :func:`exec_tid_matrix` (fleet simulation) and
    :meth:`column_s` (live serve front-end).
    """

    def __init__(self, cells=()):
        self._cells: dict[tuple[str, str, tuple[int, ...]], CostCell] = {}
        for c in cells:
            self.register(c)

    def __len__(self) -> int:
        return len(self._cells)

    def register(self, cell: CostCell) -> CostCell:
        self._cells[(cell.arch, cell.kind, cell.mesh_shape)] = cell
        return cell

    def register_dryrun(self, cell_dict: dict) -> CostCell | None:
        cell = CostCell.from_dryrun(cell_dict)
        if cell is not None:
            self.register(cell)
        return cell

    def load_file(self, path: str) -> CostCell | None:
        """Ingest one ``cell_path`` JSON artifact."""
        with open(path) as f:
            return self.register_dryrun(json.load(f))

    def load_dir(self, artifact_dir: str) -> int:
        """Ingest every ``*.json`` cell artifact under ``artifact_dir``;
        returns how many serving-usable cells were registered."""
        n = 0
        if not os.path.isdir(artifact_dir):
            return 0
        for name in sorted(os.listdir(artifact_dir)):
            if name.endswith(".json"):
                if self.load_file(os.path.join(artifact_dir, name)) is not None:
                    n += 1
        return n

    def cell(self, arch, kind, mesh_shape) -> CostCell | None:
        if arch is None or mesh_shape is None:
            return None
        return self._cells.get((arch, kind, _mesh_shape_of(mesh_shape)))

    def covers(self, replica) -> bool:
        """Both serve cells present for this replica's (arch, mesh_shape)."""
        arch = getattr(replica, "arch", None)
        mesh_shape = getattr(replica, "mesh_shape", None)
        return all(self.cell(arch, k, mesh_shape) is not None
                   for k in _SERVE_KINDS)

    def ensure_coverage(self, replica, *, efficiency: float = 0.9) -> bool:
        """Cover a replica whose mesh shape was never dry-run by projection.

        Elastic resize events add replicas with shapes that may have no
        measured cells yet; rather than dropping those columns to the blank
        roofline, the arch's *largest* measured cell per kind is projected
        onto the new shape with :func:`scaled_cell` (the measured anchor
        plus the ``efficiency`` overhead gradient).  Cells that are
        themselves projections are never used as anchors — otherwise the
        discount would compound and the estimates would depend on join
        order.  Registration is atomic: either both serve kinds end up
        covered or nothing is registered.  Returns whether the replica is
        covered afterwards.
        """
        arch = getattr(replica, "arch", None)
        mesh_shape = getattr(replica, "mesh_shape", None)
        if arch is None or mesh_shape is None:
            return False
        target = _mesh_shape_of(mesh_shape)
        missing = [k for k in _SERVE_KINDS
                   if (arch, k, target) not in self._cells]
        if not missing:
            return True
        chosen = {}
        for kind in missing:
            srcs = [c for (a, k, _), c in self._cells.items()
                    if a == arch and k == kind and not c.projected]
            if not srcs:
                return False
            chosen[kind] = max(srcs, key=lambda c: (c.num_devices,
                                                    c.mesh_shape))
        for src in chosen.values():
            self.register(scaled_cell(src, target, efficiency=efficiency))
        return True

    # -- estimates -----------------------------------------------------------

    def column_s(self, replica, prefill_tokens, decode_tokens):
        """Exec_TID column for one replica, vectorized over requests.

        ``prefill_tokens`` / ``decode_tokens``: float64 arrays (N,).  Returns
        seconds (N,), or None when the replica's cells (or hardware rates)
        are missing — callers fall back to their analytic estimate.
        """
        arch = getattr(replica, "arch", None)
        mesh_shape = getattr(replica, "mesh_shape", None)
        compute = getattr(replica, "compute_tflops", None)
        hbm = getattr(replica, "hbm_gbps", None)
        cp = self.cell(arch, "prefill", mesh_shape)
        cd = self.cell(arch, "decode", mesh_shape)
        if cp is None or cd is None or not compute or not hbm:
            return None
        pf = np.asarray(prefill_tokens, dtype=np.float64)
        dc = np.asarray(decode_tokens, dtype=np.float64)
        t = (pf * cp.flops_per_token / (compute * 1e12)
             + dc * cd.bytes_per_token / (hbm * 1e9))
        ici = getattr(replica, "ici_gbps", 0.0) or 0.0
        if ici > 0:
            t = t + (pf * cp.wire_bytes_per_token
                     + dc * cd.wire_bytes_per_token) / (ici * 1e9)
        return t

    def exec_tid_matrix(self, requests, replicas, *,
                        active_params: float) -> np.ndarray:
        """Full (N, P) Exec_TID matrix: cost-model columns where covered,
        analytic roofline (bitwise ``service_time_matrix``) elsewhere."""
        ex = service_time_matrix(requests, replicas,
                                 active_params=active_params)
        pf = np.array([r.prefill_tokens for r in requests], dtype=np.float64)
        dc = np.array([r.decode_tokens for r in requests], dtype=np.float64)
        for j, rep in enumerate(replicas):
            col = self.column_s(rep, pf, dc)
            if col is not None:
                ex[:, j] = col
        return ex


def registry_from_dryrun_artifacts(artifact_dir: str | None = None
                                   ) -> CostModelRegistry:
    """Registry seeded from the dry-run artifact directory (default: the
    repo's ``experiments/artifacts/dryrun``), empty if none exist."""
    if artifact_dir is None:
        artifact_dir = os.path.join(
            os.path.dirname(__file__), "..", "..", "..",
            "experiments", "artifacts", "dryrun")
    reg = CostModelRegistry()
    reg.load_dir(artifact_dir)
    return reg


def scaled_cell(cell: CostCell, mesh_shape, *, efficiency: float = 1.0
                ) -> CostCell:
    """Project a measured cell onto another mesh shape of the same arch.

    Per-device compute/memory cost scales inversely with device count, with
    ``efficiency`` ≤ 1 modelling the overhead gradient across mesh sizes:
    scaling *up* inflates the projected per-token cost by 1/efficiency (the
    larger mesh pays more collective overhead than the measured point),
    scaling *down* deflates it by efficiency (the smaller mesh sheds
    overhead the measurement included).  Wire bytes per device are kept
    as-is — a conservative stand-in until the target cell is dry-run for
    real.  Used to seed heterogeneous-fleet registries from a single
    measured cell.
    """
    target = _mesh_shape_of(mesh_shape)
    n_target = math.prod(target)
    ratio = cell.num_devices / n_target
    if n_target > cell.num_devices:
        ratio /= efficiency
    elif n_target < cell.num_devices:
        ratio *= efficiency
    return replace(
        cell, mesh_shape=target,
        flops_per_device=cell.flops_per_device * ratio,
        bytes_per_device=cell.bytes_per_device * ratio,
        wire_bytes_per_device=cell.wire_bytes_per_device,
        projected=True,
    )
