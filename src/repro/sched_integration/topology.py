"""Inter-pod network topology: links, contention, and failure domains.

The serving simulator treated every byte move as free — migrations landed
instantly and replicas never contended for wire.  Real fleets are a *graph*:
pods hang off links with finite bandwidth and latency, concurrent transfers
(a replica migration and a gradient collective crossing the same spine link)
contend for the same wire, and links degrade or partition outright.  The
YAFS discrete-event exemplar (SNIPPETS.md §3) models exactly this —
topology + link metrics + ``node_up``/``node_down`` events — and this module
is our deterministic, simulator-grade port of that idea:

* :class:`Topology` — an undirected link graph over named pods.  Each link
  carries bandwidth (GB/s), latency, a FIFO *reservation horizon* (the
  contention model: a transfer occupies every link on its path until it
  finishes, so a second flow sharing a link queues behind the first), a
  degrade factor, a background-utilization fraction (steady collective
  traffic stealing wire), and a down-window (partition).
* :meth:`Topology.transfer_s` — topology-derived time for moving ``nbytes``
  between pods: shortest-hop path, start at the max of the caller's clock
  and every path link's horizon (and past any down-window), duration =
  path latency + bytes over the path's narrowest *effective* bandwidth.
  ``reserve=True`` commits the flow to the links, which is what makes two
  concurrent migrations serialize instead of magically overlapping.
* :meth:`Topology.collective_s` — a ring collective over a pod set: every
  ring hop reserves its pairwise path, so a collective crossing a link a
  migration holds queues behind it (and vice versa) — contention between
  traffic *classes*, not just flows.
* Failure-domain state — :meth:`degrade` / :meth:`set_down` /
  :meth:`restore` are the mutation points the chaos tier's
  ``link_degrade`` / ``link_partition`` :class:`~repro.sched_integration.
  fleet.FailureEvent`s drive; :meth:`reachable` answers "can the gateway
  still dispatch to this pod at time t" for the scheduler's partition mask.

Recovery contract (with ``simulate_serving``): a replica behind a
partitioned path is *masked* (its Exec_TID column dispatches as ``+inf``)
for the window — in-flight work keeps running (its KV is pod-local), only
new admissions divert; when the window closes the column is restored
bit-exact from the same cost model that built it.  Migrations started into
(or across) a down link simply wait the window out: ``transfer_s`` never
drops a flow, it delays it — the same never-silently-dropped accounting the
request path obeys.

Determinism: every method is a pure function of the call sequence — no wall
clock, no RNG — so chaos timelines replay bit-identically.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

_INF = float("inf")


def link_key(a: str, b: str) -> tuple[str, str]:
    """Canonical (sorted) undirected edge key."""
    return (a, b) if a <= b else (b, a)


def parse_link_target(target: str) -> tuple[str, str]:
    """Parse a failure-event link target ``"a:b"`` into an edge key."""
    parts = target.split(":")
    if len(parts) != 2 or not parts[0] or not parts[1]:
        raise ValueError(
            f"link target must be 'podA:podB', got {target!r}")
    return link_key(parts[0], parts[1])


@dataclass
class Link:
    """One undirected inter-pod link and its live state.

    ``gbps`` is the healthy bandwidth in GB/s; the *effective* bandwidth at
    any instant is ``gbps * degrade * (1 - background_util)`` — the degrade
    factor is the chaos tier's ``link_degrade`` knob, the background
    utilization models steady gradient-collective traffic claiming a fixed
    share of the wire.  ``free_at`` is the FIFO reservation horizon
    (contention: flows through this link serialize past it); ``down_until``
    is the partition window's end (``-inf`` when up).
    """

    a: str
    b: str
    gbps: float
    latency_s: float = 0.0
    degrade: float = 1.0
    background_util: float = 0.0
    free_at: float = 0.0
    down_until: float = field(default=-_INF)

    def effective_bps(self) -> float:
        """Bytes/sec the link currently moves (degrade + background load)."""
        return self.gbps * 1e9 * self.degrade * (1.0 - self.background_util)

    def up_at(self, t: float) -> bool:
        return t >= self.down_until


class Topology:
    """Undirected link graph over pods, with per-link contention state.

    ``pod_of`` maps replica names to pod nodes (replicas not listed live
    "nowhere" and are exempt from reachability masking); ``gateway`` names
    the pod requests are dispatched *from* (and params are migrated from) —
    with no gateway set, reachability masking and migration charging are
    disabled and the topology is purely a transfer-time model.
    """

    def __init__(self, *, pod_of: dict[str, str] | None = None,
                 gateway: str | None = None):
        self._links: dict[tuple[str, str], Link] = {}
        self._adj: dict[str, list[str]] = {}
        self.pod_of: dict[str, str] = dict(pod_of or {})
        self.gateway = gateway

    # -- construction --------------------------------------------------------

    def connect(self, a: str, b: str, gbps: float,
                latency_s: float = 0.0) -> Link:
        """Add (or replace) the undirected link between pods ``a`` and
        ``b``."""
        if a == b:
            raise ValueError(f"self-link {a!r}:{b!r}")
        if gbps <= 0:
            raise ValueError(f"link {a}:{b} bandwidth must be > 0, got {gbps}")
        key = link_key(a, b)
        ln = Link(*key, gbps=float(gbps), latency_s=float(latency_s))
        self._links[key] = ln
        self._adj.setdefault(a, [])
        self._adj.setdefault(b, [])
        if b not in self._adj[a]:
            self._adj[a].append(b)
            self._adj[a].sort()
        if a not in self._adj[b]:
            self._adj[b].append(a)
            self._adj[b].sort()
        return ln

    def link(self, a: str, b: str) -> Link:
        key = link_key(a, b)
        if key not in self._links:
            raise KeyError(f"no link {key[0]}:{key[1]} in "
                           f"{sorted(self._links)}")
        return self._links[key]

    @property
    def pods(self) -> list[str]:
        return sorted(self._adj)

    @property
    def links(self) -> list[Link]:
        return [self._links[k] for k in sorted(self._links)]

    # -- failure-domain mutations (driven by FailureEvents) ------------------

    def degrade(self, a: str, b: str, factor: float) -> None:
        """Scale the link's bandwidth by ``factor`` (0 < factor ≤ 1)."""
        if not (0.0 < factor <= 1.0):
            raise ValueError(f"degrade factor must be in (0, 1], got {factor}")
        self.link(a, b).degrade = float(factor)

    def restore(self, a: str, b: str) -> None:
        """Clear a degrade back to the healthy bandwidth."""
        self.link(a, b).degrade = 1.0

    def set_down(self, a: str, b: str, until: float) -> None:
        """Partition the link until time ``until`` (extends, never shrinks,
        an already-open window)."""
        ln = self.link(a, b)
        ln.down_until = max(ln.down_until, float(until))

    def set_background_util(self, a: str, b: str, frac: float) -> None:
        """Claim a steady fraction of the link for background collective
        traffic (0 ≤ frac < 1) — foreground transfers see the remainder."""
        if not (0.0 <= frac < 1.0):
            raise ValueError(
                f"background_util must be in [0, 1), got {frac}")
        self.link(a, b).background_util = float(frac)

    # -- reachability --------------------------------------------------------

    def path(self, a: str, b: str, *, at: float = _INF) -> list[Link] | None:
        """Shortest-hop path as a link list, or None if ``b`` is unreachable
        from ``a`` over links up at time ``at``.  ``at=inf`` routes over the
        full graph ignoring down-windows (every window ends).  Deterministic:
        BFS with name-sorted neighbour expansion."""
        if a == b:
            return []
        if a not in self._adj or b not in self._adj:
            return None
        prev: dict[str, str] = {a: a}
        frontier = [a]
        while frontier:
            nxt = []
            for u in frontier:
                for v in self._adj[u]:
                    if v in prev or not self._links[link_key(u, v)].up_at(at):
                        continue
                    prev[v] = u
                    if v == b:
                        out = []
                        while v != a:
                            out.append(self._links[link_key(prev[v], v)])
                            v = prev[v]
                        return out[::-1]
                    nxt.append(v)
            frontier = nxt
        return None

    def reachable(self, a: str, b: str, *, at: float) -> bool:
        """Is ``b`` reachable from ``a`` over links up at time ``at``?"""
        return self.path(a, b, at=at) is not None

    def replica_reachable(self, name: str, *, at: float) -> bool:
        """Can the gateway dispatch to replica ``name`` at time ``at``?
        Replicas with no pod mapping (or no gateway set) are always
        reachable — topology masking is opt-in per replica."""
        pod = self.pod_of.get(name)
        if pod is None or self.gateway is None:
            return True
        return self.reachable(self.gateway, pod, at=at)

    # -- transfer-time model -------------------------------------------------

    def transfer_s(self, nbytes: float, a: str, b: str, *, at: float = 0.0,
                   reserve: bool = True) -> tuple[float, float]:
        """Topology-derived ``(start, finish)`` for moving ``nbytes`` from
        pod ``a`` to pod ``b``, starting no earlier than ``at``.

        The flow takes the shortest-hop path; its start waits for every path
        link's FIFO horizon (contention with earlier reservations) *and* for
        any down-window covering the start instant (a partition delays the
        flow, never drops it); duration is the summed path latency plus
        bytes over the narrowest effective bandwidth.  ``reserve=True``
        advances every path link's horizon to the finish — later flows
        sharing any of those links queue behind this one.
        """
        if nbytes < 0:
            raise ValueError(f"nbytes must be >= 0, got {nbytes}")
        path = self.path(a, b)            # route over the full graph
        if path is None:
            raise ValueError(f"no path {a!r} -> {b!r} in the topology")
        if not path:
            return (at, at)
        start = float(at)
        for ln in path:
            start = max(start, ln.free_at, ln.down_until)
        bps = min(ln.effective_bps() for ln in path)
        dur = sum(ln.latency_s for ln in path) + nbytes / bps
        finish = start + dur
        if reserve:
            for ln in path:
                ln.free_at = finish
        return (start, finish)

    def collective_s(self, nbytes: float, pods, *, at: float = 0.0,
                     reserve: bool = True) -> tuple[float, float]:
        """Ring collective over ``pods``: ``(start, finish)`` of an
        all-reduce moving ``nbytes`` of payload per pod.

        Each ring hop (pod i → pod i+1, wrapping) carries the standard ring
        all-reduce wire volume ``2 * nbytes * (P-1)/P`` and reserves its
        pairwise path, so hops sharing a physical link serialize — and a
        collective crossing a link a migration holds queues behind it.  The
        returned finish is the slowest hop's.
        """
        pods = list(pods)
        if len(pods) < 2:
            return (at, at)
        per_hop = 2.0 * nbytes * (len(pods) - 1) / len(pods)
        start = finish = float(at)
        for i, src in enumerate(pods):
            dst = pods[(i + 1) % len(pods)]
            s, f = self.transfer_s(per_hop, src, dst, at=at, reserve=reserve)
            start = min(start, s) if i else s
            finish = max(finish, f)
        return (start, finish)


def fully_connected(pods, gbps: float, latency_s: float = 0.0, *,
                    pod_of: dict[str, str] | None = None,
                    gateway: str | None = None) -> Topology:
    """Uniform all-to-all topology over ``pods`` — the quick-start fabric
    for tests/benchmarks (every pod pair gets a dedicated link)."""
    topo = Topology(pod_of=pod_of, gateway=gateway)
    pods = list(pods)
    for i, a in enumerate(pods):
        for b in pods[i + 1:]:
            topo.connect(a, b, gbps, latency_s)
    return topo


def spine_topology(pods, gbps: float, latency_s: float = 0.0, *,
                   spine: str = "spine", pod_of: dict[str, str] | None = None,
                   gateway: str | None = None) -> Topology:
    """Star topology: every pod hangs off one shared ``spine`` node — the
    maximally contended fabric (every cross-pod byte shares spine links)."""
    topo = Topology(pod_of=pod_of, gateway=gateway)
    for p in pods:
        topo.connect(p, spine, gbps, latency_s)
    return topo


def migration_bytes(active_params: float) -> float:
    """Wire bytes a replica migration moves: one bf16 copy of the params
    (the unit ``simulate_serving`` charges a topology-backed joiner)."""
    return 2.0 * float(active_params)
