"""HEFT_RT applied to MoE expert placement — the paper's scheduler as a
first-class feature of the training/serving framework.

Problem: expert-parallel MoE shards experts over devices in index order; with
skewed routing (real workloads are Zipfian) some devices carry far more token
load than others and the all-to-all + expert compute is bottlenecked by the
hottest device (the makespan).

Mapping to the paper's abstraction: *experts are the ready queue, devices are
the PEs*.  ``Avg_TID`` = expert load × mean device cost; ``Exec[e,p]`` =
load[e] / speed[p]; ``T_avail`` = load already committed to each device.  One
HEFT_RT mapping event (same code path as the FPGA overlay kernels) yields a
greedy-makespan placement; the permutation is applied to the stacked expert
weights AND the router columns, so the model function is exactly preserved
(tests assert output invariance).
"""

from __future__ import annotations

import numpy as np

from repro.core import heft_rt_numpy


def plan_expert_placement(
    expert_load: np.ndarray,       # (E,) tokens routed to each expert
    device_speed: np.ndarray,      # (P,) relative throughput of each device
) -> np.ndarray:
    """Returns device assignment (E,) minimizing (greedily) the makespan."""
    expert_load = np.asarray(expert_load, dtype=np.float64)
    device_speed = np.asarray(device_speed, dtype=np.float64)
    E, P = expert_load.shape[0], device_speed.shape[0]
    exec_times = expert_load[:, None] / device_speed[None, :]      # (E, P)
    avg = exec_times.mean(axis=1)
    avail = np.zeros(P)
    order, assignment, _, _, _ = heft_rt_numpy(avg, exec_times, avail)
    out = np.empty(E, dtype=np.int64)
    out[order] = assignment
    return out


def balanced_capacity_assignment(assignment: np.ndarray, num_devices: int,
                                 experts_per_device: int) -> np.ndarray:
    """Enforce equal experts-per-device (EP sharding needs a rectangular
    layout): overflowing experts move to the least-loaded underfull device,
    preserving the HEFT ordering priority."""
    E = assignment.shape[0]
    assert E == num_devices * experts_per_device
    counts = np.zeros(num_devices, dtype=np.int64)
    out = np.empty(E, dtype=np.int64)
    # process experts in descending index of... keep original order
    overflow = []
    for e in range(E):
        d = assignment[e]
        if counts[d] < experts_per_device:
            out[e] = d
            counts[d] += 1
        else:
            overflow.append(e)
    for e in overflow:
        d = int(np.argmin(counts))
        out[e] = d
        counts[d] += 1
    return out


def placement_permutation(assignment: np.ndarray, num_devices: int,
                          experts_per_device: int) -> np.ndarray:
    """perm[new_slot] = old_expert_index.

    Slot layout: device d owns contiguous slots [d*epd, (d+1)*epd) — matching
    how the expert axis shards over the 'model' mesh axis."""
    assignment = balanced_capacity_assignment(assignment, num_devices,
                                              experts_per_device)
    slots: list[list[int]] = [[] for _ in range(num_devices)]
    for e, d in enumerate(assignment):
        slots[d].append(e)
    perm = np.concatenate([np.array(s, dtype=np.int64) for s in slots])
    return perm


def apply_placement(moe_params: dict, perm: np.ndarray) -> dict:
    """Permute stacked expert weights + router columns by ``perm``.

    Output-preserving: router column j of the new layout is old column
    perm[j], and expert slot j holds old expert perm[j].
    """
    import jax.numpy as jnp
    perm = jnp.asarray(perm)
    out = dict(moe_params)
    out["router"] = moe_params["router"][:, perm]
    out["experts"] = {k: v[perm] for k, v in moe_params["experts"].items()}
    return out


def makespan(expert_load: np.ndarray, device_speed: np.ndarray,
             assignment: np.ndarray) -> float:
    load = np.zeros(device_speed.shape[0])
    for e, d in enumerate(assignment):
        load[d] += expert_load[e] / device_speed[d]
    return float(load.max())


def round_robin_assignment(num_experts: int, num_devices: int) -> np.ndarray:
    """The default EP layout: expert e on device e // (E/P)."""
    epd = num_experts // num_devices
    return np.repeat(np.arange(num_devices), epd)
