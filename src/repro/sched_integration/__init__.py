# The paper's scheduler integrated as first-class framework features:
# MoE expert placement, serving-request dispatch, the fabric-batched
# mapping-event pipeline, and the chaos tier (topology + failure timelines).
from repro.sched_integration.expert_placement import (
    apply_placement,
    makespan,
    placement_permutation,
    plan_expert_placement,
    round_robin_assignment,
)
from repro.sched_integration.cost_model import (
    CostCell,
    CostModelRegistry,
    registry_from_dryrun_artifacts,
    scaled_cell,
)
from repro.sched_integration.fabric import (
    MappingFabric,
    eft_dispatch_numpy,
    heft_rt_fast,
    make_policy_fabric,
    pow2_bucket,
    service_time_matrix,
)
from repro.sched_integration.serve_scheduler import (
    POLICIES,
    Replica,
    Request,
    ServeResult,
    default_fleet,
    goodput,
    make_requests,
    mesh_fleet,
    simulate_serving,
)
from repro.sched_integration.fleet import (
    FAILURE_KINDS,
    FailureEvent,
    FleetController,
    FleetControllerConfig,
    ResizeEvent,
    grown_replica_factory,
    load_failure_timeline,
    make_spike_requests,
    merge_event,
    split_event,
    validate_failure_timeline,
)
from repro.sched_integration.topology import (
    Link,
    Topology,
    fully_connected,
    migration_bytes,
    parse_link_target,
    spine_topology,
)

__all__ = [
    "apply_placement", "makespan", "placement_permutation",
    "plan_expert_placement", "round_robin_assignment",
    "CostCell", "CostModelRegistry", "registry_from_dryrun_artifacts",
    "scaled_cell",
    "MappingFabric", "eft_dispatch_numpy", "heft_rt_fast",
    "make_policy_fabric", "pow2_bucket", "service_time_matrix",
    "POLICIES", "Replica", "Request", "ServeResult", "default_fleet",
    "goodput", "make_requests", "mesh_fleet", "simulate_serving",
    "FAILURE_KINDS", "FailureEvent", "FleetController",
    "FleetControllerConfig", "ResizeEvent", "grown_replica_factory",
    "load_failure_timeline", "make_spike_requests", "merge_event",
    "split_event", "validate_failure_timeline",
    "Link", "Topology", "fully_connected", "migration_bytes",
    "parse_link_target", "spine_topology",
]
