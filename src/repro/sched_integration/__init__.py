# The paper's scheduler integrated as first-class framework features:
# MoE expert placement and serving-request dispatch.
from repro.sched_integration.expert_placement import (
    apply_placement,
    makespan,
    placement_permutation,
    plan_expert_placement,
    round_robin_assignment,
)
from repro.sched_integration.serve_scheduler import (
    POLICIES,
    Replica,
    Request,
    ServeResult,
    default_fleet,
    make_requests,
    simulate_serving,
)

__all__ = [
    "apply_placement", "makespan", "placement_permutation",
    "plan_expert_placement", "round_robin_assignment",
    "POLICIES", "Replica", "Request", "ServeResult", "default_fleet",
    "make_requests", "simulate_serving",
]
