"""Fault-tolerant checkpointing: atomic, asynchronous, keep-k, elastic.

* **Atomic**: checkpoints are written to ``<dir>/tmp.<step>`` and
  ``os.replace``d into place — a crash mid-write can never corrupt the
  latest-good checkpoint (restart always finds a complete one).
* **Async**: ``save()`` snapshots device arrays to host, then a background
  thread serializes — the training loop is blocked only for the device→host
  copy (the classic async-checkpoint overlap).
* **Keep-k**: bounded disk footprint, oldest checkpoints pruned after a
  successful save.
* **Elastic**: leaves are stored as *full* (unsharded) arrays keyed by pytree
  path, so a restore may target ANY mesh/sharding — ``restore(...,
  shardings=...)`` device_puts each leaf with the new layout (scale up/down
  across restarts).  Optimizer-state int8 leaves round-trip losslessly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten_into(v, flat, f"{prefix}{i}/")
               for i, v in enumerate(template)]
        return type(template)(seq)
    if template is None:
        return None
    return flat[prefix.rstrip("/")]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ---- save --------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False,
             metadata: dict | None = None) -> None:
        self.wait()  # one in-flight save at a time
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device→host now

        def _write():
            try:
                tmp = os.path.join(self.dir, f"tmp.{step}")
                final = os.path.join(self.dir, f"step_{step:08d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                meta = {"step": step, "time": time.time(), **(metadata or {})}
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)          # atomic publish
                self._prune()
            except Exception as e:  # surfaced on next wait()/save()
                self._error = e

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _prune(self):
        steps = sorted(self.available_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore -------------------------------------------------------------

    def available_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, *, shardings=None):
        """Rebuild ``template``-shaped pytree.  ``shardings``: optional pytree
        (matching template) of jax.sharding.Sharding for elastic placement —
        applied through ``dist.sharding.reshard_tree``, the same in-memory
        migration primitive live replicas use."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            from repro.dist.sharding import reshard_tree  # lazy: keep import light
            tree = reshard_tree(tree, shardings)
        return tree

    def read_metadata(self, step: int | None = None) -> dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:08d}", "meta.json")) as f:
            return json.load(f)
