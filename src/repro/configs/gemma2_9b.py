"""gemma2-9b [dense] — local/global alternating attention + logit softcaps.

42L d_model=3584 16H (kv=8, head_dim=256) d_ff=14336 vocab=256000
[arXiv:2408.00118; hf].  GeGLU, sandwich norms, tied embeddings, embed scale,
attn softcap 50, final logit softcap 30, local window 4096.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256_000, ffn_type="geglu",
    window_pattern=("local", "global"), local_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, post_block_norm=True,
    tie_embeddings=True, embed_scale=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=224, vocab_size=256, ffn_type="geglu",
        window_pattern=("local", "global"), local_window=8,
        attn_softcap=50.0, logit_softcap=30.0, post_block_norm=True,
        tie_embeddings=True, embed_scale=True,
        param_dtype="float32", compute_dtype="float32",
    )
