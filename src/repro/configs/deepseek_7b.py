"""deepseek-7b [dense] — llama-arch MHA (GQA kv=32).

30L d_model=4096 32H d_ff=11008 vocab=102400 [arXiv:2401.02954; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=172, vocab_size=160,
        param_dtype="float32", compute_dtype="float32",
    )
