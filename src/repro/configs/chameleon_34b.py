"""chameleon-34b [vlm] — early-fusion VQ image+text tokens, qk-norm.

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818].
Backbone only: the VQ image tokenizer frontend is a stub — input_specs()
feeds mixed-modal token ids in [0, 65536).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True, modality="vlm",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke",
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=1,
        d_ff=172, vocab_size=256, qk_norm=True, modality="vlm",
        param_dtype="float32", compute_dtype="float32",
    )
