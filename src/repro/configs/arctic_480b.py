"""arctic-480b [moe] — 128 experts top-2 + dense residual.

35L d_model=7168 56H (kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base].  The dense MLP runs in parallel with
the MoE on every layer (dense_residual).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual=True, dense_residual_d_ff=4864),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke",
        num_layers=3, d_model=56, num_heads=7, num_kv_heads=1,
        d_ff=112, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=112,
                      dense_residual=True, dense_residual_d_ff=112,
                      capacity_factor=2.0),
        param_dtype="float32", compute_dtype="float32",
    )
