"""phi3-medium-14b [dense] — RoPE SwiGLU GQA kv=10.

40L d_model=5120 40H (kv=10) d_ff=17920 vocab=100352 [arXiv:2404.14219].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=224, vocab_size=160,
        param_dtype="float32", compute_dtype="float32",
    )
