# Assigned architectures (public-literature configs) + paper SoC config.
# Each module exposes CONFIG (full) and smoke() (reduced, CPU-runnable).
from __future__ import annotations

import importlib

ARCH_IDS = [
    "musicgen_medium",
    "deepseek_7b",
    "phi3_medium_14b",
    "gemma2_9b",
    "yi_34b",
    "deepseek_v2_236b",
    "arctic_480b",
    "falcon_mamba_7b",
    "jamba_v0_1_52b",
    "chameleon_34b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIAS.update({
    "musicgen-medium": "musicgen_medium",
    "deepseek-7b": "deepseek_7b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma2-9b": "gemma2_9b",
    "yi-34b": "yi_34b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "chameleon-34b": "chameleon_34b",
})


def _module(name: str):
    key = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).smoke()


def all_arch_names() -> list[str]:
    return list(ARCH_IDS)
