"""falcon-mamba-7b [ssm] — attention-free Mamba-1 stack.

64L d_model=4096 d_inner=8192 ssm_state=16 vocab=65024 [arXiv:2410.05355].
No FFN sub-block (d_ff=0): each layer is norm + mamba mixer + residual.
Falcon-Mamba RMS-normalizes B/C/Δ (bcdt_rms).  Runs long_500k (sub-quadratic).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    block_pattern=("mamba",),
    ssm=SSMConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256,
                  chunk=16, bcdt_rms=True),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        num_layers=4, d_model=64, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=128,
        block_pattern=("mamba",),
        ssm=SSMConfig(d_inner=128, d_state=8, d_conv=4, dt_rank=8,
                      chunk=4, bcdt_rms=True),
        param_dtype="float32", compute_dtype="float32",
    )
