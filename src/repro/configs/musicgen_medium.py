"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
Backbone only: the EnCodec frontend is a stub — input_specs() feeds token ids
in [0, 2048) (precomputed frame embeddings enter through the same table).
GELU FFN; RoPE stands in for the original sinusoidal positions (documented
hardware adaptation: one positional scheme across the zoo).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, ffn_type="gelu", modality="audio",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        num_layers=4, d_model=96, num_heads=6, num_kv_heads=6,
        d_ff=384, vocab_size=128, ffn_type="gelu", modality="audio",
        param_dtype="float32", compute_dtype="float32",
    )
