"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf].  Attention at layer offset 4 of every 8 (1:7 ratio);
MoE on every 2nd layer (offset 1).  Runs long_500k (sub-quadratic: only 4
full-attention layers, bounded KV).
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm=SSMConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256, chunk=16),
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=14336,
                  layer_period=2, layer_offset=1),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=128,
        block_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        ssm=SSMConfig(d_inner=128, d_state=8, d_conv=4, dt_rank=8, chunk=4),
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=160,
                      layer_period=2, layer_offset=1, capacity_factor=2.0),
        param_dtype="float32", compute_dtype="float32",
    )
