"""yi-34b [dense] — llama-arch GQA kv=8.

60L d_model=7168 56H (kv=8) d_ff=20480 vocab=64000 [arXiv:2403.04652; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke",
        num_layers=3, d_model=56, num_heads=7, num_kv_heads=1,
        d_ff=160, vocab_size=128,
        param_dtype="float32", compute_dtype="float32",
    )
