"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared / 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400 [arXiv:2405.04434; hf].
MLA: q_lora=1536, kv_lora=512, nope=128, rope=64, v=128.  First layer dense
(d_ff 12288); every other layer MoE with 2 shared experts.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    first_dense_layers=1, first_dense_d_ff=12288,
    moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                  num_shared_experts=2, shared_d_ff=3072),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=192, vocab_size=160,
        attn_type="mla", kv_lora_rank=32, q_lora_rank=48,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        first_dense_layers=1, first_dense_d_ff=192,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=48,
                      num_shared_experts=2, shared_d_ff=96,
                      capacity_factor=2.0),
        param_dtype="float32", compute_dtype="float32",
    )
