from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedule import constant, inverse_sqrt, warmup_cosine

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state",
           "constant", "inverse_sqrt", "warmup_cosine"]
