"""AdamW with configurable moment precision: f32 / bf16 / blockwise-int8.

The int8 mode stores both Adam moments as blockwise-quantized int8 with
per-block (128) absmax scales — 1.03 bytes/param/moment instead of 4 — which
is what lets the ≥100B assigned architectures (arctic-480b, deepseek-v2-236b)
fit optimizer state in HBM at 256-512 chips (see EXPERIMENTS.md §Dry-run).
Quantization error is re-absorbed each step because the moments are
reconstructed, updated in f32, and re-quantized (second-moment ``v`` uses a
signed-sqrt transform to spend int8 resolution where v is small).

Pure pytree implementation — works under jit/pjit, optimizer state inherits
parameter shardings leaf-by-leaf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float | None = 1.0
    moment_dtype: str = "float32"   # 'float32' | 'bfloat16' | 'int8'


# ---------------------------------------------------------------------------
# int8 moment storage — PARAM-SHAPED with per-row (last-dim) absmax scales.
#
# Deliberately reshape-free: a flat (blocks, 128) layout forces GSPMD to
# rematerialize the full unsharded f32 tensor on every device when the param
# is sharded (arbitrary flattening of a sharded tensor cannot be partitioned).
# Param-shaped q + (..., 1) scales inherit the parameter sharding exactly, so
# quantize/dequantize stay fully local.  Rows (d_ff/d_model-sized) are coarser
# than 128-blocks; the signed-sqrt transform on v spends resolution where v is
# small, and moments are reconstructed/updated/requantized in f32 every step.
# 1-D leaves (norms, biases) stay f32 — negligible memory.
# ---------------------------------------------------------------------------

def _signed_sqrt(x):
    return jnp.sign(x) * jnp.sqrt(jnp.abs(x))


def _signed_square(x):
    return jnp.sign(x) * jnp.square(x)


def _store_moment(x: jax.Array, dtype: str, transform: bool = False):
    if dtype == "int8" and x.ndim >= 2:
        t = _signed_sqrt(x) if transform else x
        scale = jnp.max(jnp.abs(t), axis=-1, keepdims=True) / 127.0
        q = jnp.round(t / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    if dtype == "int8":
        return x.astype(jnp.float32)
    return x.astype(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)


def _load_moment(stored, shape, dtype: str, transform: bool = False):
    if isinstance(stored, dict):
        x = stored["q"].astype(jnp.float32) * stored["scale"]
        return _signed_square(x) if transform else x
    return stored.astype(jnp.float32)


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    def fresh_zero(p):  # distinct buffers for m and v (donation-safe)
        return _store_moment(jnp.zeros(p.shape, jnp.float32), cfg.moment_dtype)

    def fresh_zero_v(p):
        return _store_moment(jnp.zeros(p.shape, jnp.float32), cfg.moment_dtype,
                             True)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(fresh_zero, params),
        "v": jax.tree.map(fresh_zero_v, params),
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.learning_rate(step) if callable(cfg.learning_rate) else cfg.learning_rate
    gnorm = _global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = 1.0

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    # leaves at or above this element count update via a scan over their
    # leading (layer-stack) dim — bounds the f32 reconstruct/update transients
    # to one layer's slice instead of the whole stacked tensor.
    SCAN_THRESHOLD = 1 << 27

    def leaf_core(p, g, m_s, v_s, decay: bool):
        g = g.astype(jnp.float32) * scale
        m = _load_moment(m_s, p.shape, cfg.moment_dtype)
        v = _load_moment(v_s, p.shape, cfg.moment_dtype, True)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay and decay:
            pf = pf * (1.0 - lr * cfg.weight_decay)
        new_p = (pf - lr * upd).astype(p.dtype)
        return new_p, _store_moment(m, cfg.moment_dtype), \
            _store_moment(v, cfg.moment_dtype, True)

    def leaf_update(p, g, m_s, v_s):
        decay = p.ndim >= 2            # decay matrices only
        if p.ndim >= 3 and p.size >= SCAN_THRESHOLD:
            def body(_, xs):
                pi, gi, mi, vi = xs
                return None, leaf_core(pi, gi, mi, vi, decay)
            _, out = jax.lax.scan(body, None, (p, g, m_s, v_s))
            return out
        return leaf_core(p, g, m_s, v_s, decay)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [leaf_update(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
