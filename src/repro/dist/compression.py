"""Pod-level gradient collectives: exact mean and int8 error-feedback mean.

Cross-pod links are the slowest hop in a multi-pod mesh, and the cross-pod
all-reduce of the full gradient is the only traffic that has to cross them
every step.  ``compressed_psum_mean`` cuts those wire bytes 4× by reducing
blockwise-quantized int8 instead of f32:

  1. add the carried error-feedback residual to the local gradient;
  2. share one absmax scale per leaf across the pod axis (``pmax``) so every
     pod quantizes onto the same grid — the int8 payloads can then be summed
     *as integers* on the wire (int32 accumulation, no overflow for ≤ 2^24
     pods) and dequantized once;
  3. keep the local quantization error as the new residual, to be re-applied
     next step (error feedback: quantization noise averages out over steps
     instead of biasing the trajectory).

Both functions are written against a *named axis* and therefore run inside
``shard_map``/``pmap`` manual regions only; the trainer wraps its per-pod
gradient computation in a shard_map manual over the pod axis with everything
else left to GSPMD (see train/trainer.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def psum_mean(tree, axis_name: str):
    """Exact mean-reduce of every leaf over ``axis_name``."""
    return jax.tree.map(lambda x: lax.pmean(x, axis_name), tree)


def compressed_psum_mean(tree, axis_name: str, err=None):
    """int8 + error-feedback mean-reduce over ``axis_name``.

    ``err``: residual pytree from the previous step (or None → zeros).
    Returns ``(mean_tree, new_err_tree)``; the caller carries ``new_err``
    into the next invocation.  Worst-case per-element error of the mean is
    half an int8 step of the *pod-wide* absmax — < 2% relative for gradient-
    shaped tensors, and unbiased over steps thanks to the residual.
    """
    flat, tdef = jax.tree.flatten(tree)
    if err is None:
        flat_err = [None] * len(flat)
    else:
        flat_err = tdef.flatten_up_to(err)

    def one(g, e):
        t = g.astype(jnp.float32)
        if e is not None:
            t = t + e.astype(jnp.float32)
        # one shared grid across the pod axis → integer summation is exact
        amax = lax.pmax(jnp.max(jnp.abs(t)), axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_err = t - deq
        n = lax.psum(jnp.ones((), jnp.int32), axis_name)
        summed = lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * (scale / n.astype(jnp.float32))
        return mean.astype(g.dtype), new_err.astype(jnp.float32)

    pairs = [one(g, e) for g, e in zip(flat, flat_err)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))
