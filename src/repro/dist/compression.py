"""Pod-level gradient collectives: exact mean and int8 error-feedback mean.

Cross-pod links are the slowest hop in a multi-pod mesh, and the cross-pod
all-reduce of the full gradient is the only traffic that has to cross them
every step.  ``compressed_psum_mean`` cuts those wire bytes 4× by reducing
blockwise-quantized int8 instead of f32:

  1. add the carried error-feedback residual to the local gradient;
  2. share one absmax scale per leaf across the pod axis (``pmax``) so every
     pod quantizes onto the same grid — the int8 payloads can then be summed
     *as integers* on the wire (int32 accumulation, no overflow for ≤ 2^24
     pods) and dequantized once;
  3. keep the local quantization error as the new residual, to be re-applied
     next step (error feedback: quantization noise averages out over steps
     instead of biasing the trajectory).

Both functions are written against a *named axis* and therefore run inside
``shard_map``/``pmap`` manual regions only; the trainer wraps its per-pod
gradient computation in a shard_map manual over the pod axis with everything
else left to GSPMD (see train/trainer.py).

Residual sharding / checkpoint contract
---------------------------------------
The error-feedback residual is **per-pod local state** — each pod's leftover
quantization error from *its own* gradient.  It is never reduced over the pod
axis.  Outside the manual region the canonical global representation is the
*stacked* form built by :func:`init_residual`: every leaf has a leading pod
dim, shape ``(num_pods, *grad_leaf.shape)``, dtype float32, and is sharded
``P(pod_axis)`` (each pod holds exactly its own ``[1, ...]`` slice).  The
trainer threads this tree through the train step as first-class state
(``step(params, opt_state, residual, batch)``) and checkpoints it next to
params/opt — dropping it on restart would re-bias the very first compressed
step after every crash.

On an **elastic pod-count change** (restore onto a mesh with a different pod
axis size) :func:`reshard_residual` rebuilds the stack so the quantity the
optimizer actually sees — the mean correction ``Σ_p e_p / n`` folded into the
next all-reduce — is preserved exactly: every new pod starts from the mean of
the old pods' residuals (``Σ' e'/n' = Σ e/n``).  Same-pod-count restores are
bit-exact (the leaves round-trip losslessly through ``Checkpointer`` and
``restore(shardings=...)`` only re-places them on the new mesh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Logical cross-pod wire format of ``compressed_psum_mean``: one int8 per
# element plus one shared f32 absmax per leaf (the ``pmax``).  The CPU
# emulation materializes the int32 accumulator, but a real deployment sums
# int8 payloads with int32 accumulation on the wire.  Benchmarks derive
# their wire-byte rows from these constants so a format change (e.g.
# widening to int16) moves the tracked numbers.
WIRE_BYTES_PER_ELEM = 1
WIRE_SCALE_BYTES_PER_LEAF = 4
EXACT_BYTES_PER_ELEM = 4          # f32 all-reduce payload


def psum_mean(tree, axis_name: str):
    """Exact mean-reduce of every leaf over ``axis_name``."""
    return jax.tree.map(lambda x: lax.pmean(x, axis_name), tree)


def compressed_psum_mean(tree, axis_name: str, err=None):
    """int8 + error-feedback mean-reduce over ``axis_name``.

    ``err``: residual pytree from the previous step (or None → zeros).
    Returns ``(mean_tree, new_err_tree)``; the caller carries ``new_err``
    into the next invocation.  Worst-case per-element error of the mean is
    half an int8 step of the *pod-wide* absmax — < 2% relative for gradient-
    shaped tensors, and unbiased over steps thanks to the residual.  The
    carry telescopes: over K steps the *cumulative* mean deviates from the
    exact cumulative mean by at most the final residual / pod count, while
    dropping the residual lets per-step bias accumulate linearly (see
    tests/test_train_compress.py for the property test).
    """
    flat, tdef = jax.tree.flatten(tree)
    if err is None:
        flat_err = [None] * len(flat)
    else:
        flat_err = tdef.flatten_up_to(err)

    def one(g, e):
        t = g.astype(jnp.float32)
        if e is not None:
            t = t + e.astype(jnp.float32)
        # one shared grid across the pod axis → integer summation is exact
        amax = lax.pmax(jnp.max(jnp.abs(t)), axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_err = t - deq
        n = lax.psum(jnp.ones((), jnp.int32), axis_name)
        summed = lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * (scale / n.astype(jnp.float32))
        return mean.astype(g.dtype), new_err.astype(jnp.float32)

    pairs = [one(g, e) for g, e in zip(flat, flat_err)]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))


def init_residual(grad_tree, num_pods: int):
    """Zero residual in the stacked global form (see module docstring).

    ``grad_tree`` supplies structure and per-leaf shapes (params and grads
    share both); leaves come back ``(num_pods, *leaf.shape)`` float32.
    """
    return jax.tree.map(
        lambda g: jnp.zeros((num_pods,) + tuple(g.shape), jnp.float32),
        grad_tree)


def reshard_residual(residual, num_pods: int):
    """Adapt a stacked residual to a new pod-axis size.

    Same count → returned untouched (bit-exact restarts).  Different count →
    every new pod starts from the mean of the old pods' residuals, which
    preserves the applied correction ``Σ_p e_p / n`` exactly (the only
    pod-aggregate the compressed all-reduce folds into the trajectory).
    """
    def one(e):
        e = jnp.asarray(e)
        if e.shape[0] == num_pods:
            return e
        mean = jnp.mean(e.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(mean, (num_pods,) + e.shape[1:])

    return jax.tree.map(one, residual)
