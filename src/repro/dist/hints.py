"""Activation sharding hints: named constraint sites + the policy context.

The model code never mentions mesh axes.  Instead it marks layout-critical
tensors with ``shard_hint(x, "<site name>")``; a launcher installs a *policy*
(name → PartitionSpec, plus a few ``__dunder__`` scalars) around tracing:

    with jax.set_mesh(mesh), sharding_policy(policy):
        jitted.lower(...)

Sites present in the model stack (see sharding.activation_hint_policy for the
defaults):

    layer_boundary   (B, S, D)   residual stream between sub-layers
    sublayer_input   (B, S, D)   post-norm block input (SP gather point)
    attn_heads       (B, S, H, hd)   q/k/v head layouts
    attn_kv          (B, S, KV, hd)  one-shot K/V gather before the kv scan
    ffn_hidden       (B, S, F)   SwiGLU/GELU hidden activations
    mamba_inner      (B, S, dI)  SSM inner stream
    moe_groups[4]    (G, ...)    MoE dispatch group layouts
    moe_rows[4]      (E, ...)    MoE expert-parallel row layouts
    moe_logits       (G, Tl, E)  router logits
    logits           (B, C, V)   unembedded logit chunks
    embed_grad       (V, D)      scatter-added embedding cotangent

Reserved non-spec keys: ``__mesh__`` (the jax Mesh used to resolve specs),
``__moe_groups__`` (MoE dispatch group count), ``__attn_q_chunk__`` (query
chunking override, ``"full"`` → one q block).

With no policy installed every hint is an exact identity — CPU unit tests and
smoke runs never pay for (or depend on) the distribution layer.
"""

from __future__ import annotations

import contextlib
import threading

# The machine-readable site inventory: every shard_hint site name the model
# stack may use, one entry per site (the docstring's moe_groups[4]/moe_rows[4]
# shorthand expands to the base-rank and rank-4 variants).  The static lint
# pass (repro.analysis, rule hint-drift) enforces a bijection between this
# tuple and the shard_hint call sites under models/ — add the site here and
# in activation_hint_policy in the same PR that introduces it.
SITE_INVENTORY = (
    "layer_boundary",
    "sublayer_input",
    "attn_heads",
    "attn_kv",
    "ffn_hidden",
    "mamba_inner",
    "moe_groups",
    "moe_groups4",
    "moe_rows",
    "moe_rows4",
    "moe_logits",
    "logits",
    "embed_grad",
)

_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def current_policy() -> dict | None:
    """The innermost installed policy, or None."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def sharding_policy(policy):
    """Install ``policy`` (a mapping) for the duration of the context.

    Nested policies shadow outer ones wholesale (no merging) — a lowering
    that wants to tweak one site copies the dict and overrides the key.
    """
    stack = _stack()
    stack.append(dict(policy))
    try:
        yield
    finally:
        stack.pop()


def shard_hint(x, name: str):
    """Constrain ``x`` to the policy's layout for ``name`` (identity if none).

    The spec is resolved against the policy's ``__mesh__`` and trimmed to
    ``x.ndim`` (a too-long spec would be a hard error mid-trace; trailing
    entries are the least significant, so trimming keeps the intent).
    """
    pol = current_policy()
    if not pol:
        return x
    spec = pol.get(name)
    mesh = pol.get("__mesh__")
    if spec is None or mesh is None:
        return x

    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    ndim = getattr(x, "ndim", None)
    entries = tuple(spec)
    if ndim is not None and len(entries) > ndim:
        entries = entries[:ndim]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*entries)))
