"""Mesh sharding rules: PartitionSpec trees for params, optimizer moments,
caches, batches, and the default activation hint policy.

Everything here is pure spec construction — no devices are touched, so these
functions run identically on a laptop, in the 512-fake-device dry-run, and on
real pods.  Specs are *named* (logical ``pod`` / ``data`` / ``model`` axes via
:class:`MeshAxes`); ``named(mesh, tree)`` binds them to a concrete mesh.

Parameter layout (the baseline the §Perf hillclimb variants mutate):

* 2-D projections are Megatron-style: column-parallel inputs ``(D, F)`` shard
  ``P(data, model)`` (FSDP on d_model, TP on the output features), row-
  parallel outputs ``(F, D)`` shard ``P(model, data)``.
* MoE expert stacks ``(E, D, F)`` / ``(E, F, D)`` shard experts over
  ``model`` and d_model over ``data`` (ZeRO-3 on the expert weights — they
  dominate parameter bytes for every assigned MoE arch).
* ``embed (V, D)`` → ``P(model, data)``; ``lm_head (D, V)`` → ``P(data,
  model)``; 1-D leaves (norms, biases, Mamba ``D``/``dt_bias``) replicate.
* Leaves stacked under ``stages`` (the scan-over-layers stack) get a leading
  ``None`` for the stage dim.

``fsdp=False`` drops the ``data`` axis from weights (TP-only replication);
``fsdp_experts_only=True`` re-enables it for expert tensors alone (attention
and dense weights are small enough replicated — their per-layer FSDP gathers
disappear, experts keep ZeRO-3, which they need to fit).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path

from repro.models import model as model_mod
from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical mesh axis names.  ``pod=None`` on single-pod meshes."""

    pod: str | None = None
    data: str = "data"
    model: str = "model"

    @property
    def batch(self):
        """Axis (or axes) batch-like leading dims shard over."""
        return (self.pod, self.data) if self.pod else self.data

    @property
    def batch_tuple(self) -> tuple[str, ...]:
        return (self.pod, self.data) if self.pod else (self.data,)


def named(mesh, tree):
    """Bind a PartitionSpec tree to ``mesh`` as NamedShardings."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def reshard_tree(tree, new_shardings, *, old_shardings=None):
    """Migrate a pytree between shardings purely in memory.

    The one resharding primitive every elastic path shares: the checkpoint
    restore (``Checkpointer.restore(shardings=...)``), the trainer's
    pod-count residual migration, and a live ``ServeEngine.reshard`` all
    re-place leaves with this helper — none of them needs a disk round-trip.

    ``new_shardings`` is a pytree matching ``tree`` (or a prefix of it) whose
    leaves are ``jax.sharding.Sharding``s; ``None`` leaves are left untouched.
    ``old_shardings``, when given, marks leaves whose placement is already
    correct (``old == new``) so their transfer is skipped.

    A leaf whose source and target shardings live on different device sets
    (migrating a replica between disjoint mesh slices) falls back to a host
    round-trip: not every supported jax version can transfer a committed
    array directly across meshes, and the values are bit-identical either
    way.
    """
    flat_t, tdef = jax.tree.flatten(tree)
    flat_new = tdef.flatten_up_to(new_shardings)
    flat_old = (tdef.flatten_up_to(old_shardings)
                if old_shardings is not None else [None] * len(flat_t))

    def place(x, new, old):
        if new is None or (old is not None and old == new):
            return x
        try:
            return jax.device_put(x, new)
        except (ValueError, RuntimeError):
            return jax.device_put(np.asarray(x), new)

    return tdef.unflatten(
        [place(x, n, o) for x, n, o in zip(flat_t, flat_new, flat_old)])


def _path_keys(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


# Megatron column-parallel (input dim, output features) / row-parallel
# (input features, output dim) 2-D projections, by leaf name.
_COL2 = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj"}
_ROW2 = {"wo", "w_down", "out_proj"}
# MLA low-rank down-projections: (D, rank) — rank too small to TP-shard.
_MLA_DOWN = {"w_dkv", "w_kr", "w_dq"}
# MLA up-projections: (rank, H, head_dim) — heads over model.
_MLA_UP = {"w_uk", "w_uv", "w_uq"}


def _param_rule(keys: list[str], shape: tuple[int, ...], ax: MeshAxes,
                fsdp: bool, fsdp_experts_only: bool) -> P:
    """Spec for one (possibly stage-stacked) parameter leaf."""
    stacked = "stages" in keys
    name = keys[-1]
    dims = shape[1:] if stacked else shape
    nd = len(dims)
    is_expert = "experts" in keys
    d = ax.data if (fsdp or (fsdp_experts_only and is_expert)) else None
    m = ax.model

    if nd <= 1:
        spec = P()
    elif name == "embed":
        spec = P(m, d)
    elif name == "lm_head":
        spec = P(d, m)
    elif is_expert and nd == 3:
        # (E, D, F) gate/up vs (E, F, D) down: d_model gets the FSDP axis
        spec = P(m, d, None) if name in ("w_gate", "w_up") else P(m, None, d)
    elif name == "router":
        spec = P(d, None)
    elif name in _MLA_DOWN:
        spec = P(d, None)
    elif name in _MLA_UP:
        spec = P(None, m, None)
    elif name == "wq" and nd == 3:         # MLA direct q: (D, H, e)
        spec = P(d, m, None)
    elif name in _COL2:
        spec = P(d, m)
    elif name in _ROW2:
        spec = P(m, d)
    elif name == "x_proj":                 # mamba (dI, R + 2N)
        spec = P(m, None)
    elif name == "dt_proj":                # mamba (R, dI)
        spec = P(None, m)
    elif name == "conv_w":                 # mamba depthwise (K, dI)
        spec = P(None, m)
    elif name == "A_log":                  # mamba (dI, N)
        spec = P(m, None)
    else:
        spec = P()

    if stacked and len(tuple(spec)) > 0:
        spec = P(None, *tuple(spec))
    elif stacked:
        spec = P(None)
    return spec


def param_pspecs(cfg: ModelConfig, ax: MeshAxes, *, fsdp: bool = True,
                 fsdp_experts_only: bool = False):
    """PartitionSpec tree matching ``model.param_specs(cfg)`` leaf-for-leaf."""
    shapes = model_mod.param_specs(cfg)
    return tree_map_with_path(
        lambda path, leaf: _param_rule(_path_keys(path), tuple(leaf.shape),
                                       ax, fsdp, fsdp_experts_only),
        shapes)


def opt_pspecs(param_pspecs, moment_dtype: str, ax: MeshAxes, *,
               param_shapes=None):
    """Optimizer-state specs mirroring ``optim.adamw.init_opt_state``.

    Moments inherit the parameter spec leaf-by-leaf.  For ``int8`` moments,
    ≥2-D leaves are stored as ``{"q": int8 param-shaped, "scale": (..., 1)}``
    (see optim/adamw.py) — ``q`` keeps the param spec, ``scale`` drops the
    last (length-1) dim's axis.  ``param_shapes`` (ShapeDtypeStruct tree, from
    ``model.param_specs``) supplies leaf ranks; without it the spec's own
    length is used, which is only correct for full-rank specs.
    """
    def moment(spec: P, ndim: int):
        if moment_dtype == "int8" and ndim >= 2:
            entries = list(tuple(spec)) + [None] * (ndim - len(tuple(spec)))
            return {"q": spec, "scale": P(*entries[:-1], None)}
        return spec

    if param_shapes is not None:
        m = jax.tree.map(lambda sh, sp: moment(sp, len(sh.shape)),
                         param_shapes, param_pspecs)
    else:
        m = jax.tree.map(lambda sp: moment(sp, len(tuple(sp))), param_pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "m": m, "v": m}


def batch_pspec(ax: MeshAxes, shape_cfg: ShapeConfig | None = None, *,
                batch_shard: bool = True) -> P:
    """(B, S) token/label batches: batch over (pod,)data, sequence local.

    ``batch_shard=False`` replicates the batch dim — the serve-replica
    layout, where per-request batches are tiny and the mesh slice's
    parallelism is all tensor/FSDP.
    """
    return P(ax.batch if batch_shard else None, None)


def _cache_rule(keys: list[str], ax: MeshAxes, seq_shard: bool,
                batch_shard: bool = True) -> P:
    stacked = "stages" in keys
    name = keys[-1]
    b, m = ax.batch if batch_shard else None, ax.model
    if name in ("k", "v"):            # (B, Smax, KV, hd)
        spec = P(b, m, None, None) if seq_shard else P(b, None, m, None)
    elif name in ("ckv", "kr"):       # MLA latent (B, Smax, R/rope)
        spec = P(b, m, None) if seq_shard else P(b, None, None)
    elif name == "conv":              # mamba (B, K-1, dI)
        spec = P(b, None, m)
    elif name == "ssm":               # mamba (B, dI, N)
        spec = P(b, m, None)
    else:
        spec = P(b)
    return P(None, *tuple(spec)) if stacked else spec


def cache_pspecs(cfg: ModelConfig, ax: MeshAxes, shape_cfg: ShapeConfig, *,
                 seq_shard: bool = False, batch_shard: bool = True):
    """Specs for the KV/SSM cache tree of ``model.cache_specs``.

    Default: batch over (pod,)data and KV heads over ``model``.
    ``seq_shard=True`` is the flash-decode layout — cache *sequence* over
    ``model`` (padding-free for every head count; see hillclimb
    ``flashdecode``).  ``batch_shard=False`` replicates the batch dim
    (serve-replica layout).
    """
    specs = model_mod.cache_specs(cfg, shape_cfg.global_batch,
                                  shape_cfg.seq_len)
    return tree_map_with_path(
        lambda path, leaf: _cache_rule(_path_keys(path), ax, seq_shard,
                                       batch_shard),
        specs)


def activation_hint_policy(cfg: ModelConfig, ax: MeshAxes,
                           shape_cfg: ShapeConfig, *,
                           model_axis_size: int | None = None,
                           batch_shard: bool = True) -> dict:
    """Default name → PartitionSpec policy for the model's hint sites.

    Baseline layout: batch-like dims over (pod,)data everywhere; sequence
    over ``model`` at layer boundaries for train/prefill (decode has S=1);
    heads / hidden / d_inner over ``model`` inside the blocks.  MoE dispatch
    groups shard over *all* mesh axes so the (B,S,D) → (G,Tl,D) regroup
    splits at existing shard boundaries, and expert rows put E over ``model``
    and rows over the batch axes (the EP exchange is the two all-to-alls).

    ``model_axis_size`` additionally pins ``__moe_groups__`` =
    global_batch × model-axis-size — the group count for which the regroup
    moves zero bytes (see moe._group_count).  ``batch_shard=False``
    replicates batch-like dims (the serve-replica layout: tensor-parallel
    heads/hidden only, request batches too small to split).
    """
    b, m = ax.batch if batch_shard else None, ax.model
    seq = m if shape_cfg.kind in ("train", "prefill") else None
    pol: dict = {
        "layer_boundary": P(b, seq, None),
        "logits": P(b, None, m),
        "embed_grad": P(m, ax.data),
        "ffn_hidden": P(b, None, m),
    }
    kinds = {cfg.layer_kind(i) for i in range(cfg.num_layers)}
    if "attn" in kinds:
        pol["attn_heads"] = P(b, None, m, None)
    if "mamba" in kinds:
        pol["mamba_inner"] = P(b, None, m)
    if cfg.moe is not None:
        pol["moe_rows"] = P(m, b, None)
        pol["moe_rows4"] = P(m, b, None, None)
        # Group-layout hints activate the manual shard_map dispatch (see
        # moe._maybe_shard_map), which requires the group dim to divide the
        # full (pod, data, model) extent — guaranteed only when the caller
        # pins the model-axis size and tokens are plentiful (train/prefill).
        # Decode (T = B tokens) keeps GSPMD-auto dispatch: the capacity
        # scatter is tiny there and arbitrary group counts stay legal.
        if model_axis_size is not None and shape_cfg.kind in ("train",
                                                              "prefill"):
            gax = ax.batch_tuple + (m,)
            pol["moe_groups"] = P(gax, None, None)
            pol["moe_groups4"] = P(gax, None, None, None)
            pol["moe_logits"] = P(gax, None, None)
            pol["__moe_groups__"] = shape_cfg.global_batch * model_axis_size
    return pol


def page_pspecs(cfg: ModelConfig, ax: MeshAxes, *, seq_shard: bool = False):
    """Specs for a ``serve.paging`` page-pool tree (continuous batching).

    Pool leaves have the same rank as their dense cache counterparts — the
    batch axis becomes the page (or state-slot) axis and ``Smax`` becomes
    ``page_size`` — so the ``_cache_rule`` name-based specs apply
    *structurally*: the page dim replicates exactly like the serve-replica
    batch dim (``batch_shard=False``), ``page_size`` takes whatever the
    sequence dim would (KV heads stay over ``model``; ``seq_shard=True``
    moves the flash-decode split onto the page_size dim).  One rule, two
    layouts — gather/scatter between pool and dense view is then a pure
    page-axis permutation that GSPMD never reshards for.
    """
    shape_cfg = ShapeConfig("serve", "decode", 1, 1)   # structure-only
    return cache_pspecs(cfg, ax, shape_cfg, seq_shard=seq_shard,
                        batch_shard=False)


def replica_pspecs(cfg: ModelConfig, ax: MeshAxes, *, fsdp: bool = True,
                   seq_shard: bool = False) -> dict:
    """Spec bundle for one mesh-backed serve replica (see serve/engine.py).

    A replica's mesh slice parallelizes the *model* (TP heads/hidden, FSDP
    weights), never the request batch — per-request batches are tiny, so
    batch-like dims replicate and any slice shape serves any batch size.
    Returns ``{"params", "cache", "batch", "policy"}``: PartitionSpec trees
    for the three input groups plus the activation hint policy (sans
    ``__mesh__``, which the engine binds to its concrete slice).
    """
    shape_cfg = ShapeConfig("serve", "decode", 1, 1)   # structure-only
    return {
        "params": param_pspecs(cfg, ax, fsdp=fsdp),
        "cache": cache_pspecs(cfg, ax, shape_cfg, seq_shard=seq_shard,
                              batch_shard=False),
        "batch": batch_pspec(ax, shape_cfg, batch_shard=False),
        "policy": activation_hint_policy(cfg, ax, shape_cfg,
                                         batch_shard=False),
    }
