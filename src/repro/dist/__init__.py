"""repro.dist — the sharded execution substrate under the HEFT scheduler.

The serving/training north-star treats heterogeneous model replicas as the
paper's "PEs"; this package is what makes one replica an actual multi-device
substrate.  Three layers:

* :mod:`repro.dist.sharding` — **mesh sharding rules**: PartitionSpec trees
  for params / optimizer moments / KV-state caches, plus the activation hint
  policy the model forward consumes.
* :mod:`repro.dist.hints` — the **hint plumbing**: a ``sharding_policy``
  context installs a name → PartitionSpec mapping; ``shard_hint(x, name)``
  sites inside the model blocks (attention heads, FFN hidden, Mamba inner,
  MoE group/row layouts, layer boundaries) turn into
  ``with_sharding_constraint`` only when a policy is active — without one
  they are exact identities, so unit tests and smoke runs are unaffected.
* :mod:`repro.dist.compression` — **pod-level collectives**: ``psum_mean``
  and the int8 + error-feedback ``compressed_psum_mean`` used for cross-pod
  gradient reduction over the slow inter-pod links, plus the residual
  lifecycle helpers ``init_residual`` / ``reshard_residual`` (the residual is
  first-class train-step state, stacked per pod and checkpointed — see the
  contract in that module's docstring).

Axis conventions (used by every PartitionSpec this package emits)
-----------------------------------------------------------------
``MeshAxes`` names three logical mesh axes:

* ``pod``   — outermost data parallelism across pods (slow links).  Params
  and optimizer state are *replicated* over ``pod``; gradients cross it via
  the (optionally compressed) pod collectives.  ``None`` on single-pod
  meshes.
* ``data``  — fast data parallelism *and* the FSDP/ZeRO-3 axis: weight
  matrices shard their d_model-sized dim over ``data`` (``fsdp=True``) and
  are all-gathered transiently per layer.
* ``model`` — tensor parallelism: attention heads, FFN hidden dim, Mamba
  d_inner, MoE experts, and the vocab dim of embed/lm_head shard over
  ``model``.

Batch-like leading dims shard over ``(pod, data)`` when a pod axis exists,
else over ``data``.  MoE dispatch groups shard over *all* of
``(pod, data, model)`` so the (B, S, D) → (G, T_l, D) regroup splits at
existing shard boundaries and moves zero bytes.
"""

from __future__ import annotations

import contextlib


def _install_jax_compat() -> None:
    """Backfill `jax.shard_map` / `jax.set_mesh` on older jax (< 0.5).

    The distribution layer (and its tests) use the modern spellings; on the
    pinned jax 0.4.x toolchain they map 1:1 onto
    ``jax.experimental.shard_map.shard_map`` (``axis_names`` → the complement
    of ``auto``, ``check_vma`` → ``check_rep``) and the ``Mesh`` context
    manager.  No-op on jax versions that already provide them.
    """
    import jax

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                      check_vma=None, check_rep=None, auto=None):
            if auto is None:
                auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                        if axis_names is not None else frozenset())
            if check_rep is None:
                check_rep = bool(check_vma) if check_vma is not None else True
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              auto=frozenset(auto))

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    # optimization_barrier has no vmap batching rule on the pinned jax —
    # the barrier is elementwise-identity, so batching is a pass-through
    # (needed by the trainer's vmap-over-pods gradient computation, which
    # maps the model's scan-over-layers residual barriers).
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching

        if optimization_barrier_p not in batching.primitive_batchers:
            def _opt_barrier_batcher(args, dims):
                return optimization_barrier_p.bind(*args), dims

            batching.primitive_batchers[optimization_barrier_p] = \
                _opt_barrier_batcher
    except ImportError:  # newer jax: private path moved AND rule exists
        pass


_install_jax_compat()

from repro.dist.compression import (  # noqa: E402
    compressed_psum_mean,
    init_residual,
    psum_mean,
    reshard_residual,
)
from repro.dist.hints import current_policy, shard_hint, sharding_policy  # noqa: E402
from repro.dist.sharding import (  # noqa: E402
    MeshAxes,
    activation_hint_policy,
    batch_pspec,
    cache_pspecs,
    named,
    opt_pspecs,
    param_pspecs,
    replica_pspecs,
    reshard_tree,
)

__all__ = [
    "MeshAxes", "activation_hint_policy", "batch_pspec", "cache_pspecs",
    "compressed_psum_mean", "current_policy", "init_residual", "named",
    "opt_pspecs", "param_pspecs", "psum_mean", "replica_pspecs",
    "reshard_residual", "reshard_tree", "shard_hint", "sharding_policy",
]
