"""Leveled logging for the launchers, with a ``REPRO_LOG`` env knob.

The launch scripts used to ``print`` unconditionally; this routes them
through stdlib logging so verbosity is one environment variable:

  REPRO_LOG=debug    everything (incl. per-cell memory analyses)
  REPRO_LOG=info     the default — same lines the prints used to emit
  REPRO_LOG=warning  only warnings/errors
  REPRO_LOG=error    only errors
  REPRO_LOG=silent   nothing

Output format stays the launchers' established ``[tag] message`` style on
stdout, so existing example transcripts and subprocess-capturing tests read
identically at the default level.
"""

from __future__ import annotations

import logging
import os
import sys

LOG_LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "warning": logging.WARNING,
    "warn": logging.WARNING,
    "error": logging.ERROR,
    "silent": logging.CRITICAL + 10,
}


def log_level() -> int:
    """Resolve the ``REPRO_LOG`` knob (default ``info``)."""
    env = os.environ.get("REPRO_LOG", "").strip().lower()
    if env and env not in LOG_LEVELS:
        raise ValueError(
            f"REPRO_LOG must be one of {sorted(LOG_LEVELS)}, got {env!r}")
    return LOG_LEVELS[env or "info"]


class _TagFormatter(logging.Formatter):
    """``[tag] message`` — the launchers' print prefix, preserved."""

    def format(self, record: logging.LogRecord) -> str:
        tag = record.name
        if tag.startswith("repro."):
            tag = tag[len("repro."):]
        return f"[{tag}] {record.getMessage()}"


def get_logger(name: str) -> logging.Logger:
    """Logger printing ``[name] ...`` to stdout at the ``REPRO_LOG`` level.

    The level is re-read from the environment on every call, so a launcher
    invoked with ``REPRO_LOG=silent`` quiets loggers created at import time
    too.
    """
    logger = logging.getLogger(f"repro.{name}")
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(_TagFormatter())
        logger.addHandler(handler)
        logger.propagate = False
    logger.setLevel(log_level())
    return logger
