"""Counters, gauges, and log-bucketed latency histograms + a registry.

The paper's headline numbers are distributional: per-decision scheduling
latency averaged over millions of decisions (9.144 ns), latency CDFs under
load (Figs 5/6).  :class:`Histogram` makes that axis reproducible in
software: log2-spaced buckets spanning **1 ns → ~1000 s**, so one histogram
covers the paper's hardware-scale decisions (ns), our jit dispatch (µs),
and end-to-end request latencies (s) without re-binning.

Everything is plain-Python and allocation-light on the hot path (one
``dict`` lookup + integer math per ``record``); the registry's
:meth:`~MetricsRegistry.snapshot` is the JSON export consumed by the
benchmark harness and embedded into Chrome trace artifacts by
``Tracer.export``.

Timing helpers (:func:`time_s`, :class:`Stopwatch`) are the single wall-
clock idiom the runtime layers share — ``runtime/overhead.py``'s measured
model and the serve engine's per-request timing are deduped onto these.
"""

from __future__ import annotations

import json
import math
import time


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (queue depth, utilization, pool size)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


# Histogram bucket i covers [HIST_MIN * 2**i, HIST_MIN * 2**(i+1)); 40 log2
# buckets span 1 ns → ~1100 s, the ns→s latency axis of the paper's CDFs.
HIST_MIN_S = 1e-9
HIST_BUCKETS = 40


class Histogram:
    """Log2-bucketed latency histogram over seconds.

    Values below ``HIST_MIN_S`` clamp into bucket 0 and values beyond the
    top edge clamp into the last bucket (count and sum stay exact either
    way).  ``record(v, n=k)`` is a weighted record — one measured duration
    standing for ``k`` identical decisions, how per-decision latency is
    derived from a batched mapping event without k distinct clock reads.
    """

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets = [0] * HIST_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    @staticmethod
    def bucket_edges() -> list[float]:
        """The HIST_BUCKETS+1 bucket edges in seconds."""
        return [HIST_MIN_S * 2.0 ** i for i in range(HIST_BUCKETS + 1)]

    @staticmethod
    def bucket_index(v: float) -> int:
        """Index of the bucket containing ``v`` (clamped at both ends).

        ``log2`` rounding at exact power-of-two edges is corrected against
        the edge values themselves, so ``edge[i] <= v < edge[i+1]`` holds
        exactly for every in-range value (property-tested).
        """
        if v <= HIST_MIN_S:
            return 0
        i = int(math.log2(v / HIST_MIN_S))
        if v < HIST_MIN_S * 2.0 ** i:
            i -= 1
        elif v >= HIST_MIN_S * 2.0 ** (i + 1):
            i += 1
        return min(max(i, 0), HIST_BUCKETS - 1)

    def record(self, v: float, n: int = 1) -> None:
        self.buckets[self.bucket_index(v)] += n
        self.count += n
        self.sum += v * n
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Approximate percentile (0..100) by log-interpolating inside the
        covering bucket, clamped to the observed [min, max] (interpolation
        alone could overshoot a bucket's true extreme values)."""
        if self.count == 0:
            return math.nan
        target = self.count * q / 100.0
        cum = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if cum + c >= target:
                lo = HIST_MIN_S * 2.0 ** i
                frac = (target - cum) / c
                est = lo * 2.0 ** frac           # log-linear within bucket
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "count": self.count,
            "sum_s": self.sum,
            "mean_s": (self.sum / self.count) if self.count else math.nan,
            "min_s": self.min if self.count else math.nan,
            "max_s": self.max if self.count else math.nan,
            "p50_s": self.percentile(50),
            "p99_s": self.percentile(99),
            "buckets": {str(i): c for i, c in enumerate(self.buckets) if c},
        }


def _fullname(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name+labels → metric, with get-or-create accessors and JSON export.

    Labels are part of the metric identity (``fabric.map_batch_s{backend=
    jit,bucket=64}``), so one registry holds the whole per-backend /
    per-bucket breakdown the Fig. 4 latency-vs-queue analysis needs.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, labels: dict):
        key = _fullname(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls()
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {key!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, key: str) -> bool:
        return key in self._metrics

    def snapshot(self) -> dict:
        """JSON-able view: scalars for counters/gauges, the bucket snapshot
        for histograms, sorted by metric name."""
        out = {}
        for key in sorted(self._metrics):
            m = self._metrics[key]
            out[key] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out

    def export_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path


# ---------------------------------------------------------------------------
# Shared timing idiom
# ---------------------------------------------------------------------------

def time_s(fn, *args, **kw):
    """Call ``fn`` and return ``(result, elapsed_seconds)`` — the one
    wall-clock measurement helper the runtime layers share."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


class Stopwatch:
    """Context manager: ``elapsed_s`` on exit, optionally recorded into a
    :class:`Histogram` (``n`` weights the record, e.g. decisions/batch)."""

    __slots__ = ("histogram", "n", "elapsed_s", "start_s")

    def __init__(self, histogram: Histogram | None = None, n: int = 1):
        self.histogram = histogram
        self.n = n
        self.elapsed_s = 0.0
        self.start_s = 0.0

    def __enter__(self):
        self.start_s = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed_s = time.perf_counter() - self.start_s
        if self.histogram is not None:
            self.histogram.record(self.elapsed_s / max(self.n, 1), n=self.n)
        return False
