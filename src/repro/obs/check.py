"""CLI: validate a Chrome trace artifact (the CI ``--trace`` gate).

  PYTHONPATH=src python -m repro.obs.check out.json \\
      --require fabric. --require-metrics fabric.decision_s

Exit status is non-zero on schema violations, missing required event
names, or missing metrics-snapshot keys.  Lives outside ``trace.py`` so
``python -m`` does not re-execute an already-imported module.
"""

from __future__ import annotations

import argparse
import json

from repro.obs.trace import validate_chrome_trace


def main() -> None:
    ap = argparse.ArgumentParser(
        description="validate a Chrome trace artifact (Perfetto JSON)")
    ap.add_argument("path")
    ap.add_argument("--require", action="append", default=[],
                    metavar="SUBSTRING",
                    help="require an event whose name contains SUBSTRING "
                         "(repeatable)")
    ap.add_argument("--require-metrics", action="append", default=[],
                    metavar="SUBSTRING",
                    help="require an embedded metrics snapshot whose key "
                         "contains SUBSTRING (repeatable)")
    args = ap.parse_args()
    with open(args.path) as f:
        obj = json.load(f)
    n = validate_chrome_trace(obj, require_names=args.require)
    for want in args.require_metrics:
        snap = obj.get("metrics") or {}
        if not any(want in k for k in snap):
            raise SystemExit(
                f"[obs] {args.path}: no metrics key matching {want!r} "
                f"(saw {sorted(snap)[:20]})")
    print(f"[obs] {args.path}: valid Chrome trace, {n} events")


if __name__ == "__main__":
    main()
