"""Bounded-ring tracer with Chrome-trace-event (Perfetto) export.

The paper's evaluation is *measured*: per-decision scheduling latency,
tasks/sec, latency breakdowns under dynamically arriving workloads
(Section VI).  This module is the event side of reproducing those numbers:
a :class:`Tracer` records span / instant / counter events into a bounded
ring buffer and exports them as Chrome trace-event JSON, loadable directly
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Design constraints, in order:

* **Near-zero cost when off.**  A disabled tracer (``Tracer(enabled=False)``
  or the shared :data:`NULL_TRACER`) allocates nothing per call: ``span``
  returns a module-level singleton no-op context manager and the record
  paths return before touching the ring.  Instrumentation sites guard with
  ``if tracer is not None`` so the *default* runtime path is byte-identical
  to the uninstrumented code.
* **Bounded memory.**  Events land in a preallocated ring
  (``capacity`` slots); wraparound drops the oldest events.  A steady-state
  serving loop can stay instrumented forever without growing the heap.
* **Two clocks.**  Wall-clock events take their timestamp from
  ``time.perf_counter`` relative to the tracer's epoch; simulators pass
  explicit ``ts_us`` values so simulated timelines export on their own
  axis (the discrete-event serving simulator's queue-depth counters).

Timestamps are microseconds (the Chrome trace-event unit).
"""

from __future__ import annotations

import io
import json
import time

_PH_KNOWN = frozenset({"X", "i", "I", "C", "B", "E", "M"})


class TraceEvent:
    """One trace event (Chrome trace-event phases: X=span, i=instant,
    C=counter).  ``ts``/``dur`` are microseconds; ``args`` is the free-form
    payload dict."""

    __slots__ = ("name", "ph", "ts", "dur", "args", "tid")

    def __init__(self, name: str, ph: str, ts: float, dur: float = 0.0,
                 args: dict | None = None, tid: int = 0):
        self.name = name
        self.ph = ph
        self.ts = ts
        self.dur = dur
        self.args = args
        self.tid = tid

    def to_json(self) -> dict:
        ev = {"name": self.name, "ph": self.ph, "ts": self.ts,
              "pid": 0, "tid": self.tid, "cat": "repro"}
        if self.ph == "X":
            ev["dur"] = self.dur
        if self.args:
            ev["args"] = self.args
        return ev


class _NullSpan:
    """No-op context manager; a single module-level instance is reused so
    the disabled-tracer span path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """Live span: records one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        tr._append(TraceEvent(self._name, "X", (self._t0 - tr._epoch) * 1e6,
                              (t1 - self._t0) * 1e6, self._args))
        return False


class Tracer:
    """Span/instant/counter events into a bounded ring buffer.

    Parameters
    ----------
    capacity:
        Ring size in events; wraparound drops the oldest.
    enabled:
        ``False`` turns every record call into a no-op (``span`` returns the
        shared :data:`NULL_SPAN`, nothing is allocated or stored).
    """

    def __init__(self, capacity: int = 65536, *, enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._ring: list[TraceEvent | None] = [None] * self.capacity
        self._head = 0          # next write slot
        self._count = 0         # total events ever recorded
        self._epoch = time.perf_counter()

    # -- recording -----------------------------------------------------------

    def _append(self, ev: TraceEvent) -> None:
        self._ring[self._head] = ev
        self._head = (self._head + 1) % self.capacity
        self._count += 1

    def record(self, ev: TraceEvent) -> None:
        """Append a pre-built event (structured-event producers, e.g. the
        fleet controller's decision log, mirror into a shared tracer)."""
        if self.enabled:
            self._append(ev)

    def now_us(self) -> float:
        """Current wall-clock timestamp on this tracer's axis (µs)."""
        return (time.perf_counter() - self._epoch) * 1e6

    def span(self, name: str, **args):
        """Context manager recording a complete ("X") event on exit."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args or None)

    def complete(self, name: str, start_s: float, dur_s: float, **args) -> None:
        """Record a complete event from caller-held wall-clock readings —
        the hot-path alternative to :meth:`span` (one call, no context
        manager).  ``start_s`` is a ``time.perf_counter`` reading."""
        if self.enabled:
            self._append(TraceEvent(name, "X", (start_s - self._epoch) * 1e6,
                                    dur_s * 1e6, args or None))

    def instant(self, name: str, ts_us: float | None = None, **args) -> None:
        """Instant event, at ``ts_us`` (simulated time) or now."""
        if self.enabled:
            ts = self.now_us() if ts_us is None else ts_us
            self._append(TraceEvent(name, "i", ts, 0.0, args or None))

    def counter(self, name: str, ts_us: float | None = None, **values) -> None:
        """Counter ("C") event — Perfetto renders these as track timelines
        (queue depth, backlog, occupancy).  Values must be numeric."""
        if self.enabled:
            ts = self.now_us() if ts_us is None else ts_us
            self._append(TraceEvent(name, "C", ts, 0.0, values))

    # -- inspection / export -------------------------------------------------

    def __len__(self) -> int:
        return min(self._count, self.capacity)

    @property
    def dropped(self) -> int:
        """Events lost to ring wraparound."""
        return max(0, self._count - self.capacity)

    def events(self) -> list[TraceEvent]:
        """Buffered events, oldest first."""
        n = len(self)
        if self._count <= self.capacity:
            return [e for e in self._ring[:n]]
        # wrapped: head points at the oldest slot
        return [self._ring[(self._head + i) % self.capacity]
                for i in range(self.capacity)]

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._head = 0
        self._count = 0

    def to_chrome(self, *, metrics=None) -> dict:
        """Chrome trace-event JSON object format.

        ``metrics`` (a :class:`~repro.obs.metrics.MetricsRegistry` or a
        plain snapshot dict) is embedded under a top-level ``"metrics"``
        key — Perfetto ignores unknown top-level keys, so the artifact
        carries the latency-histogram snapshot next to the timeline.
        """
        out = {
            "traceEvents": sorted((e.to_json() for e in self.events()),
                                  key=lambda ev: ev["ts"]),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs", "dropped": self.dropped},
        }
        if metrics is not None:
            snap = metrics.snapshot() if hasattr(metrics, "snapshot") else metrics
            out["metrics"] = snap
        return out

    def export(self, path: str, *, metrics=None) -> str:
        """Write the Chrome trace JSON to ``path`` and return it."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(metrics=metrics), f, indent=1)
        return path


NULL_TRACER = Tracer(capacity=1, enabled=False)


# ---------------------------------------------------------------------------
# Artifact validation (CI gates the --trace output through this)
# ---------------------------------------------------------------------------

def validate_chrome_trace(obj, *, require_names=()) -> int:
    """Validate a Chrome trace artifact; returns the event count.

    ``obj``: a path, a file-like, or an already-parsed dict.  Checks the
    schema Perfetto's JSON importer relies on — a ``traceEvents`` list whose
    entries carry ``name``/``ph``/numeric ``ts``, known phase codes, and
    ``dur`` on complete events — and that every substring in
    ``require_names`` matches at least one event name.  Raises
    ``ValueError`` on any violation.
    """
    if isinstance(obj, str):
        with open(obj) as f:
            obj = json.load(f)
    elif isinstance(obj, io.IOBase):
        obj = json.load(obj)
    if not isinstance(obj, dict):
        raise ValueError(f"trace root must be a JSON object, got {type(obj)}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace has no 'traceEvents' list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("name", "ph", "ts"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}: {ev}")
        if ev["ph"] not in _PH_KNOWN:
            raise ValueError(f"traceEvents[{i}] unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"traceEvents[{i}] non-numeric ts: {ev['ts']!r}")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"traceEvents[{i}] complete event without dur")
    names = {ev["name"] for ev in events}
    for want in require_names:
        if not any(want in n for n in names):
            raise ValueError(
                f"trace has no event matching {want!r} "
                f"(saw {sorted(names)[:20]})")
    return len(events)


if __name__ == "__main__":   # CLI lives in repro.obs.check
    from repro.obs.check import main
    main()
