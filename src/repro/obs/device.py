"""Device-resident scheduler counters — the software analogue of the
paper's hardware performance counters.

The paper's FPGA overlay can report per-decision statistics without
perturbing the scheduler because the counters are *fabric registers*
updated in the same cycle as the decision.  The TPU-side analogue: the
mapping fabric's jitted dispatch carries an extra donated f32 register
vector, accumulated *inside* the compiled program from the decision
outputs — no per-event host sync, no extra dispatch.  ``MappingFabric``
drains the registers on demand (one host transfer), exactly like reading
the overlay's counter file over AXI.

Counter lanes (:data:`COUNTER_NAMES`):

* ``events`` — mapping events dispatched (batch rows count individually),
* ``decisions`` — tasks actually committed to a PE (assignment ≥ 0),
* ``occupancy`` — total real (non-padding) ready-queue slots seen; divided
  by ``events`` this is the mean bucket occupancy, the padding-efficiency
  signal of the power-of-two bucketing,
* ``t_avail_spread`` — Σ per-event (max − min) of the post-event T_avail
  registers over real PE lanes: the load-imbalance integral (0 for a
  perfectly balanced pool).

Accumulation is pure arithmetic on the dispatch *outputs*, so the schedule
itself is bit-identical with counters on or off (property-tested against
the ``heft_rt_numpy`` oracle in ``tests/test_obs.py``).

Counters are f32 on device: counts stay exact up to 2**24 events per
drain — drain (which zeroes by default) well before that.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

COUNTER_NAMES = ("events", "decisions", "occupancy", "t_avail_spread")
NUM_COUNTERS = len(COUNTER_NAMES)


def zero_counters():
    """Fresh device counter registers (f32[NUM_COUNTERS])."""
    return jnp.zeros((NUM_COUNTERS,), dtype=jnp.float32)


def accumulate_counters(counters, assignment, new_avail, valid, p_valid):
    """Fold one dispatch's outputs into the counter registers (traceable).

    ``assignment``/``valid``: (D,) or (B, D); ``new_avail``: (P,) or
    (B, P); ``p_valid``: (P,) real-lane mask (False on padded PE lanes,
    whose registers are inert but present on device).  Returns the new
    register vector; runs inside the fabric's jitted dispatch, so the
    donated input buffer is reused in place.
    """
    if assignment.ndim == 1:
        assignment = assignment[None]
        new_avail = new_avail[None]
        valid = valid[None]
    row_valid = jnp.any(valid, axis=1)           # padded batch rows are inert
    events = jnp.sum(row_valid)
    decisions = jnp.sum((assignment >= 0) & valid)
    occupancy = jnp.sum(valid)
    mx = jnp.max(jnp.where(p_valid[None, :], new_avail, -jnp.inf), axis=1)
    mn = jnp.min(jnp.where(p_valid[None, :], new_avail, jnp.inf), axis=1)
    spread = jnp.sum(jnp.where(row_valid, mx - mn, 0.0))
    delta = jnp.stack([events, decisions, occupancy, spread])
    return counters + delta.astype(counters.dtype)


def accumulate_counters_np(counters, assignment, new_avail, valid=None):
    """Host twin for the fabric's numpy backend (no padded lanes there).

    ``counters`` is a mutable f64 array updated in place; semantics match
    :func:`accumulate_counters` lane for lane.
    """
    assignment = np.asarray(assignment)
    new_avail = np.asarray(new_avail)
    if valid is None and assignment.ndim == 1:
        # Hot path (per-event map_event): scalar ops, no temporaries beyond
        # one bool mask — this runs once per mapping event.
        counters[0] += 1.0
        counters[1] += int((assignment >= 0).sum())
        counters[2] += assignment.size
        counters[3] += float(new_avail.max() - new_avail.min())
        return counters
    assignment = np.atleast_2d(assignment)
    new_avail = np.atleast_2d(new_avail)
    if valid is None:
        valid = np.ones(assignment.shape, dtype=bool)
    counters[0] += np.sum(np.any(valid, axis=1))
    counters[1] += np.sum((assignment >= 0) & valid)
    counters[2] += np.sum(valid)
    counters[3] += np.sum(new_avail.max(axis=1) - new_avail.min(axis=1))
    return counters


def counters_dict(values) -> dict[str, float]:
    """Name → value view of a drained register vector."""
    arr = np.asarray(values, dtype=np.float64)
    if arr.shape != (NUM_COUNTERS,):
        raise ValueError(
            f"expected {NUM_COUNTERS} counter lanes, got shape {arr.shape}")
    return {name: float(arr[i]) for i, name in enumerate(COUNTER_NAMES)}
