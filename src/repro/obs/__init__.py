# repro.obs — unified tracing + metrics: bounded-ring Tracer with Perfetto
# (Chrome trace-event) export, Counter/Gauge/log-bucketed Histogram registry,
# device-resident scheduler counters, and the REPRO_LOG leveled logger.
from repro.obs.device import (
    COUNTER_NAMES,
    NUM_COUNTERS,
    accumulate_counters,
    accumulate_counters_np,
    counters_dict,
    zero_counters,
)
from repro.obs.log import LOG_LEVELS, get_logger, log_level
from repro.obs.metrics import (
    HIST_BUCKETS,
    HIST_MIN_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Stopwatch,
    time_s,
)
from repro.obs.trace import (
    NULL_TRACER,
    TraceEvent,
    Tracer,
    validate_chrome_trace,
)

__all__ = [
    "COUNTER_NAMES", "NUM_COUNTERS", "accumulate_counters",
    "accumulate_counters_np", "counters_dict", "zero_counters",
    "LOG_LEVELS", "get_logger", "log_level",
    "HIST_BUCKETS", "HIST_MIN_S", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "Stopwatch", "time_s",
    "NULL_TRACER", "TraceEvent", "Tracer", "validate_chrome_trace",
]
