"""Attention blocks: GQA (+ local/global windows, softcap, qk-norm) and MLA.

Design notes
------------
* Prefill/train attention is *chunked* with an online-softmax accumulator
  (flash-attention recurrence in pure JAX): ``lax.scan`` over query chunks,
  ``lax.fori_loop`` over the causally-reachable key chunks.  Peak live memory
  per step is O(q_chunk × k_chunk) instead of O(S²) — required for the 32k
  prefill cells, and it keeps HLO small for the 512-device dry-runs.
  Local-window layers (Gemma-2) additionally lower-bound the key-chunk loop,
  so skipped chunks cost neither FLOPs nor bytes.
* Decode attends one query against the full KV cache (no S² term).
* MLA (DeepSeek-V2) caches only the compressed latent (kv_lora + rope dims)
  and uses the absorbed-projection trick at decode: W_UK folds into the query
  and W_UV into the output, so per-token cache traffic is kv_lora+rope ≈ 576
  values instead of 2·H·head_dim = 32768 — the paper-published 57× KV saving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.hints import shard_hint
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, dtype_of, rms_norm, softcap

NEG = -2.3e38  # practical -inf for f32 masking


# ===========================================================================
# GQA
# ===========================================================================

def init_gqa_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dt),
        "wk": dense_init(ks[1], (D, KV * hd), dt),
        "wv": dense_init(ks[2], (D, KV * hd), dt),
        "wo": dense_init(ks[3], (H * hd, D), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _qk_chunk_scores(qc_, kc_, scale, cap):
    """qc_: (B,Q,N,G,d) f32-accum scores against kc_: (B,K,N,d)."""
    s = jnp.einsum("bqngd,bknd->bngqk", qc_, kc_,
                   preferred_element_type=jnp.float32) * scale
    return softcap(s, cap) if cap is not None else s


def chunked_causal_attention(
    q: jax.Array,            # (B, S, H, d)
    k: jax.Array,            # (B, S, KV, d)
    v: jax.Array,            # (B, S, KV, d)
    *,
    scale: float,
    attn_cap: float | None,
    window: int | None,      # None → global causal
    q_chunk: int | None = None,
    kv_chunk: int = 512,
    differentiable: bool = False,
) -> jax.Array:
    """Online-softmax chunked attention with decoupled q/kv chunk sizes.

    Two inner-loop flavours:
      * inference (``differentiable=False``): ``fori_loop`` with a dynamic
        upper bound — only causally-reachable key chunks are touched (exact
        triangular FLOPs), but dynamic-bound loops don't reverse-diff;
      * training  (``differentiable=True``): ``scan`` with a *static* trip
        count.  Global layers sweep all key chunks and rely on the causal
        mask (≤2× attention-matmul FLOPs — see §Perf for the custom-vjp
        reclaim); local-window layers keep exact chunk skipping because the
        window span is static.

    Sharding note (§Perf): the default q_chunk=512 pairs with head-sharded
    layouts.  Installing the ``__attn_q_chunk__`` policy key sets q_chunk=S
    (one q block) so the softmax carries shard over *query positions* — the
    only dim guaranteed divisible by the model axis for every assigned arch
    (head counts 8/10/24/56 pad, which makes GSPMD re-gather the carries on
    every inner step).
    """
    from repro.dist.hints import current_policy
    B, S, H, d = q.shape
    KV = k.shape[2]
    dv = v.shape[-1]          # MLA: value head dim ≠ query head dim
    G = H // KV
    pol = current_policy() or {}
    if q_chunk is None:
        q_chunk = pol.get("__attn_q_chunk__", 512)
        if q_chunk == "full":
            q_chunk = S
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    assert S % qc == 0 and S % kc == 0, (S, qc, kc)
    nq = S // qc
    nk = S // kc

    qs = jnp.moveaxis(q.reshape(B, nq, qc, KV, G, d), 1, 0)  # (nq,B,qc,KV,G,d)

    def make_step(i, qblk):
        qpos = i * qc + jnp.arange(qc)                        # (qc,)

        def process_chunk(state, j, extra_valid):
            m, l, acc = state
            kblk = lax.dynamic_slice_in_dim(k, j * kc, kc, axis=1)
            vblk = lax.dynamic_slice_in_dim(v, j * kc, kc, axis=1)
            s = _qk_chunk_scores(qblk, kblk, scale, attn_cap)  # (B,KV,G,qc,kc)
            kpos = j * kc + jnp.arange(kc)
            mask = kpos[None, :] <= qpos[:, None]              # causal
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mask &= extra_valid
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))        # (B,KV,G,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bngqk,bknd->bngqd", p, vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return m_new, l_new, acc_new

        return qpos, process_chunk

    def q_body(carry, inp):
        i, qblk = inp                                          # qblk (B,qc,KV,G,d)
        _, process_chunk = make_step(i, qblk)
        m0 = jnp.full((B, KV, G, qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, dv), jnp.float32)

        if not differentiable:
            lo = 0 if window is None else \
                jnp.maximum(0, (i * qc - window) // kc)
            hi = ((i + 1) * qc + kc - 1) // kc
            m, l, acc = lax.fori_loop(
                lo, hi,
                lambda j, st: process_chunk(st, j, True), (m0, l0, a0))
        else:
            span = nk if window is None else \
                (window - 1 + qc - 1) // kc + 2   # kv chunks a q block can see
            if window is None or span >= nk:
                R = nk

                def offs_to_j(r):
                    return r, r * kc <= i * qc + qc - 1
            else:
                R = span

                def offs_to_j(r):
                    j_raw = (i * qc - (window - 1)) // kc + r
                    return jnp.clip(j_raw, 0, nk - 1), \
                        (j_raw >= 0) & (j_raw * kc <= i * qc + qc - 1)

            def scan_body(st, r):
                j, valid = offs_to_j(r)
                return process_chunk(st, j, valid), None

            (m, l, acc), _ = lax.scan(scan_body, (m0, l0, a0), jnp.arange(R))

        out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,KV,G,qc,d)
        return carry, jnp.moveaxis(out, 3, 1)                  # (B,qc,KV,G,d)

    if differentiable:
        # flash-style memory behaviour under autodiff: per-q-chunk remat means
        # the backward holds ONE chunk row of probabilities at a time instead
        # of stacking (B,H,S,S) as scan residuals.
        q_body = jax.checkpoint(
            q_body, policy=jax.checkpoint_policies.nothing_saveable)

    if nq == 1:  # single q block: no outer scan, carries shard on q positions
        _, out_block = q_body(None, (jnp.asarray(0), qs[0]))
        out = out_block.reshape(B, S, H, dv)
        return out.astype(q.dtype)

    _, outs = lax.scan(q_body, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dv)        # (B,S,H,dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, 1, H, d)
    k_cache: jax.Array,      # (B, Smax, KV, d)
    v_cache: jax.Array,      # (B, Smax, KV, d)
    pos: jax.Array,          # () shared position, or (B,) one per sequence
    *,
    scale: float,
    attn_cap: float | None,
    window: int | None,
) -> jax.Array:
    """One-query attention against the cache.

    ``pos`` is the number of valid cache slots: a scalar for lockstep batched
    decode, or a ``(B,)`` vector for continuous batching, where every row of
    the batch sits at its own sequence position (serve/paging.py).  Rows are
    independent either way, so a vector-``pos`` row computes bit-identically
    to the same request decoded alone with a scalar ``pos``.
    """
    B, _, H, d = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, d)
    s = _qk_chunk_scores(qg, k_cache, scale, attn_cap)         # (B,KV,G,1,Smax)
    kpos = jnp.arange(Smax)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        mask = kpos <= pos
        if window is not None:
            mask &= (pos - kpos) < window
        s = jnp.where(mask[None, None, None, None, :], s, NEG)
    else:
        mask = kpos[None, :] <= pos[:, None]                   # (B, Smax)
        if window is not None:
            mask &= (pos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[:, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, d).astype(q.dtype)


def gqa_block(
    params: dict,
    x: jax.Array,             # (B, S, D)
    cfg: ModelConfig,
    *,
    window: int | None,
    positions: jax.Array,     # (S,) or scalar decode position
    cache: dict | None = None,  # {'k': (B,Smax,KV,d), 'v': ...}
    decode_pos: jax.Array | None = None,
    differentiable: bool = False,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = shard_hint((x @ params["wq"]).reshape(B, S, H, hd), "attn_heads")
    k = shard_hint((x @ params["wk"]).reshape(B, S, KV, hd), "attn_heads")
    v = shard_hint((x @ params["wv"]).reshape(B, S, KV, hd), "attn_heads")
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = hd ** -0.5

    if decode_pos is None:
        # §Perf hint: gathering K/V ONCE here (e.g. P(b, None, None, None))
        # replaces a per-kv-chunk re-gather inside the online-softmax scan
        # (with S-sharded K/V each dynamic slice straddles shards and GSPMD
        # gathers the full tensor per step).
        k = shard_hint(k, "attn_kv")
        v = shard_hint(v, "attn_kv")

    new_cache = None
    if decode_pos is not None:
        assert cache is not None and S == 1
        if jnp.ndim(decode_pos) == 0:
            k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), decode_pos, axis=1)
            v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), decode_pos, axis=1)
        else:
            # Continuous batching: each row writes its token at its own
            # position (row-independent scatter — bit-identical per row to
            # the scalar-pos update of that row alone).
            rows = jnp.arange(B)
            k_cache = cache["k"].at[rows, decode_pos].set(
                k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[rows, decode_pos].set(
                v[:, 0].astype(cache["v"].dtype))
        out = decode_attention(q, k_cache, v_cache, decode_pos, scale=scale,
                               attn_cap=cfg.attn_softcap, window=window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = chunked_causal_attention(q, k, v, scale=scale,
                                       attn_cap=cfg.attn_softcap, window=window,
                                       differentiable=differentiable)
        if cache is not None:  # prefill: fill the cache
            Smax = cache["k"].shape[1]
            kpad = jnp.zeros_like(cache["k"]).at[:, :S].set(k.astype(cache["k"].dtype))
            vpad = jnp.zeros_like(cache["v"]).at[:, :S].set(v.astype(cache["v"].dtype))
            new_cache = {"k": kpad, "v": vpad}
    y = out.reshape(B, S, H * hd) @ params["wo"]
    return y, new_cache


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg.compute_dtype)
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dt),
            "v": jax.ShapeDtypeStruct(shape, dt)}


# ===========================================================================
# MLA (DeepSeek-V2)
# ===========================================================================

def init_mla_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    D, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], (D, cfg.kv_lora_rank), dt),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "w_kr": dense_init(ks[1], (D, rope_d), dt),
        "w_uk": dense_init(ks[2], (cfg.kv_lora_rank, H, nope), dt),
        "w_uv": dense_init(ks[3], (cfg.kv_lora_rank, H, vd), dt),
        "wo": dense_init(ks[4], (H * vd, D), dt),
    }
    if cfg.q_lora_rank > 0:
        p["w_dq"] = dense_init(ks[5], (D, cfg.q_lora_rank), dt)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), jnp.float32)
        p["w_uq"] = dense_init(ks[6], (cfg.q_lora_rank, H, nope + rope_d), dt)
    else:
        p["wq"] = dense_init(ks[5], (D, H, nope + rope_d), dt)
    return p


def _mla_queries(params, x, cfg, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank > 0:
        cq = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhd->bshd", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    q = shard_hint(q, "attn_heads")
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_block(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict | None = None,   # {'ckv': (B,Smax,R), 'kr': (B,Smax,rope_d)}
    decode_pos: jax.Array | None = None,
    differentiable: bool = False,
    **_unused,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    H = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (nope + rope_d) ** -0.5

    q_nope, q_rope = _mla_queries(params, x, cfg, positions)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)  # (B,S,R)
    kr = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                    cfg.rope_theta)[:, :, 0, :]                            # (B,S,rope)

    new_cache = None
    if decode_pos is not None:
        assert cache is not None and S == 1
        if jnp.ndim(decode_pos) == 0:
            ckv_c = lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), decode_pos, axis=1)
            kr_c = lax.dynamic_update_slice_in_dim(
                cache["kr"], kr.astype(cache["kr"].dtype), decode_pos, axis=1)
        else:
            rows = jnp.arange(B)
            ckv_c = cache["ckv"].at[rows, decode_pos].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            kr_c = cache["kr"].at[rows, decode_pos].set(
                kr[:, 0].astype(cache["kr"].dtype))
        # absorbed decode: fold W_UK into q, attend in latent space
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"])  # (B,1,H,R)
        s = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshd,btd->bhst", q_rope, kr_c,
                          preferred_element_type=jnp.float32)) * scale
        kpos = jnp.arange(ckv_c.shape[1])
        if jnp.ndim(decode_pos) == 0:
            s = jnp.where((kpos <= decode_pos)[None, None, None, :], s, NEG)
        else:
            s = jnp.where(
                (kpos[None, :] <= decode_pos[:, None])[:, None, None, :],
                s, NEG)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p, ckv_c,
                           preferred_element_type=jnp.float32)       # (B,1,H,R)
        out = jnp.einsum("bshr,rhd->bshd", o_lat.astype(x.dtype), params["w_uv"])
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    else:
        # prefill/train: expand to per-head K/V, reuse the chunked kernel
        k_nope = shard_hint(
            jnp.einsum("bsr,rhd->bshd", ckv, params["w_uk"]), "attn_heads")
        v = shard_hint(
            jnp.einsum("bsr,rhd->bshd", ckv, params["w_uv"]), "attn_heads")
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            kr[:, :, None, :], (B, S, H, rope_d))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_causal_attention(q, k, v, scale=scale, attn_cap=None,
                                       window=None,
                                       differentiable=differentiable)
        if cache is not None:
            Smax = cache["ckv"].shape[1]
            ckv_c = jnp.zeros_like(cache["ckv"]).at[:, :S].set(ckv.astype(cache["ckv"].dtype))
            kr_c = jnp.zeros_like(cache["kr"]).at[:, :S].set(kr.astype(cache["kr"].dtype))
            new_cache = {"ckv": ckv_c, "kr": kr_c}
    y = out.reshape(B, S, H * vd) @ params["wo"]
    return y, new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg.compute_dtype)
    return {"ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
            "kr": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_head_dim), dt)}
