"""Model entry points: init, forward, loss, prefill, decode.

Parameters are plain nested-dict pytrees (no framework): stage parameters are
stacked along a leading ``num_stages`` axis (see transformer.py), embeddings
and head live at the top level.  All entry points are jit/pjit-compatible and
take only arrays + static config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.hints import shard_hint
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of, embed_init, rms_norm, softcap
from repro.models.transformer import (
    _sublayer_plan,
    apply_stack,
    init_stage,
    init_sublayer,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k_embed, k_first, k_stages, k_head = jax.random.split(key, 4)

    params: dict = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size), dt)

    first_slot = {"kind": "attn", "window": cfg.window_kind(0), "moe": False}
    first = []
    if cfg.first_dense_layers:
        fks = jax.random.split(k_first, cfg.first_dense_layers)
        for i in range(cfg.first_dense_layers):
            cfg_first = cfg.with_(d_ff=cfg.first_dense_d_ff or cfg.d_ff)
            first.append(init_sublayer(fks[i], cfg_first, first_slot))
    params["first"] = first

    stage_keys = jax.random.split(k_stages, cfg.num_stages)
    params["stages"] = jax.vmap(lambda k: init_stage(k, cfg))(stage_keys)
    return params


def param_shapes(cfg: ModelConfig):
    """Shape pytree without allocating (drives param_count + checkpoints)."""
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.key(0), cfg))
    return jax.tree.map(lambda l: l.shape, shapes)


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _embed_lookup(embed, tokens):
    return jnp.take(embed, tokens, axis=0)


def _embed_lookup_fwd(embed, tokens):
    # `embed` rides along as a residual only for its shape/dtype/sharding —
    # it is live across the step anyway (the optimizer reads it).
    return _embed_lookup(embed, tokens), (tokens, embed)


def _embed_lookup_bwd(res, dy):
    tokens, embed = res
    # Scatter-add the cotangent, keeping the (V, D) gradient SHARDED: without
    # the hint GSPMD materializes the full unsharded embedding gradient per
    # device (tens of GB for 100k vocabs) before resharding.
    dembed = jnp.zeros(embed.shape, dy.dtype).at[tokens.reshape(-1)].add(
        dy.reshape(-1, embed.shape[1]))
    dembed = shard_hint(dembed, "embed_grad")
    return dembed.astype(embed.dtype), None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = _embed_lookup(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(dtype_of(cfg.compute_dtype))


def _unembed(params, x, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return softcap(logits, cfg.logit_softcap)


def forward(params, tokens, cfg: ModelConfig, *, caches=None, decode_pos=None,
            remat: bool = True, differentiable: bool = False):
    """tokens (B,S) → (hidden (B,S,D), new_caches, metrics)."""
    B, S = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    x = shard_hint(x, "layer_boundary")
    if decode_pos is not None:
        if jnp.ndim(decode_pos) == 0:
            positions = jnp.full((S,), decode_pos, dtype=jnp.int32)
        else:
            # Per-row decode positions (continuous batching): (B,) → (B, 1),
            # broadcastable against the (..., S) layout apply_rope expects.
            positions = decode_pos.astype(jnp.int32)[:, None]
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    x, new_caches, metrics = apply_stack(
        params["stages"], params["first"], x, cfg,
        positions=positions, caches=caches, decode_pos=decode_pos, remat=remat,
        differentiable=differentiable)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches, metrics


def logits_fn(params, tokens, cfg: ModelConfig, remat: bool = True):
    x, _, metrics = forward(params, tokens, cfg, remat=remat)
    return _unembed(params, x, cfg), metrics


def chunked_cross_entropy(params, hidden, labels, cfg: ModelConfig,
                          chunk: int = 512):
    """Mean next-token CE without materializing (B,S,V) f32 logits.

    Scans over sequence chunks; each step computes (B, chunk, V) logits and
    reduces — peak memory is one chunk of logits (vocab stays shardable).
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    h = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    y = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(total, inp):
        hc, yc = inp
        logits = shard_hint(_unembed(params, hc, cfg), "logits")
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return total + jnp.sum(logz - gold), None

    # remat: the backward recomputes one logit chunk at a time instead of
    # stacking (B, S, V) logits as scan residuals.
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (h, y))
    return total / (B * S)


def loss_fn(params, tokens, labels, cfg: ModelConfig, remat: bool = True):
    hidden, _, metrics = forward(params, tokens, cfg, remat=remat,
                                 differentiable=True)
    ce = chunked_cross_entropy(params, hidden, labels, cfg)
    loss = ce
    if metrics:
        loss = loss + metrics.get("aux_loss", 0.0) + metrics.get("z_loss", 0.0)
    out_metrics = {"ce": ce, **{k: v for k, v in metrics.items()}}
    return loss, out_metrics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree matching the cache layout of apply_stack."""
    plan = _sublayer_plan(cfg)

    def sub_spec(slot):
        if slot["kind"] == "attn":
            spec = (attn_mod.mla_cache_spec(cfg, batch, max_len)
                    if cfg.attn_type == "mla"
                    else attn_mod.gqa_cache_spec(cfg, batch, max_len))
        else:
            spec = mamba_mod.mamba_cache_spec(cfg, batch)
        return {"mixer": spec}

    def stack(spec):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_stages,) + s.shape, s.dtype),
            spec)

    first_slot = {"kind": "attn", "window": cfg.window_kind(0), "moe": False}
    return {
        "first": [sub_spec(first_slot) for _ in range(cfg.first_dense_layers)],
        "stages": {f"sub{j}": stack(sub_spec(plan[j]))
                   for j in range(cfg.period)},
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def prefill_step(params, tokens, cfg: ModelConfig, max_len: int | None = None,
                 differentiable: bool = False):
    """tokens (B,S) → (last-token logits (B,V), filled caches).

    ``differentiable=True`` selects the static-trip-count attention loops
    (used by the dry-run so HLO while bounds are statically analyzable)."""
    B, S = tokens.shape
    caches = init_cache(cfg, B, max_len or S)
    hidden, new_caches, _ = forward(params, tokens, cfg, caches=caches,
                                    remat=False,
                                    differentiable=differentiable)
    logits = _unembed(params, hidden[:, -1:, :], cfg)[:, 0, :]
    return logits, new_caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """One decode step.  tokens (B,1); pos: scalar index of this token, or a
    per-row (B,) int32 vector when rows decode at independent positions
    (continuous batching).  Rows are independent, so the vector path is
    bitwise identical per row to running that row alone with a scalar pos.

    Returns (logits (B,V), new_caches).
    """
    hidden, new_caches, _ = forward(params, tokens, cfg, caches=caches,
                                    decode_pos=pos, remat=False)
    logits = _unembed(params, hidden[:, -1:, :], cfg)[:, 0, :]
    return logits, new_caches


# ---------------------------------------------------------------------------
# FLOP accounting (roofline §: MODEL_FLOPS = 6·N·D dense / 6·N_active·D MoE)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, tokens: int, *, train: bool = True,
                active_only: bool = True) -> float:
    n = cfg.active_param_count() if active_only else cfg.param_count()
    mult = 6.0 if train else 2.0
    return mult * n * tokens
