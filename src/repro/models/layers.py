"""Shared primitive layers: norms, RoPE, embeddings, softcaps, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers — all params created through these so dtype policy is uniform.
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else (1.0 / jnp.sqrt(fan_in))
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    """RMSNorm: variance in f32, elementwise scaling in the input dtype.

    Deliberately avoids materializing an f32 copy of x: a full-width
    ``x.astype(f32)`` as the first op of a rematted layer invites XLA to
    hoist the convert out of the layer scan and save a second, twice-as-big
    f32 residual stack (observed on the 512-device dry-runs).  The f32
    convert here feeds a reduction only, so it fuses away.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    rrms = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * rrms * (1.0 + weight).astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies in f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (split-half convention).  x: (..., S, H, D); positions:
    broadcastable to (..., S)."""
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)                       # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (...,S,d/2)
    sin = jnp.sin(angles)[..., :, None, :]                      # (...,S,1,d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
