"""Mixture-of-Experts with GShard-style *grouped* capacity dispatch, shared
experts, and an optional dense residual branch (Arctic).

Dispatch strategy (expert-parallel friendly — every step partitions cleanly
under GSPMD, verified by the 512-device dry-runs):

  1. tokens reshape to (G, T_l, D) where G = number of devices (the GShard
     "group" dim); all routing bookkeeping (top-k, position-in-expert cumsum,
     capacity drop) happens *within a group* — no cross-device prefix sums;
  2. each group scatters its tokens into a local (E, C_l, D) buffer
     (batched scatter over the sharded group dim → no collective);
  3. buffers regroup to the expert-parallel "rows" layout (E, R, D) with E
     sharded over 'model' and R = G·C_l rows sharded over 'data' — one
     moderate all-to-all (the EP token exchange);
  4. experts run a batched SwiGLU over their rows; expert weights are stored
     FSDP-sharded (E over 'model', d_model over 'data') and all-gathered over
     'data' per layer (transient, overlapped by the layer scan);
  5. rows return to groups (second all-to-all) and combine with renormalized
     router probabilities.

FLOPs = top_k · T · cf · (3·D·F·2) — useful-MoE-flops × capacity factor;
wire = 2 small all-to-alls + the FSDP weight gather.  Roofline notes: for
expert sets much larger than the token batch (arctic-480b at 1M tokens) the
weight gather dominates and the cell is inherently collective-bound — see
EXPERIMENTS.md §Roofline.

Capacity semantics are per-group (GShard): C_l = cf·k·T_l/E slots per expert
per group; overflow drops are *local*, so routing decisions depend only on
the group's own tokens (deterministic under resharding).

HEFT_RT hook: per-expert load statistics returned in ``metrics`` feed
:mod:`repro.sched_integration.expert_placement` (the paper's scheduler
applied to expert rebalancing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.hints import current_policy, shard_hint
from repro.models.config import ModelConfig
from repro.models.ffn import ffn_block, init_ffn_params
from repro.models.layers import dense_init, dtype_of


def init_moe_params(key, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    dt = dtype_of(cfg.param_dtype)
    D, E, F = cfg.d_model, m.num_experts, m.expert_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "experts": {
            "w_gate": dense_init(ks[1], (E, D, F), dt),
            "w_up": dense_init(ks[2], (E, D, F), dt),
            "w_down": dense_init(ks[3], (E, F, D), dt),
        },
    }
    if m.num_shared_experts > 0:
        p["shared"] = init_ffn_params(ks[4], cfg, d_ff=m.shared_d_ff or
                                      m.expert_d_ff * m.num_shared_experts)
    if m.dense_residual:
        p["dense"] = init_ffn_params(ks[5], cfg,
                                     d_ff=m.dense_residual_d_ff or cfg.d_ff)
    return p


def _maybe_shard_map(dispatch_local, combine_local):
    """Wrap dispatch/combine in shard_map over the group dim when a mesh
    policy is installed (the 512-device dry-runs / real launches).

    GSPMD cannot partition the capacity scatter/gather along a sharded batch
    dim (it replicates — tens of GB per device at 1M tokens); shard_map makes
    the group dim manual so every scatter/gather is device-local, while the
    expert all-to-alls remain GSPMD-auto resharding of the shard_map outputs.
    Without a mesh (unit tests, smoke runs) the local functions run as-is —
    bitwise the same math.
    """
    pol = current_policy() or {}
    mesh = pol.get("__mesh__")
    gspec = pol.get("moe_groups")
    if mesh is None or gspec is None:
        return dispatch_local, combine_local

    from jax.sharding import PartitionSpec as P

    gax = gspec[0]                      # group-dim axis names
    manual = frozenset(gax) if isinstance(gax, tuple) else frozenset((gax,))
    g3 = P(gax, None, None)             # (G, ·, ·)
    kg = P(None, gax, None)             # (K, G, Tl)

    dispatch = jax.shard_map(
        dispatch_local, mesh=mesh,
        in_specs=(g3, g3), out_specs=(g3, kg, kg),
        axis_names=manual, check_vma=False)
    combine = jax.shard_map(
        combine_local, mesh=mesh,
        in_specs=(g3, kg, kg, g3), out_specs=g3,
        axis_names=manual, check_vma=False)
    return dispatch, combine


def _num_groups(T: int) -> int:
    """Fallback group count when no policy installs ``__moe_groups__``:
    largest power-of-two ≤ min(T // 8, 256) so T_l ≥ 8 rows per group."""
    g = 1
    while g * 2 <= min(T // 8, 256):
        g *= 2
    return g


def _group_count(T: int) -> int:
    """Group count for the dispatch.  The launcher policy sets
    ``__moe_groups__`` = batch × model-axis-size so that the (B, S, D) →
    (G, T_l, D) reshape splits the sequence exactly at its existing shard
    boundaries — the group regroup then moves ZERO bytes in both the forward
    and backward pass (otherwise XLA inserts a full all-gather of the 30 GB
    token tensor when transposing the reshard)."""
    pol = current_policy() or {}
    g = pol.get("__moe_groups__")
    if g and T % g == 0 and T // g >= 1:
        return g
    return _num_groups(T)


def capacity_for(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * tokens_per_group * m.top_k / m.num_experts)
    return max(4, c)


def moe_block(params: dict, x: jax.Array, cfg: ModelConfig
              ) -> tuple[jax.Array, dict]:
    """x: (B, S, D) → (out (B,S,D), metrics {aux_loss, z_loss, expert_load})."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    G = _group_count(T)
    Tl = T // G
    C = capacity_for(cfg, Tl)

    xt = x.reshape(G, Tl, D)
    xt = shard_hint(xt, "moe_groups")            # P((b,m), None, None)

    # --- routing (bf16 product, f32 accumulation — an f32 copy of the token
    # tensor would cost 2× memory AND get all-gathered in the router-grad
    # backward dot) ----------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xt,
                        params["router"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)      # (G, Tl, E)
    logits = shard_hint(logits, "moe_logits")
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # (G, Tl, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance & z losses (Switch/GShard style) ----------------------
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux_loss = m.router_aux_weight * E * jnp.sum(me * ce)
    z_loss = m.router_z_weight * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # --- per-group capacity dispatch (shard_map: local scatters) ------------
    def dispatch_local(xt_l, ids_l, keeps_init=None):
        """xt_l (g, Tl, D); ids_l (g, Tl, K) → (buf, dests, keeps)."""
        g = xt_l.shape[0]
        buf = jnp.zeros((g, E * C + 1, D), dtype=xt_l.dtype)
        scatter_rows = jax.vmap(lambda b, d, v: b.at[d].set(v))
        dests, keeps = [], []
        counts = jnp.zeros((g, E), jnp.int32)
        for k in range(K):
            ids_k = ids_l[..., k]
            onehot = jax.nn.one_hot(ids_k, E, dtype=jnp.int32)
            pos_k = jnp.cumsum(onehot, axis=1) - onehot          # exclusive
            pos = jnp.take_along_axis(pos_k, ids_k[..., None], 2)[..., 0] \
                + jnp.take_along_axis(counts, ids_k, axis=1)
            keep = pos < C
            dest = jnp.where(keep, ids_k * C + pos, E * C)
            buf = scatter_rows(buf, dest, xt_l)
            dests.append(dest)
            keeps.append(keep)
            counts = jnp.minimum(counts + jnp.sum(onehot, axis=1), C)
        return buf, jnp.stack(dests), jnp.stack(keeps)           # (K,g,Tl)

    def combine_local(flat_l, dests_l, keeps_l, gates_l):
        """flat_l (g, E*C+1, D); → (g, Tl, D) f32 combine."""
        g = flat_l.shape[0]
        combined = jnp.zeros((g, Tl, D), jnp.float32)
        for k in range(K):
            wk = (gates_l[..., k] * keeps_l[k]).astype(jnp.float32)
            picked = jnp.take_along_axis(flat_l, dests_l[k][..., None], axis=1)
            combined = combined + picked.astype(jnp.float32) * wk[..., None]
        return combined

    dispatch_fn, combine_fn = _maybe_shard_map(dispatch_local, combine_local)
    buf, dests, keeps = dispatch_fn(xt, expert_ids)
    total_kept = sum(
        jnp.sum(jax.nn.one_hot(expert_ids[..., k], E, dtype=jnp.int32)
                * keeps[k][..., None].astype(jnp.int32), axis=(0, 1))
        for k in range(K))

    # --- regroup to expert-parallel rows layout -----------------------------
    grouped = buf[:, : E * C].reshape(G, E, C, D)
    rows = jnp.moveaxis(grouped, 0, 1)                          # (E, G, C, D)
    rows = shard_hint(rows, "moe_rows4")         # P(m, b, None, None)
    rows = rows.reshape(E, G * C, D)
    rows = shard_hint(rows, "moe_rows")          # P(m, b, None)

    # --- expert computation (batched SwiGLU over E) --------------------------
    w = params["experts"]
    h = jax.nn.silu(jnp.einsum("erd,edf->erf", rows, w["w_gate"])) * \
        jnp.einsum("erd,edf->erf", rows, w["w_up"])
    expert_out = jnp.einsum("erf,efd->erd", h, w["w_down"])     # (E, R, D)
    expert_out = shard_hint(expert_out, "moe_rows")

    # --- back to groups + combine --------------------------------------------
    back = jnp.moveaxis(expert_out.reshape(E, G, C, D), 0, 1)   # (G, E, C, D)
    back = shard_hint(back, "moe_groups4")       # P((b,m), None, None, None)
    flat = jnp.concatenate(
        [back.reshape(G, E * C, D),
         jnp.zeros((G, 1, D), back.dtype)], axis=1)             # (G, E*C+1, D)
    combined = combine_fn(flat, dests, keeps, gate_vals)

    out = combined.astype(x.dtype).reshape(B, S, D)
    # shared experts / dense residual run on the (B, S, D) layer-boundary
    # layout — the group layout double-books mesh axes against the FFN's
    # d_ff sharding and XLA falls back to full all-gathers in the backward.
    if "shared" in params or "dense" in params:
        xb = shard_hint(x, "layer_boundary")
        out = shard_hint(out, "layer_boundary")
        if "shared" in params:
            out = out + ffn_block(params["shared"], xb, cfg)
        if "dense" in params:
            out = out + ffn_block(params["dense"], xb, cfg)

    metrics = {"aux_loss": aux_loss, "z_loss": z_loss,
               "expert_load": total_kept.astype(jnp.float32)}
    return out, metrics
