"""Dense feed-forward blocks: SwiGLU (llama family) and GELU MLP (musicgen)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.hints import shard_hint
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of


def init_ffn_params(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    dt = dtype_of(cfg.param_dtype)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_type in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (D, F), dt),
            "w_up": dense_init(ks[1], (D, F), dt),
            "w_down": dense_init(ks[2], (F, D), dt),
        }
    return {
        "w_up": dense_init(ks[0], (D, F), dt),
        "w_down": dense_init(ks[1], (F, D), dt),
    }


def ffn_block(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in params:
        act = jax.nn.gelu if cfg.ffn_type == "geglu" else jax.nn.silu
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    # hidden stays TP-sharded: the w_down row-parallel matmul then reduces
    # partial sums instead of all-gathering the (B, S, F) activation.
    h = shard_hint(h, "ffn_hidden")
    return h @ params["w_down"]
