# LM substrate: flexible decoder-only stacks (GQA/MLA attention, local/global
# windows, softcaps, MoE with shared experts + dense residual, Mamba-1 SSM,
# hybrid interleaves) behind one ModelConfig, built for scan-over-layers
# compilation and pjit sharding.
from repro.models.config import SHAPES, ModelConfig, MoEConfig, ShapeConfig, SSMConfig
from repro.models.model import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    logits_fn,
    loss_fn,
    model_flops,
    param_shapes,
    param_specs,
    prefill_step,
)

__all__ = [
    "SHAPES", "ModelConfig", "MoEConfig", "ShapeConfig", "SSMConfig",
    "cache_specs", "decode_step", "forward", "init_cache", "init_params",
    "logits_fn", "loss_fn", "model_flops", "param_shapes", "param_specs",
    "prefill_step",
]
