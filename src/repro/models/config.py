"""Model configuration schema covering all ten assigned architectures.

One flexible decoder-only configuration space spans dense GQA transformers,
MLA (DeepSeek-V2), local/global alternation + softcaps (Gemma-2), MoE with
shared experts and dense residual (DeepSeek-V2 / Arctic), Mamba-1 SSM stacks
(Falcon-Mamba), and attention/Mamba hybrid interleaves with periodic MoE
(Jamba).  Layer heterogeneity is expressed as a repeating *pattern* whose
period must divide ``num_layers - first_dense_layers`` so the stack lowers to
one ``lax.scan`` over stacked per-stage parameters (small HLO, fast compiles,
remat-friendly — essential for the 512-device dry-runs).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0       # DeepSeek-V2: always-on shared experts
    shared_d_ff: int = 0              # d_ff of the shared-expert MLP
    dense_residual: bool = False      # Arctic: dense MLP in parallel with MoE
    dense_residual_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight
    router_z_weight: float = 1e-3
    layer_period: int = 1             # every k-th layer is MoE …
    layer_offset: int = 0             # … starting at this layer index


@dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0                  # 0 → ceil(d_model/16)
    chunk: int = 16                   # within-chunk parallel width (see mamba.py)
    bcdt_rms: bool = False            # Falcon-Mamba: RMS-normalize B, C, Δ


@dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # layer kinds: repeating pattern over layers ('attn' | 'mamba')
    block_pattern: tuple[str, ...] = ("attn",)
    first_dense_layers: int = 0       # leading layers kept out of the scan
                                      # (e.g. DeepSeek-V2's dense first layer)

    # attention
    attn_type: str = "gqa"            # 'gqa' | 'mla'
    head_dim: int = 0                 # 0 → d_model // num_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False             # Chameleon
    attn_softcap: float | None = None  # Gemma-2: 50.0
    window_pattern: tuple[str, ...] = ("global",)  # 'local'|'global' cycle
    local_window: int = 4096

    # MLA (attn_type == 'mla')
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # FFN
    ffn_type: str = "swiglu"          # 'swiglu' | 'gelu'
    first_dense_d_ff: int = 0         # d_ff for the leading dense layers

    # MoE / SSM sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None

    # embeddings / output
    tie_embeddings: bool = False
    logit_softcap: float | None = None  # Gemma-2: 30.0
    embed_scale: bool = False           # Gemma-2: multiply embed by sqrt(d)
    post_block_norm: bool = False       # Gemma-2 sandwich norms

    # numerics
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # modality frontend stub ([audio]/[vlm]: backbone only — `input_specs()`
    # feeds token ids; precomputed frame/patch embeddings enter via the same
    # embedding table shape)
    modality: str = "text"            # 'text' | 'audio' | 'vlm'

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        scanned = self.num_layers - self.first_dense_layers
        assert scanned % self.period == 0, (
            f"{self.name}: effective period {self.period} must divide "
            f"scanned layers {scanned}")
        if "mamba" in self.block_pattern:
            assert self.ssm is not None, f"{self.name}: mamba blocks need ssm config"

    # ---- derived ----------------------------------------------------------

    @property
    def period(self) -> int:
        """Effective stage period: lcm of block / MoE / window cycles so every
        stage of the layer scan is structurally identical."""
        p = len(self.block_pattern)
        if self.moe is not None:
            p = math.lcm(p, self.moe.layer_period)
        if any(self.layer_kind(i) == "attn"
               for i in range(self.first_dense_layers,
                              self.first_dense_layers + p)):
            p = math.lcm(p, len(self.window_pattern))
        return p

    @property
    def num_stages(self) -> int:
        return (self.num_layers - self.first_dense_layers) // self.period

    def layer_kind(self, layer: int) -> str:
        if layer < self.first_dense_layers:
            return "attn"
        return self.block_pattern[
            (layer - self.first_dense_layers) % len(self.block_pattern)]

    def is_moe_layer(self, layer: int) -> bool:
        if self.moe is None or layer < self.first_dense_layers:
            return False
        return (layer - self.moe.layer_offset) % self.moe.layer_period == 0 \
            and layer >= self.moe.layer_offset

    def window_kind(self, layer: int) -> str:
        return self.window_pattern[layer % len(self.window_pattern)]

    # ---- analytics (roofline §) -------------------------------------------

    def param_count(self) -> int:
        """Total parameters (exact, mirrors init_params shapes)."""
        from repro.models.model import param_shapes  # lazy import
        shapes = param_shapes(self)
        total = 0
        for leaf in _tree_leaves(shapes):
            total += math.prod(leaf)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        from repro.models.model import param_shapes
        shapes = param_shapes(self)
        total = 0
        for path, leaf in _tree_items(shapes):
            n = math.prod(leaf)
            if "experts" in path and self.moe is not None:
                n = n * self.moe.top_k // self.moe.num_experts
            total += n
        return total

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _tree_leaves(tree):
    out = []
    _walk(tree, "", out)
    return [v for _, v in out]


def _tree_items(tree):
    out: list[tuple[str, tuple]] = []
    _walk(tree, "", out)
    return out


def _walk(node, path, out):
    if isinstance(node, dict):
        for k, v in node.items():
            _walk(v, f"{path}/{k}", out)
    elif isinstance(node, (list, tuple)) and node and isinstance(node[0], (dict, list, tuple)):
        for i, v in enumerate(node):
            _walk(v, f"{path}/{i}", out)
    else:
        out.append((path, tuple(node)))


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
