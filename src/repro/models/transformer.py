"""Decoder stacks: period-based scan-over-layers with heterogeneous blocks.

Layers are grouped into *stages* of ``cfg.period`` sub-layers (the repeating
``block_pattern``); stage parameters are stacked along a leading axis and the
stack runs as ONE ``lax.scan`` — HLO size is O(period), not O(num_layers),
which keeps 512-device dry-run compiles fast, and remat applies per stage.

Heterogeneity handled here:
  * gemma2: ('local','global') window alternation + sandwich (post) norms;
  * jamba: ('mamba',…,'attn',…) 1:7 pattern with MoE on every 2nd layer;
  * deepseek-v2: first dense layer outside the scan (``first_dense_layers``).

Each sub-layer slot carries its own kind ('attn'|'mamba'), window kind, and
FFN kind ('dense'|'moe'), resolved *statically* from the config at trace time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.hints import shard_hint
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models.config import ModelConfig
from repro.models.ffn import ffn_block, init_ffn_params
from repro.models.layers import rms_norm
from repro.models.moe import init_moe_params, moe_block


@jax.custom_vjp
def _residual_barrier(x):
    """`optimization_barrier` with an explicit VJP (the primitive has no
    differentiation rule on the pinned jax); the cotangent is barriered too,
    so the backward residual stream gets the same hoisting protection."""
    return lax.optimization_barrier(x)


def _residual_barrier_fwd(x):
    return lax.optimization_barrier(x), None


def _residual_barrier_bwd(_, g):
    return (lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


def _sublayer_plan(cfg: ModelConfig) -> list[dict]:
    """Static description of each sub-layer slot within a stage."""
    plan = []
    for j in range(cfg.period):
        layer = cfg.first_dense_layers + j  # representative layer index
        kind = cfg.layer_kind(layer)
        moe = cfg.is_moe_layer(layer)
        plan.append({
            "kind": kind,
            "window": cfg.window_kind(layer) if kind == "attn" else None,
            "moe": moe,
            # pure-SSM stacks (falcon-mamba) have no FFN sub-block at all
            "ffn": "moe" if moe else ("none" if cfg.d_ff == 0 else "dense"),
        })
    # sanity: the pattern must align stage-invariantly for window/moe cycles
    for stage in range(1, cfg.num_stages):
        for j in range(cfg.period):
            layer = cfg.first_dense_layers + stage * cfg.period + j
            kind = cfg.layer_kind(layer)
            assert kind == plan[j]["kind"]
            assert cfg.is_moe_layer(layer) == plan[j]["moe"], (
                f"{cfg.name}: MoE period must align with block pattern period")
            if kind == "attn":
                assert cfg.window_kind(layer) == plan[j]["window"], (
                    f"{cfg.name}: window pattern must align with stage period")
    return plan


def init_sublayer(key, cfg: ModelConfig, slot: dict) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {"norm_1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if slot["kind"] == "attn":
        p["mixer"] = (attn_mod.init_mla_params(k1, cfg) if cfg.attn_type == "mla"
                      else attn_mod.init_gqa_params(k1, cfg))
    else:
        p["mixer"] = mamba_mod.init_mamba_params(k1, cfg)
    ffn_kind = slot.get("ffn", "moe" if slot["moe"] else "dense")
    if ffn_kind != "none":
        p["norm_2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = (init_moe_params(k2, cfg) if ffn_kind == "moe"
                    else init_ffn_params(k3, cfg))
    if cfg.post_block_norm:
        p["post_norm_1"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["post_norm_2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def init_stage(key, cfg: ModelConfig) -> dict:
    plan = _sublayer_plan(cfg)
    keys = jax.random.split(key, cfg.period)
    return {f"sub{j}": init_sublayer(keys[j], cfg, plan[j])
            for j in range(cfg.period)}


def apply_sublayer(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    slot: dict,
    *,
    positions,
    cache: dict | None,
    decode_pos,
    differentiable: bool = False,
) -> tuple[jax.Array, dict | None, dict]:
    """Pre-norm residual block: x + mixer(norm(x)); x + ffn(norm(x))."""
    metrics: dict = {}
    h = rms_norm(x, params["norm_1"], cfg.norm_eps)
    # SP-style sequence gather point: with layer-boundary activations sharded
    # (batch, model-on-seq), installing sublayer_input = P(batch, None, None)
    # turns the per-matmul weight gathers over 'model' into ONE activation
    # all-gather here + a reduce-scatter at the boundary (§Perf lever).
    h = shard_hint(h, "sublayer_input")
    if slot["kind"] == "attn":
        window = cfg.local_window if slot["window"] == "local" else None
        block = attn_mod.mla_block if cfg.attn_type == "mla" else attn_mod.gqa_block
        mixer_cache = cache.get("mixer") if cache else None
        h, new_mixer_cache = block(params["mixer"], h, cfg, window=window,
                                   positions=positions, cache=mixer_cache,
                                   decode_pos=decode_pos,
                                   differentiable=differentiable)
    else:
        mixer_cache = cache.get("mixer") if cache else None
        h, new_mixer_cache = mamba_mod.mamba_block(
            params["mixer"], h, cfg, cache=mixer_cache, decode_pos=decode_pos)
    if cfg.post_block_norm:
        h = rms_norm(h, params["post_norm_1"], cfg.norm_eps)
    x = x + h

    ffn_kind = slot.get("ffn", "moe" if slot["moe"] else "dense")
    if ffn_kind != "none":
        h = rms_norm(x, params["norm_2"], cfg.norm_eps)
        h = shard_hint(h, "sublayer_input")
        if ffn_kind == "moe":
            h, moe_metrics = moe_block(params["ffn"], h, cfg)
            metrics.update(moe_metrics)
        else:
            h = ffn_block(params["ffn"], h, cfg)
        if cfg.post_block_norm:
            h = rms_norm(h, params["post_norm_2"], cfg.norm_eps)
        x = x + h
    x = shard_hint(x, "layer_boundary")

    new_cache = {"mixer": new_mixer_cache} if new_mixer_cache is not None else None
    return x, new_cache, metrics


def apply_stack(
    stage_params: dict,          # leaves stacked (num_stages, ...)
    first_dense_params: list,    # unrolled leading layers (deepseek-v2)
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions,
    caches: dict | None = None,  # {'first': [...], 'stages': stacked pytree}
    decode_pos=None,
    remat: bool = True,
    differentiable: bool = False,
) -> tuple[jax.Array, dict | None, dict]:
    plan = _sublayer_plan(cfg)

    # --- leading dense layers, unrolled -----------------------------------
    first_slot = {"kind": "attn", "window": cfg.window_kind(0), "moe": False}
    new_first_caches = []
    for i, lp in enumerate(first_dense_params):
        c = caches["first"][i] if caches else None
        x, nc, _ = apply_sublayer(lp, x, cfg, first_slot, positions=positions,
                                  cache=c, decode_pos=decode_pos,
                                  differentiable=differentiable)
        new_first_caches.append(nc)

    # --- scanned stages -----------------------------------------------------
    agg_init = {}
    if cfg.moe is not None and any(s["moe"] for s in plan):
        E = cfg.moe.num_experts
        agg_init = {"aux_loss": jnp.zeros((), jnp.float32),
                    "z_loss": jnp.zeros((), jnp.float32),
                    "expert_load": jnp.zeros((E,), jnp.float32)}

    def stage_body(carry, stage_in):
        x, agg = carry
        # barrier: keeps the saved-for-backward residual in its storage dtype
        # (XLA otherwise hoists downstream f32 converts into the save loop,
        # doubling the stacked-residual footprint).
        x = _residual_barrier(x)
        sp, c = stage_in
        new_cache = {}
        for j, slot in enumerate(plan):
            sub_cache = c.get(f"sub{j}") if c is not None else None
            x, nc, met = apply_sublayer(sp[f"sub{j}"], x, cfg, slot,
                                        positions=positions, cache=sub_cache,
                                        decode_pos=decode_pos,
                                        differentiable=differentiable)
            if nc is not None:
                new_cache[f"sub{j}"] = nc
            if met:
                agg = {
                    "aux_loss": agg["aux_loss"] + met["aux_loss"],
                    "z_loss": agg["z_loss"] + met["z_loss"],
                    "expert_load": agg["expert_load"] + met["expert_load"],
                }
        return (x, agg), (new_cache if new_cache else None)

    body = stage_body
    if remat:
        body = jax.checkpoint(stage_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stage_params, caches["stages"]) if caches else (stage_params, None)
    if caches is None:
        # scan needs a concrete xs pytree; feed params only
        (x, agg), _ = lax.scan(lambda c, sp: body(c, (sp, None)),
                               (x, agg_init), stage_params)
        new_stage_caches = None
    else:
        (x, agg), new_stage_caches = lax.scan(body, (x, agg_init), xs)

    new_caches = None
    if caches is not None:
        new_caches = {"first": new_first_caches, "stages": new_stage_caches}
    return x, new_caches, agg
