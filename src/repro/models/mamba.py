"""Mamba-1 (selective SSM) block — Falcon-Mamba / Jamba mamba layers.

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §2 applies to the
substrate too): the GPU implementation fuses the whole recurrence in shared
memory per block; on TPU we use a *two-level chunked scan*:

  * outer ``lax.scan`` over sequence chunks carries the (B, d_inner, N)
    boundary state — O(S/Q) sequential steps;
  * within a chunk, ``lax.associative_scan`` over the Q positions evaluates
    the recurrence in log2(Q) vector passes, materializing only
    (B, Q, d_inner, N) — bounded VMEM/HBM pressure regardless of S.

This keeps HLO small (one scan), keeps the backward pass memory at one
chunk's residuals per layer, and is numerically stable (no exp of positive
cumulative sums).  Decode is the O(1) single-step recurrence with a rolling
conv state.

Falcon-Mamba detail: RMS-normalizes B, C and Δ before use (``bcdt_rms``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.hints import shard_hint
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of


def _dt_rank(cfg: ModelConfig) -> int:
    r = cfg.ssm.dt_rank
    return r if r > 0 else -(-cfg.d_model // 16)


def init_mamba_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    s = cfg.ssm
    D, dI, N = cfg.d_model, s.d_inner, s.d_state
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (dI, N))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI), dt),
        "conv_w": dense_init(ks[1], (s.d_conv, dI), dt, scale=0.5),
        "conv_b": jnp.zeros((dI,), jnp.float32),
        "x_proj": dense_init(ks[2], (dI, R + 2 * N), dt),
        "dt_proj": dense_init(ks[3], (R, dI), dt),
        "dt_bias": jnp.full((dI,), -4.6, jnp.float32),  # softplus ≈ 0.01
        "A_log": jnp.log(A),                             # (dI, N) f32
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[4], (dI, D), dt),
    }


def _rms(x, eps):
    return x * lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv, kernel K, via K shifted adds.

    x: (B, S, dI); w: (K, dI); state: (B, K-1, dI) trailing inputs of the
    previous segment (decode/streaming).  Returns (y, new_state).
    """
    B, S, dI = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, dI), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, dI)
    y = jnp.zeros((B, S, dI), jnp.float32)
    for i in range(K):
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b
    new_state = xp[:, -(K - 1):]
    return y.astype(x.dtype), new_state


def _ssm_inputs(params, u, cfg):
    """u: (B, L, dI) → Δ (B,L,dI), B_t (B,L,N), C_t (B,L,N) in f32."""
    s = cfg.ssm
    N = s.d_state
    R = _dt_rank(cfg)
    proj = u @ params["x_proj"]                        # (B, L, R+2N)
    dt_r, B_t, C_t = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    if getattr(s, "bcdt_rms", False):
        eps = cfg.norm_eps
        dt_r, B_t, C_t = _rms(dt_r, eps), _rms(B_t, eps), _rms(C_t, eps)
    delta = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                            + params["dt_bias"])      # (B, L, dI)
    return delta, B_t, C_t


def _chunk_recurrence(h0, decay, bx):
    """Within-chunk associative scan.

    h0: (B, dI, N); decay/bx: (B, Q, dI, N).  Returns h_t for every t
    (B, Q, dI, N).
    """
    def combine(a, b):
        a_a, a_b = a
        b_a, b_b = b
        return a_a * b_a, b_a * a_b + b_b

    a_sc, b_sc = lax.associative_scan(combine, (decay, bx), axis=1)
    return a_sc * h0[:, None] + b_sc


def selective_scan(params, u, cfg, h0=None):
    """u: (B, S, dI) post-conv activations → (y (B,S,dI), h_final)."""
    s = cfg.ssm
    B, S, dI = u.shape
    N = s.d_state
    Q = min(s.chunk, S)
    assert S % Q == 0, (S, Q)
    A = -jnp.exp(params["A_log"])                      # (dI, N) f32
    if h0 is None:
        h0 = jnp.zeros((B, dI, N), jnp.float32)

    delta, B_t, C_t = _ssm_inputs(params, u, cfg)
    uf = u.astype(jnp.float32)

    nc = S // Q
    # (nc, B, Q, ...) chunked views, scanned over nc
    def chunked(x):
        return jnp.moveaxis(x.reshape(B, nc, Q, *x.shape[2:]), 1, 0)

    xs = (chunked(delta), chunked(B_t), chunked(C_t), chunked(uf))

    def chunk_body(h, inp):
        d_c, b_c, c_c, u_c = inp                      # (B,Q,dI/..N)
        decay = jnp.exp(d_c[..., None] * A)           # (B,Q,dI,N)
        bx = (d_c * u_c)[..., None] * b_c[:, :, None, :]   # (B,Q,dI,N)
        hs = _chunk_recurrence(h, decay, bx)          # (B,Q,dI,N)
        y = jnp.einsum("bqdn,bqn->bqd", hs, c_c)      # (B,Q,dI)
        return hs[:, -1], y

    h_final, ys = lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, dI)
    y = y + uf * params["D"]
    return y.astype(u.dtype), h_final


def mamba_block(
    params: dict,
    x: jax.Array,                 # (B, S, D)
    cfg: ModelConfig,
    *,
    cache: dict | None = None,    # {'conv': (B,K-1,dI), 'ssm': (B,dI,N)}
    decode_pos: jax.Array | None = None,
    **_unused,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    dI = cfg.ssm.d_inner
    xz = x @ params["in_proj"]                         # (B, S, 2·dI)
    u, z = jnp.split(xz, 2, axis=-1)
    u = shard_hint(u, "mamba_inner")

    conv_state = cache["conv"] if cache is not None else None
    h0 = cache["ssm"] if cache is not None else None

    if decode_pos is not None:
        assert S == 1 and cache is not None
        u_c, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                                     conv_state)
        u_c = jax.nn.silu(u_c)
        # single-step recurrence
        delta, B_t, C_t = _ssm_inputs(params, u_c, cfg)
        A = -jnp.exp(params["A_log"])
        decay = jnp.exp(delta[:, 0, :, None] * A)                    # (B,dI,N)
        bx = (delta[:, 0] * u_c[:, 0].astype(jnp.float32))[..., None] \
            * B_t[:, 0, None, :]
        h = decay * h0 + bx
        y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0])[:, None, :]       # (B,1,dI)
        y = y + u_c.astype(jnp.float32) * params["D"]
        new_cache = {"conv": new_conv, "ssm": h}
        y = y.astype(x.dtype)
    else:
        u_c, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                                     conv_state)
        u_c = jax.nn.silu(u_c)
        y, h_final = selective_scan(params, u_c, cfg, h0)
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv, "ssm": h_final}

    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    return out, new_cache


def mamba_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    dt = dtype_of(cfg.compute_dtype)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, s.d_inner), dt),
        "ssm": jax.ShapeDtypeStruct((batch, s.d_inner, s.d_state), jnp.float32),
    }
