# The paper's primary contribution: HEFT_RT scheduling — software reference
# (heft_rt), the hardware cycle/resource models that reproduce the paper's
# latency and FPGA-cost claims, and the classic-HEFT quality baseline.
from repro.core.heft_rt import (
    ScheduleResult,
    eft_assign,
    heft_rt,
    heft_rt_batched,
    heft_rt_jit,
    heft_rt_numpy,
    priority_order,
)
from repro.core.heft_static import DAG, StaticSchedule, heft_static, upward_rank
from repro.core.queue_model import (
    CycleReport,
    first_decision_worst_case,
    hw_latency_ns,
    oddeven_sort_cycles,
    per_decision_latency_ns,
    simulate_mapping_event,
    worst_case_cycles,
)
from repro.core.resource_model import (
    PAPER_CRITICAL_PATH_NS,
    PAPER_DESIGN,
    PAPER_PER_DECISION_NS,
    SchedulerDesign,
    critical_path_ns,
    total_luts,
    total_registers,
    utilization,
)

__all__ = [
    "ScheduleResult", "eft_assign", "heft_rt", "heft_rt_batched", "heft_rt_jit",
    "heft_rt_numpy", "priority_order",
    "DAG", "StaticSchedule", "heft_static", "upward_rank",
    "CycleReport", "first_decision_worst_case", "hw_latency_ns",
    "oddeven_sort_cycles", "per_decision_latency_ns", "simulate_mapping_event",
    "worst_case_cycles",
    "PAPER_CRITICAL_PATH_NS", "PAPER_DESIGN", "PAPER_PER_DECISION_NS",
    "SchedulerDesign", "critical_path_ns", "total_luts", "total_registers",
    "utilization",
]
