"""HEFT_RT — the runtime variant of Heterogeneous Earliest Finish Time.

This is the algorithm the paper implements in hardware (Section III-B / IV):
at each *mapping event* the scheduler receives

  - the ready queue: for each task, its average execution time across all PEs
    (``Avg_TID``) and its per-PE execution time (``Exec_TID[PE_i]``),
  - the estimated availability time of every PE (``T_avail``),

sorts the ready queue by *descending* average execution time (the priority
queue), and then assigns tasks one by one to the PE with the earliest finish
time ``T_finish[PE_i] = T_avail[PE_i] + Exec_TID[PE_i]``, updating the selected
PE's availability register after each assignment (the hardware feedback loop
through the PE Handlers and the EFT Selector).

Two functionally identical implementations exist in this repo:

  * this module — pure ``jax.numpy`` + ``lax.scan`` (the "software" scheduler,
    also the oracle for the Pallas kernels),
  * :mod:`repro.kernels` — the TPU-native dataplane mirroring the paper's FPGA
    overlay (odd–even transposition sort + EFT min-tree), validated to make
    *bit-identical* mapping decisions (the paper's Fig. 3 claim).

Conventions
-----------
* Invalid / padding queue slots are marked by ``valid=False``; they sort last
  and receive assignment ``-1``.
* Unsupported (task, PE) pairs carry ``exec = +inf`` and are never selected
  unless every PE is unsupported (then the task is marked unschedulable, -1).
* Ties in the EFT selection resolve to the lowest PE index — the semantics of
  the paper's comparator min-tree.
* The sort is *stable* (odd–even transposition with strict compare is stable),
  so software and hardware orderings agree exactly even with duplicate keys.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

INF = jnp.inf


class ScheduleResult(NamedTuple):
    """Output of one mapping event.

    All per-task arrays are in *priority order* (the order tasks were dequeued
    from the priority queue), length D (queue depth).
    """

    order: jax.Array        # i32[D] — queue slot index (QID) in priority order
    assignment: jax.Array   # i32[D] — selected PE per dequeued task, -1 if none
    start_time: jax.Array   # f32[D] — T_avail of the selected PE at assignment
    finish_time: jax.Array  # f32[D] — start + exec on the selected PE
    new_avail: jax.Array    # f32[P] — updated PE availability registers


def priority_order(avg: jax.Array, valid: jax.Array) -> jax.Array:
    """Stable descending sort order by average execution time.

    Mirrors the shift-register priority queue: highest ``Avg_TID`` first,
    invalid slots last, stable among ties.
    """
    keys = jnp.where(valid, avg.astype(jnp.float32), -INF)
    return jnp.argsort(-keys, stable=True).astype(jnp.int32)


def eft_assign(
    exec_sorted: jax.Array,   # f32[D, P] exec times in priority order
    avail: jax.Array,         # f32[P]
    valid_sorted: jax.Array,  # bool[D]
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sequential EFT assignment — the PE-handler / EFT-selector feedback loop.

    Returns (assignment i32[D], start f32[D], finish f32[D], new_avail f32[P]).
    """
    P = avail.shape[-1]
    lanes = jnp.arange(P)

    def step(avail, inp):
        ex, v = inp
        finish = avail + ex                       # PE handlers: adders
        pe = jnp.argmin(finish).astype(jnp.int32)  # EFT selector: min-tree
        f = finish[pe]
        schedulable = v & jnp.isfinite(f)
        start = avail[pe]
        # Availability register write-back of the selected PE handler only.
        new_avail = jnp.where((lanes == pe) & schedulable, f, avail)
        pe_out = jnp.where(schedulable, pe, jnp.int32(-1))
        return new_avail, (
            pe_out,
            jnp.where(schedulable, start, INF),
            jnp.where(schedulable, f, INF),
        )

    new_avail, (pes, starts, fins) = lax.scan(
        step, avail.astype(jnp.float32), (exec_sorted.astype(jnp.float32), valid_sorted)
    )
    return pes, starts, fins, new_avail


def heft_rt(
    avg: jax.Array,          # f32[D] — Avg_TID per queue slot
    exec_times: jax.Array,   # f32[D, P] — Exec_TID[PE_i]
    avail: jax.Array,        # f32[P] — T_avail
    valid: jax.Array | None = None,  # bool[D]
) -> ScheduleResult:
    """One HEFT_RT mapping event (software reference implementation)."""
    D = avg.shape[-1]
    if valid is None:
        valid = jnp.ones((D,), dtype=bool)
    order = priority_order(avg, valid)
    exec_sorted = jnp.take(exec_times, order, axis=0)
    valid_sorted = jnp.take(valid, order, axis=0)
    pes, starts, fins, new_avail = eft_assign(exec_sorted, avail, valid_sorted)
    return ScheduleResult(order, pes, starts, fins, new_avail)


heft_rt_jit = jax.jit(heft_rt)


def heft_rt_batched(avg, exec_times, avail, valid=None):
    """vmapped mapping events — used by sweep benchmarks and the serving
    scheduler when scoring many independent queues at once."""
    if valid is None:
        valid = jnp.ones(avg.shape, dtype=bool)
    return jax.vmap(heft_rt)(avg, exec_times, avail, valid)


# ---------------------------------------------------------------------------
# Plain-numpy twin used by the discrete-event runtime simulator (hot path is
# thousands of tiny mapping events; numpy avoids dispatch overhead there, and
# tests pin it against heft_rt / the Pallas kernels).
# ---------------------------------------------------------------------------

import numpy as np  # noqa: E402


def heft_rt_numpy(avg, exec_times, avail):
    """Returns (order, assignment, start, finish, new_avail) as numpy arrays.

    ``avg``: (n,), ``exec_times``: (n, P), ``avail``: (P,). All slots valid.
    """
    avg = np.asarray(avg, dtype=np.float64)
    exec_times = np.asarray(exec_times, dtype=np.float64)
    avail = np.array(avail, dtype=np.float64)
    n = avg.shape[0]
    # numpy has no descending stable sort; negate with stable mergesort.
    order = np.argsort(-avg, kind="stable")
    assignment = np.full(n, -1, dtype=np.int64)
    start = np.full(n, np.inf)
    finish = np.full(n, np.inf)
    for i, t in enumerate(order):
        fin = avail + exec_times[t]
        pe = int(np.argmin(fin))
        if np.isfinite(fin[pe]):
            assignment[i] = pe
            start[i] = avail[pe]
            finish[i] = fin[pe]
            avail[pe] = fin[pe]
    return order, assignment, start, finish, avail
