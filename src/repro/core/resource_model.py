"""Analytical FPGA resource & timing model for the hardware HEFT_RT scheduler.

Reproduces the scaling behaviour of Tables II, III and IV of the paper on the
Zynq ZCU102.  The paper's own analysis (Section VI-A) says:

  * Priority-queue LUTs/registers scale linearly with depth D and with the
    key bit-width W (each cell holds W(Avg) + W(QID) bits plus compare/swap
    muxes); W(QID) = ceil(log2 D).
  * LUT-RAM scales with P·D·W_exec (stores Exec[QID][PE_i]); past a size
    threshold the tools map it to BRAM instead (the P=16, D=512 row).
  * Path delay is INDEPENDENT of D (neighbour-only exchanges) and grows
    with P through the EFT-selector comparator tree (log2 P levels) plus
    wiring/mux fan-in effects.

The constants below are least-squares / exact fits to the paper's tables; the
benchmarks print model-vs-paper side by side so the fit quality is visible.
ZCU102 capacity: 274,080 LUTs; 548,160 registers; 1,824 half-BRAMs (912×36Kb).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

ZCU102_LUTS = 274_080
ZCU102_REGS = 548_160
ZCU102_LUTRAM = 144_000


@dataclass(frozen=True)
class SchedulerDesign:
    P: int = 4        # number of PEs
    D: int = 512      # priority-queue depth
    W_avg: int = 16   # bit width of Avg_TID
    W_exec: int = 16  # bit width of Exec_TID[PE_i]

    @property
    def W_qid(self) -> int:
        return max(1, math.ceil(math.log2(self.D)))


# --- fitted constants -------------------------------------------------------
# Priority queue cell cost per bit of (W_avg + W_qid) payload, fitted to
# Table IV's P=4 rows (D=256→512 slope; exact at D=256/512, <4% at D=64).
_LUT_PER_CELL_BIT = 1.4643   # logic LUTs per queue-cell payload bit
_REG_PER_CELL_BIT = 1.0503   # registers per queue-cell payload bit
_LUT_QUEUE_BASE = 409.0      # control FSM / sorted-detect / shift control
_REG_QUEUE_BASE = 962.0

# PE handler: adder (W_exec) + availability register + mux.
_LUT_PER_PE_BIT = 6.3        # from Table II: 404 LUTs / 4 PEs / 16 bits
_REG_PER_PE_BIT = 2.0        # 128 regs / 4 PEs / 16 bits

# EFT selector comparator tree: (P-1) comparators of W_exec bits.
_LUT_PER_CMP_BIT = 1.0       # from Table II: 48 LUTs / 3 comparators / 16 bits

# LUT-RAM: a Xilinx SLICEM LUT stores 64 bits; distributed RAM for the
# Exec[QID][PE] table costs P·D·W_exec/64 LUTs ≈ 0.625·P·D at W=16 with
# dual-port duplication (matches 160/320/640/1280/2560 in Table IV exactly).
_LUTRAM_PER_ENTRY_BIT = 0.625 / 16.0
_LUTRAM_BRAM_THRESHOLD = 4096  # P·D above which tools spill to BRAM (P=16 row)

# Path delay (ns): base queue compare-exchange + EFT tree depth + fanout term.
# Exact 3-point fit to Table IV (P=4:3.048, P=8:4.637, P=16:6.875 @ D=512):
#   delay = a + b·log2(P) + c·P·log2(P)
_DELAY_BASE = 0.519
_DELAY_PER_TREE_LEVEL = 1.15633
_DELAY_PER_PE_FANOUT = 0.027042


def queue_luts(d: SchedulerDesign) -> float:
    bits = d.W_avg + d.W_qid
    return _LUT_QUEUE_BASE + _LUT_PER_CELL_BIT * d.D * bits


def queue_registers(d: SchedulerDesign) -> float:
    bits = d.W_avg + d.W_qid
    return _REG_QUEUE_BASE + _REG_PER_CELL_BIT * d.D * bits


def pe_handler_luts(d: SchedulerDesign) -> float:
    return _LUT_PER_PE_BIT * d.P * d.W_exec


def pe_handler_registers(d: SchedulerDesign) -> float:
    return _REG_PER_PE_BIT * d.P * d.W_exec


def eft_selector_luts(d: SchedulerDesign) -> float:
    return _LUT_PER_CMP_BIT * (d.P - 1) * d.W_exec


def lutram(d: SchedulerDesign) -> float:
    if d.P * d.D > _LUTRAM_BRAM_THRESHOLD:
        # tools split between LUT-RAM and BRAM past the threshold (Table IV,
        # P=16 row: 3,200 LUT-RAM + 3.5 BRAM instead of 5,120 LUT-RAM).
        return _LUTRAM_PER_ENTRY_BIT * _LUTRAM_BRAM_THRESHOLD * d.W_exec + \
            0.25 * _LUTRAM_PER_ENTRY_BIT * (d.P * d.D - _LUTRAM_BRAM_THRESHOLD) * d.W_exec
    return _LUTRAM_PER_ENTRY_BIT * d.P * d.D * d.W_exec


def bram(d: SchedulerDesign) -> float:
    if d.P * d.D > _LUTRAM_BRAM_THRESHOLD:
        return 3.5
    return 0.5  # TID store (paper Table II "Total" row)


def total_luts(d: SchedulerDesign) -> float:
    return queue_luts(d) + pe_handler_luts(d) + eft_selector_luts(d)


def total_registers(d: SchedulerDesign) -> float:
    return queue_registers(d) + pe_handler_registers(d)


def critical_path_ns(d: SchedulerDesign) -> float:
    """Path delay: flat in D, tree-depth + fan-out growth in P."""
    tree_levels = math.ceil(math.log2(max(d.P, 2)))
    return _DELAY_BASE + _DELAY_PER_TREE_LEVEL * tree_levels + \
        _DELAY_PER_PE_FANOUT * d.P * tree_levels


def utilization(d: SchedulerDesign) -> dict[str, float]:
    return {
        "luts": total_luts(d) / ZCU102_LUTS,
        "registers": total_registers(d) / ZCU102_REGS,
        "lutram": lutram(d) / ZCU102_LUTRAM,
    }


# Paper ground truth for the benchmark comparison (Tables II–IV).
PAPER_TABLE_IV = [
    # (P, D, LUTs, LUT-RAM, Registers, BRAM, critical path ns)
    (4, 64, 2817, 160, 2520, 0.5, 3.060),
    (4, 128, 5190, 320, 4159, 0.5, 3.029),
    (4, 256, 9857, 640, 7543, 0.5, 2.976),
    (4, 512, 19603, 1280, 14534, 0.5, 3.048),
    (8, 512, 20471, 2560, 15243, 0.5, 4.637),
    (16, 512, 22038, 3200, 16422, 3.5, 6.875),
]

PAPER_TABLE_II = {
    "priority_queue": {"luts": 18632, "registers": 13433},
    "pe_handlers": {"luts": 404, "registers": 128},
    "eft_selector": {"luts": 48, "registers": 0},
    "total": {"luts": 19603, "lutram": 1280, "registers": 14534, "bram": 0.5},
}

PAPER_TABLE_III = {
    # HEFT_RT1: P=16, D=132, W=16 — vs Derafshi et al. [5]
    "heft_rt1": {"P": 16, "D": 132, "W": 16,
                 "luts": 7598, "lutram": 1920, "registers": 6430, "delay_ns": 5.91},
    # HEFT_RT2: P=4, D=64, W=32 — vs Tang & Bergmann [4]
    "heft_rt2": {"P": 4, "D": 64, "W": 32,
                 "luts": 4360, "lutram": 160, "registers": 3590, "delay_ns": 3.035},
}

# The design point used for the headline 9.144 ns/decision claim.
PAPER_DESIGN = SchedulerDesign(P=4, D=512, W_avg=16, W_exec=16)
PAPER_CRITICAL_PATH_NS = 3.048
PAPER_PER_DECISION_NS = 9.144
