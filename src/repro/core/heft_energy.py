"""Energy-aware HEFT_RT — the paper's stated future work (Section VII).

"As future work, we will explore acceleration of energy-aware scheduling
heuristics in order to expand our evaluations beyond focusing purely on
optimization of execution time."

This module implements the natural extension compatible with the hardware
datapath: the PE handlers additionally hold per-PE power coefficients, and
the selector minimizes

    cost[PE_i] = T_finish[PE_i] + λ · E(task, PE_i)
    E(task, PE_i) = Exec_TID[PE_i] · power[PE_i]

λ = 0 recovers exact HEFT_RT (tested); λ → ∞ approaches min-energy greedy.
Hardware cost: one extra multiplier + adder per PE handler and a wider
comparator tree — the resource model extension is a second W-bit multiplier
per handler (+≈6.3 LUTs/bit) with no change to the 3n+3 cycle count, since
the energy term folds into the same single-cycle select.

The Pareto sweep (`energy_pareto`) reproduces the classic energy/makespan
trade-off curve on the paper's SoC, where the FFT accelerator is both faster
AND lower-energy for FFTs, while for ARM-only tasks the trade-off is real.
"""

from __future__ import annotations

import numpy as np


def heft_rt_energy_numpy(avg, exec_times, avail, power, lam: float = 0.0):
    """Energy-aware mapping event.

    power: (P,) relative power draw of each PE (W, arbitrary units).
    Returns (order, assignment, start, finish, new_avail, energy).
    """
    avg = np.asarray(avg, dtype=np.float64)
    exec_times = np.asarray(exec_times, dtype=np.float64)
    avail = np.array(avail, dtype=np.float64)
    power = np.asarray(power, dtype=np.float64)
    n = avg.shape[0]
    order = np.argsort(-avg, kind="stable")
    assignment = np.full(n, -1, dtype=np.int64)
    start = np.full(n, np.inf)
    finish = np.full(n, np.inf)
    energy = 0.0
    for i, t in enumerate(order):
        fin = avail + exec_times[t]
        cost = fin + lam * exec_times[t] * power
        pe = int(np.argmin(cost))
        if np.isfinite(fin[pe]):
            assignment[i] = pe
            start[i] = avail[pe]
            finish[i] = fin[pe]
            avail[pe] = fin[pe]
            energy += exec_times[t, pe] * power[pe]
    return order, assignment, start, finish, avail, energy


def energy_pareto(avg, exec_times, power, lams=(0.0, 0.25, 0.5, 1.0, 2.0, 8.0)):
    """Sweep λ → [(lam, makespan, energy)] — the energy/latency frontier."""
    P = exec_times.shape[1]
    out = []
    for lam in lams:
        _, _, _, _, new_avail, energy = heft_rt_energy_numpy(
            avg, exec_times, np.zeros(P), power, lam)
        out.append((lam, float(np.max(new_avail)), float(energy)))
    return out
