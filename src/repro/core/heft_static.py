"""Classic (static) HEFT — Topcuoglu & Hariri 2002 — the paper's reference [3].

The paper contrasts HEFT_RT against classic HEFT: classic HEFT requires the
*full application DAG* up front (upward ranks need successor knowledge) and can
only schedule one application at a time — which is exactly why Aliyev et al.
[10]'s hardware HEFT is "not suitable for runtime execution" (Section II) and
why HEFT_RT exists.  We implement classic HEFT as the quality baseline: the
runtime benchmarks compare HEFT_RT's dynamically-built schedules against the
static HEFT schedule computed with perfect knowledge (an upper bound on
schedule quality for a single DAG).

Implementation is plain numpy — it is a baseline/oracle, not a hot path.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class DAG:
    """Task DAG with per-PE computation costs and edge communication costs."""

    num_tasks: int
    comp: np.ndarray                    # (T, P) computation cost; inf if unsupported
    succ: dict[int, list[tuple[int, float]]] = field(default_factory=dict)
    # succ[t] = [(child, comm_cost), ...]

    def predecessors(self) -> dict[int, list[tuple[int, float]]]:
        pred: dict[int, list[tuple[int, float]]] = {t: [] for t in range(self.num_tasks)}
        for t, children in self.succ.items():
            for c, w in children:
                pred[c].append((t, w))
        return pred


def upward_rank(dag: DAG) -> np.ndarray:
    """rank_u(t) = mean_p comp[t,p] + max_{c in succ(t)} (comm(t,c) + rank_u(c))."""
    comp_mean = np.where(np.isfinite(dag.comp), dag.comp, np.nan)
    wbar = np.nanmean(comp_mean, axis=1)
    rank = np.zeros(dag.num_tasks)
    # reverse topological order via DFS
    visited = np.zeros(dag.num_tasks, dtype=bool)
    order: list[int] = []

    def dfs(t: int) -> None:
        visited[t] = True
        for c, _ in dag.succ.get(t, []):
            if not visited[c]:
                dfs(c)
        order.append(t)

    for t in range(dag.num_tasks):
        if not visited[t]:
            dfs(t)
    for t in order:  # children already finalized
        best = 0.0
        for c, w in dag.succ.get(t, []):
            best = max(best, w + rank[c])
        rank[t] = wbar[t] + best
    return rank


@dataclass
class StaticSchedule:
    assignment: np.ndarray   # (T,) PE per task
    start: np.ndarray        # (T,)
    finish: np.ndarray       # (T,)

    @property
    def makespan(self) -> float:
        return float(np.max(self.finish))


def heft_static(dag: DAG, num_pes: int, insertion: bool = True) -> StaticSchedule:
    """Full classic HEFT: rank-order tasks, insertion-based EFT placement."""
    ranks = upward_rank(dag)
    order = np.argsort(-ranks, kind="stable")
    pred = dag.predecessors()

    # per-PE list of (start, finish) occupied slots, kept sorted
    slots: list[list[tuple[float, float]]] = [[] for _ in range(num_pes)]
    assignment = np.full(dag.num_tasks, -1, dtype=np.int64)
    start = np.full(dag.num_tasks, np.inf)
    finish = np.full(dag.num_tasks, np.inf)

    for t in order:
        best_pe, best_start, best_finish = -1, np.inf, np.inf
        for p in range(num_pes):
            cost = dag.comp[t, p]
            if not np.isfinite(cost):
                continue
            # data-ready time: all predecessors finished (+ comm if cross-PE)
            ready = 0.0
            for u, w in pred[t]:
                comm = 0.0 if assignment[u] == p else w
                ready = max(ready, finish[u] + comm)
            st = _earliest_slot(slots[p], ready, cost) if insertion else \
                max(ready, slots[p][-1][1] if slots[p] else 0.0)
            ft = st + cost
            if ft < best_finish:
                best_pe, best_start, best_finish = p, st, ft
        assignment[t] = best_pe
        start[t] = best_start
        finish[t] = best_finish
        _insert_slot(slots[best_pe], (best_start, best_finish))

    return StaticSchedule(assignment, start, finish)


def _earliest_slot(busy: list[tuple[float, float]], ready: float, dur: float) -> float:
    """Insertion-based policy: earliest gap ≥ dur starting at or after ready."""
    t = ready
    for s, f in busy:
        if t + dur <= s:
            return t
        t = max(t, f)
    return t


def _insert_slot(busy: list[tuple[float, float]], slot: tuple[float, float]) -> None:
    busy.append(slot)
    busy.sort()
