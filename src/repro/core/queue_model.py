"""Cycle-accurate model of the paper's hardware scheduler datapath.

Reproduces Section IV-B/VI-A of the paper:

  * shift-register priority queue of depth D, insertion at 1 task/cycle,
  * odd–even transposition sort, one compare phase per cycle, alternating
    even/odd phases; sorting terminates after TWO consecutive swap-free cycles,
  * dequeue (drain) at 1 task/cycle while the LUT-RAM lookup + PE Handler adder
    + EFT Selector min-tree produce one task→PE decision per cycle (1 extra
    cycle of latency for the first decision),
  * worst-case total of ``3n + 3`` cycles for a ready queue of size n, with the
    first mapping decision available after ``2n + 3`` cycles.

The emulator below steps the queue FSM cycle by cycle, so early termination,
pre-sorted inputs, duplicate keys etc. all fall out naturally, and the closed
form is *validated* against it in tests rather than assumed.

Wall-clock latency = cycles × critical path (ns), with the critical path taken
from :mod:`repro.core.resource_model` (Table IV of the paper).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CycleReport:
    n: int                  # ready-queue size for this mapping event
    fill_cycles: int        # n — one insertion per cycle
    sort_cycles: int        # compare phases actually executed (incl. 2 idle)
    first_decision_cycle: int  # cycle index at which the first task→PE pair emerges
    drain_cycles: int       # n — one dequeue+decision per cycle
    total_cycles: int

    @property
    def worst_case(self) -> int:
        return 3 * self.n + 3

    @property
    def avg_cycles_per_decision(self) -> float:
        return self.total_cycles / max(self.n, 1)


def oddeven_sort_cycles(keys: np.ndarray) -> tuple[np.ndarray, int]:
    """Run odd–even transposition (descending, strict swaps) on ``keys``.

    Returns (permutation order, number of compare cycles executed).  One phase
    (even- or odd-indexed compare pairs) = one cycle, exactly as the shift
    register queue does it; termination after two consecutive swap-free cycles
    (both phase parities must pass clean).
    """
    keys = np.asarray(keys, dtype=np.float64)
    n = keys.shape[0]
    idx = np.arange(n)
    vals = keys.copy()
    if n <= 1:
        return idx, 2  # still needs the two clean phases to flag sorted
    cycles = 0
    clean = 0
    parity = 0
    while clean < 2:
        swapped = False
        start = parity
        for i in range(start, n - 1, 2):
            # descending order: swap if left strictly smaller than right.
            if vals[i] < vals[i + 1]:
                vals[i], vals[i + 1] = vals[i + 1], vals[i]
                idx[i], idx[i + 1] = idx[i + 1], idx[i]
                swapped = True
        cycles += 1
        clean = 0 if swapped else clean + 1
        parity ^= 1
    return idx, cycles


def simulate_mapping_event(avgs: np.ndarray) -> CycleReport:
    """Cycle count for one mapping event over a ready queue of the given keys."""
    n = int(np.asarray(avgs).shape[0])
    order, sort_cycles = oddeven_sort_cycles(np.asarray(avgs))
    fill = n
    drain = n
    select_latency = 1  # LUT-RAM read + PE-handler add + EFT-selector tree
    first_decision = fill + sort_cycles + select_latency
    total = fill + sort_cycles + select_latency + max(drain - 1, 0)
    return CycleReport(
        n=n,
        fill_cycles=fill,
        sort_cycles=sort_cycles,
        first_decision_cycle=first_decision,
        drain_cycles=drain,
        total_cycles=total,
    )


def worst_case_cycles(n: int) -> int:
    """Paper's closed form: 3n + 3 cycles for a ready queue of size n."""
    return 3 * n + 3


def first_decision_worst_case(n: int) -> int:
    """Paper's closed form: first decision after 2n + 3 cycles."""
    return 2 * n + 3


def hw_latency_ns(n: int, critical_path_ns: float, worst_case: bool = True,
                  avgs: np.ndarray | None = None) -> float:
    """Wall-clock scheduling latency of the hardware scheduler.

    With ``worst_case`` (the paper's reporting convention) this is
    ``(3n+3) × critical_path``; otherwise the emulated cycle count for the
    concrete ``avgs`` is used (captures early sort termination).
    """
    if worst_case or avgs is None:
        cycles = worst_case_cycles(n)
    else:
        cycles = simulate_mapping_event(avgs).total_cycles
    return cycles * critical_path_ns


def per_decision_latency_ns(n: int, critical_path_ns: float,
                            asymptotic: bool = False) -> float:
    """Average time per task→PE decision: ((3n+3)/n) × path delay.

    For n→large this tends to 3 cycles × path delay — the paper's reporting
    convention (``asymptotic=True``): 3 × 3.048 ns = 9.144 ns for the
    D=512 / P=4 design.
    """
    cycles = 3.0 if asymptotic else worst_case_cycles(n) / n
    return cycles * critical_path_ns
