"""Serving engine: continuous batching with a HEFT_RT front-end scheduler.

Two layers:

* ``ServeEngine`` — a real decode loop (prefill + batched token-by-token
  decode with KV/state caches) for a single replica.  Used by the examples
  (CPU-scale models) and by launch/serve.py.
* ``HeftFrontEnd`` — maps dynamically arriving requests onto a fleet of
  replicas with HEFT_RT (the paper's scheduler as the admission layer; see
  sched_integration/serve_scheduler.py for the fleet-scale simulation).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heft_rt_numpy
from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill_step


@dataclass
class ServeEngine:
    """Single-replica engine: batched prefill + greedy decode."""

    cfg: ModelConfig
    params: dict
    max_len: int = 256

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, self.cfg))
        self._prefill = jax.jit(
            lambda p, t: prefill_step(p, t, self.cfg, max_len=self.max_len))

    def generate(self, prompts: np.ndarray, new_tokens: int,
                 greedy: bool = True, seed: int = 0):
        """prompts: (B, S0) int32 → (B, S0+new_tokens) generated ids."""
        B, S0 = prompts.shape
        logits, caches = self._prefill(self.params, jnp.asarray(prompts))
        out = [jnp.asarray(prompts)]
        key = jax.random.key(seed)
        tok = None
        for i in range(new_tokens):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(tok[:, None])
            logits, caches = self._decode(self.params, caches, tok[:, None],
                                          jnp.int32(S0 + i))
        return np.asarray(jnp.concatenate(out, axis=1))


@dataclass
class ReplicaHandle:
    name: str
    engine: ServeEngine
    speed: float = 1.0             # relative throughput (heterogeneous fleet)
    avail_at: float = 0.0          # availability-time register (T_avail)
    processed: int = 0


@dataclass
class HeftFrontEnd:
    """HEFT_RT request→replica mapper over live engines.

    Mirrors the paper's runtime loop: each scheduling tick, the ready queue
    of requests is passed with per-replica exec-time estimates and T_avail
    registers to the HEFT_RT scheduler; commitments execute on the engines.

    ``fabric`` selects the mapping-event backend: ``None`` keeps the
    unbatched ``heft_rt_numpy`` oracle; a
    :class:`~repro.sched_integration.fabric.MappingFabric` routes events
    through the bucketed jit/Pallas dispatch pipeline (identical decisions,
    device-resident T_avail registers).
    """

    replicas: list[ReplicaHandle]
    fabric: object | None = None      # MappingFabric, optional

    def estimate_s(self, prompt_len: int, new_tokens: int,
                   replica: ReplicaHandle) -> float:
        base = 1e-4 * prompt_len + 2e-3 * new_tokens   # host-scale estimate
        return base / replica.speed

    def schedule(self, requests: list[tuple[np.ndarray, int]]):
        """requests: [(prompt, new_tokens)] → list of (req_idx, replica_idx)."""
        n, p = len(requests), len(self.replicas)
        ex = np.array([[self.estimate_s(len(pr), nt, r)
                        for r in self.replicas] for pr, nt in requests])
        avg = ex.mean(axis=1)
        avail = np.array([r.avail_at for r in self.replicas])
        if self.fabric is not None:
            order, assignment, start, finish, new_avail = self.fabric.map_event(
                avg, ex, avail, update=False)
        else:
            order, assignment, start, finish, new_avail = heft_rt_numpy(
                avg, ex, avail)
        for i, r in enumerate(self.replicas):
            r.avail_at = float(new_avail[i])
        return [(int(order[i]), int(assignment[i])) for i in range(n)]

    def run_batch(self, requests: list[tuple[np.ndarray, int]]):
        """Schedule + execute, returning (outputs, per-replica counts)."""
        plan = self.schedule(requests)
        outputs: dict[int, np.ndarray] = {}
        for req_idx, rep_idx in plan:
            prompt, new_tokens = requests[req_idx]
            rep = self.replicas[rep_idx]
            t0 = time.perf_counter()
            outputs[req_idx] = rep.engine.generate(prompt[None, :], new_tokens)
            rep.processed += 1
        return [outputs[i] for i in range(len(requests))], \
            {r.name: r.processed for r in self.replicas}
