"""Serving engine: continuous batching with a HEFT_RT front-end scheduler.

Two layers:

* ``ServeEngine`` — a real decode loop (prefill + batched token-by-token
  decode with KV/state caches) for a single replica.  Optionally *mesh-
  backed*: give it a ``repro.dist`` mesh slice and its prefill/decode steps
  jit under the ``replica_pspecs`` layouts (params FSDP+TP, KV heads over
  ``model``, batch replicated) with the activation hint policy installed —
  the replica becomes an actual multi-device substrate instead of an
  abstract speed factor.
* ``HeftFrontEnd`` — maps dynamically arriving requests onto a fleet of
  replicas with HEFT_RT (the paper's scheduler as the admission layer; see
  sched_integration/serve_scheduler.py for the fleet-scale simulation).
  Heterogeneous fleets mix replica mesh shapes (1×1, 2×1, 2×2 slices of one
  device pool — ``repro.launch.mesh.slice_device_pool``); per-replica
  ``Exec_TID`` estimates come from the dry-run cost-model registry when the
  replica's (arch × mesh) cells are covered, host-scale roofline otherwise.

Public contracts:

* **Dense path** (`generate`, `start`/`step`) — per-request decode against a
  dense fixed-shape cache; the *bitwise oracle* every other path is tested
  against.  `reshard(mesh)` migrates a live replica (params + in-flight KV)
  token-identically; `snapshot_caches`/`restore_caches` are the chaos tier's
  kill-and-recover unit.
* **Paged path** (`start_paged` → `admit`/`decode_tick`/`finished_slots`/
  `retire`) — continuous batching through the block-paged KV pool in
  `serve/paging.py`: requests join/leave a running batch without retracing
  (power-of-two lane buckets), admission *reserves every page up front* so
  pool exhaustion refuses admission (``admit() -> None`` — callers queue,
  never drop), and each request's token stream is bit-identical to
  ``generate`` under ANY admission interleaving.
  `snapshot_pages`/`restore_pages` move one in-flight request between
  engines at page granularity.  Design note: docs/serving.md.
* **Front end** — `run_batch` (one HEFT_RT mapping event, whole-batch
  generate per replica) and `run_continuous` (per-tick admission: HEFT_RT
  maps arrivals to sticky per-replica FIFO queues, each tick drains queue
  heads into free paged slots).  Both return outputs in request order.
"""

from __future__ import annotations

import contextlib
import math
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heft_rt_numpy
from repro.dist.hints import sharding_policy
from repro.dist.sharding import MeshAxes, named, replica_pspecs, reshard_tree
from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill_step
from repro.obs.metrics import Stopwatch


def _host_scale_s(prompt_tokens, new_tokens):
    """The abstract-fleet service-time estimate (seconds, elementwise)."""
    return 1e-4 * prompt_tokens + 2e-3 * new_tokens


def _span(tracer, name, **args):
    """Tracer span, or a no-op context when no tracer is attached."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **args)


@dataclass
class ServeEngine:
    """Single-replica engine: batched prefill + greedy decode.

    ``mesh``/``axes`` back the replica with a mesh slice: params are
    device_put to their FSDP+TP layout once, caches live sharded across the
    slice (KV heads over ``model``), and every step traces under
    ``jax.set_mesh`` + the replica's activation ``sharding_policy``.
    """

    cfg: ModelConfig
    params: dict
    max_len: int = 256
    mesh: object | None = None          # jax Mesh slice backing this replica
    axes: MeshAxes | None = None
    fsdp: bool = True
    tracer: object | None = None        # repro.obs.Tracer: step/reshard spans

    def __post_init__(self):
        self._paged = None              # PagedRuntime (start_paged)
        self._build()

    def _build(self):
        """(Re)place params and (re)build the compiled steps for the current
        mesh slice — the shared path of construction and live resharding."""
        if self.mesh is not None:
            ax = self.axes or MeshAxes()
            self.axes = ax
            specs = replica_pspecs(self.cfg, ax, fsdp=self.fsdp)
            p_sh = named(self.mesh, specs["params"])
            c_sh = named(self.mesh, specs["cache"])
            b_sh = named(self.mesh, specs["batch"])
            self._policy = dict(specs["policy"], __mesh__=self.mesh)
            self._cache_sh = c_sh
            with self._ctx():
                self.params = reshard_tree(self.params, p_sh)
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, c, t, pos, self.cfg),
                in_shardings=(p_sh, c_sh, b_sh, None),
                out_shardings=(None, c_sh), donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda p, t: prefill_step(p, t, self.cfg, max_len=self.max_len),
                in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh))
        else:
            self._policy = None
            self._cache_sh = None
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, c, t, pos, self.cfg))
            self._prefill = jax.jit(
                lambda p, t: prefill_step(p, t, self.cfg, max_len=self.max_len))

    def reshard(self, mesh, axes: MeshAxes | None = None, caches=None):
        """Migrate this *live* replica to a new mesh slice, in memory.

        Params (and optionally a caller-held KV/state cache tree from an
        in-flight generation) are re-laid-out under the new slice's
        ``replica_pspecs`` via :func:`repro.dist.sharding.reshard_tree` — no
        checkpoint/disk round-trip — and the prefill/decode executables are
        rebuilt for the new mesh.  ``mesh=None`` migrates back to the
        unmeshed single-device engine.  Generation is bit-identical across
        the migration (the replica_pspecs layouts are value-preserving), so
        a fleet controller can move replicas between slice shapes mid-run
        without perturbing in-flight decodes.

        Returns the migrated cache tree (None when ``caches`` is None).
        """
        with _span(self.tracer, "engine.reshard",
                   to=str(tuple(mesh.devices.shape)) if mesh is not None
                   else "host",
                   with_caches=caches is not None):
            self.mesh = mesh
            if axes is not None:
                self.axes = axes
            if mesh is None:
                # Actually vacate the old slice: params must not stay
                # committed to devices the caller is about to re-carve for
                # other replicas.
                self.params = jax.tree.map(
                    lambda x: jnp.asarray(np.asarray(x)), self.params)
            self._build()
            if self._paged is not None:
                # Paged runtime: the page pool migrates as a unit (pages are
                # the live-migration granule) and the tick recompiles for
                # the new slice; in-flight slots keep decoding.
                self._paged.rebind()
            if caches is not None:
                if self._cache_sh is not None:
                    caches = reshard_tree(caches, self._cache_sh)
                else:
                    caches = jax.tree.map(
                        lambda x: jnp.asarray(np.asarray(x)), caches)
            return caches

    def snapshot_caches(self, caches):
        """Host-side snapshot of an in-flight KV/state cache tree.

        This is the chaos tier's recovery unit: taken at a committed decode
        step (between :meth:`step` calls), the snapshot outlives the
        replica's process — kill the engine mid-generation and
        :meth:`restore_caches` re-materializes the same step onto a spare
        slice, token-identical from the last committed token.
        """
        with _span(self.tracer, "engine.snapshot"):
            return jax.tree.map(lambda x: np.asarray(x), caches)

    def restore_caches(self, caches):
        """Re-materialize a :meth:`snapshot_caches` tree onto this replica's
        slice (its ``replica_pspecs`` cache layout via ``reshard_tree``;
        plain device residency unmeshed)."""
        with self._ctx(), _span(self.tracer, "engine.restore"):
            if self._cache_sh is not None:
                return reshard_tree(caches, self._cache_sh)
            return jax.tree.map(jnp.asarray, caches)

    @property
    def mesh_shape(self) -> tuple[int, ...] | None:
        return tuple(self.mesh.devices.shape) if self.mesh is not None else None

    def _ctx(self):
        """Mesh + hint-policy context for traces/transfers (identity unmeshed)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        ctx = contextlib.ExitStack()
        ctx.enter_context(jax.set_mesh(self.mesh))
        ctx.enter_context(sharding_policy(self._policy))
        return ctx

    def start(self, prompts: np.ndarray):
        """Prefill: (B, S0) prompts → (logits, caches).

        With :meth:`step`, the resumable half of :meth:`generate` — a caller
        can pause decoding, migrate the caches through :meth:`reshard`, and
        resume on the new mesh slice.
        """
        with self._ctx(), _span(self.tracer, "engine.prefill",
                                B=int(prompts.shape[0]),
                                S0=int(prompts.shape[1])):
            return self._prefill(self.params, jnp.asarray(prompts))

    def step(self, caches, tok, pos: int):
        """One decode step: (caches, (B, 1) tokens, position) → (logits,
        caches).  The cache tree is donated (pass the latest one)."""
        with self._ctx(), _span(self.tracer, "engine.decode_step", pos=pos):
            return self._decode(self.params, caches, jnp.asarray(tok),
                                jnp.int32(pos))

    def generate(self, prompts: np.ndarray, new_tokens: int,
                 greedy: bool = True, seed: int = 0):
        """prompts: (B, S0) int32 → (B, S0+new_tokens) generated ids."""
        B, S0 = prompts.shape
        tr = self.tracer
        with self._ctx():
            t0 = time.perf_counter()
            logits, caches = self._prefill(self.params, jnp.asarray(prompts))
            if tr is not None:
                tr.complete("engine.prefill", t0, time.perf_counter() - t0,
                            B=B, S0=S0)
            out = [jnp.asarray(prompts)]
            key = jax.random.key(seed)
            tok = None
            for i in range(new_tokens):
                if greedy:
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(sub, logits).astype(jnp.int32)
                out.append(tok[:, None])
                t0 = time.perf_counter()
                logits, caches = self._decode(self.params, caches, tok[:, None],
                                              jnp.int32(S0 + i))
                if tr is not None:
                    tr.complete("engine.decode_step", t0,
                                time.perf_counter() - t0, pos=S0 + i)
            return np.asarray(jnp.concatenate(out, axis=1))

    # -- continuous batching (block-paged KV pool; see serve/paging.py) -----

    def start_paged(self, *, max_batch: int = 8, page_size: int = 16,
                    num_pages: int | None = None):
        """Switch this replica to the in-flight decode API.

        Builds the device-resident page pool (``num_pages`` defaults to full
        occupancy ``max_batch * max_len/page_size``; set it lower to
        exercise admission-gating exhaustion) and the compiled
        gather→decode→scatter tick.  After this, drive the engine with
        :meth:`admit` / :meth:`decode_tick` / :meth:`retire`; the dense
        :meth:`generate` path stays available and is the bitwise oracle the
        paged path is tested against.  Returns the
        :class:`~repro.serve.paging.PagedRuntime` (also kept on the engine).
        """
        from repro.serve.paging import PagedRuntime

        self._paged = PagedRuntime(self, max_batch, page_size,
                                   num_pages=num_pages)
        return self._paged

    @property
    def paged(self):
        """The active PagedRuntime, or None before :meth:`start_paged`."""
        return self._paged

    def _require_paged(self):
        if self._paged is None:
            raise RuntimeError("call start_paged() before the in-flight API")
        return self._paged

    def admit(self, prompt: np.ndarray, new_tokens: int) -> int | None:
        """Prefill + join the running batch without stopping it.

        Reserves the request's full page budget up front; returns the slot
        id, or ``None`` when the pool lacks a slot/pages — callers queue
        rejected requests (the contract is queue-never-drop; see
        ``HeftFrontEnd.run_continuous``).
        """
        rt = self._require_paged()
        with _span(self.tracer, "engine.admit",
                   S0=int(np.asarray(prompt).size), new_tokens=new_tokens):
            return rt.admit(prompt, new_tokens)

    def decode_tick(self, sched=None):
        """One decode step for every in-flight slot → {slot: new token}.

        ``sched`` (optional): a staged HEFT_RT mapping event ``(avg,
        exec_times, fabric)`` for a fused-backend
        :class:`~repro.sched_integration.fabric.MappingFabric` — the
        decision runs *inside* the tick's compiled program against the
        fabric's device-resident registers, and the call returns
        ``(tokens, decision)`` instead (see ``PagedRuntime.decode_tick``
        and docs/scheduling.md)."""
        rt = self._require_paged()
        with _span(self.tracer, "engine.decode_tick",
                   active=len(rt.active_slots()), fused=sched is not None):
            return rt.decode_tick(sched)

    def finished_slots(self) -> list[int]:
        """Slots whose generation completed and await :meth:`retire`."""
        return self._require_paged().finished_slots()

    def retire(self, slot: int) -> np.ndarray:
        """Free a finished slot's pages; returns its (S0+new_tokens,) ids."""
        return self._require_paged().retire(slot)

    def free_pages(self) -> int:
        """Pages currently available for admission."""
        return self._require_paged().pool.free_pages

    def snapshot_pages(self, slot: int) -> dict:
        """Page-granular snapshot of ONE in-flight request (the continuous-
        batching analogue of :meth:`snapshot_caches`: O(request), not
        O(pool)).  Restore with :meth:`restore_pages` on any paged engine."""
        with _span(self.tracer, "engine.snapshot_pages", slot=slot):
            return self._require_paged().snapshot_slot(slot)

    def restore_pages(self, snap: dict) -> int | None:
        """Re-admit a :meth:`snapshot_pages` request here; decoding resumes
        token-identically.  None when the pool is currently full."""
        with _span(self.tracer, "engine.restore_pages"):
            return self._require_paged().restore_slot(snap)


@dataclass
class ReplicaHandle:
    """One fleet slot: an engine plus its scheduling identity.

    ``speed`` scales the host-scale fallback estimate (legacy abstract
    fleets).  Mesh-backed replicas instead carry the cost-model key
    (``arch`` + ``mesh_shape``, auto-filled from the engine's mesh) and
    aggregate hardware rates, so the front-end's Exec_TID column can come
    from dry-run cost cells.
    """

    name: str
    engine: ServeEngine
    speed: float = 1.0             # relative throughput (heterogeneous fleet)
    avail_at: float = 0.0          # availability-time register (T_avail)
    processed: int = 0
    arch: str | None = None              # cost-model key
    mesh_shape: tuple[int, ...] | None = None
    compute_tflops: float | None = None  # aggregate effective rates
    hbm_gbps: float | None = None
    ici_gbps: float = 0.0

    def __post_init__(self):
        if self.mesh_shape is None:
            self.mesh_shape = self.engine.mesh_shape

    def sync_mesh_identity(self) -> None:
        """Re-derive the scheduling identity after ``engine.reshard``.

        The cost-model key follows the engine's new slice, and ``speed`` /
        aggregate rates rescale with the device count — without this, the
        front end keeps scheduling the migrated replica with the *old*
        slice's Exec_TID column.
        """
        old_n = math.prod(self.mesh_shape) if self.mesh_shape else 1
        self.mesh_shape = self.engine.mesh_shape
        new_n = math.prod(self.mesh_shape) if self.mesh_shape else 1
        if new_n != old_n:
            scale = new_n / old_n
            self.speed *= scale
            if self.compute_tflops:
                self.compute_tflops *= scale
            if self.hbm_gbps:
                self.hbm_gbps *= scale


@dataclass
class HeftFrontEnd:
    """HEFT_RT request→replica mapper over live engines.

    Mirrors the paper's runtime loop: each scheduling tick, the ready queue
    of requests is passed with per-replica exec-time estimates and T_avail
    registers to the HEFT_RT scheduler; commitments execute on the engines.

    ``fabric`` selects the mapping-event backend: ``None`` keeps the
    unbatched ``heft_rt_numpy`` oracle; a
    :class:`~repro.sched_integration.fabric.MappingFabric` routes events
    through the bucketed jit/Pallas dispatch pipeline (identical decisions,
    device-resident T_avail registers).

    ``cost_registry`` (a
    :class:`~repro.sched_integration.cost_model.CostModelRegistry`) supplies
    dry-run-derived Exec_TID columns for replicas whose (arch × mesh) cells
    it covers; uncovered replicas keep the host-scale estimate.
    """

    replicas: list[ReplicaHandle]
    fabric: object | None = None      # MappingFabric, optional
    cost_registry: object | None = None
    tracer: object | None = None      # repro.obs.Tracer: decision spans
    metrics: object | None = None     # repro.obs.MetricsRegistry
    unreachable: set = field(default_factory=set)   # chaos partition mask

    # -- dynamic handle registry (elastic fleet) ----------------------------

    def add_replica(self, handle: ReplicaHandle) -> None:
        """Join a replica mid-run.  With a fabric attached, the PE pool grows
        in place so the compiled dispatch keeps matching the fleet width.
        The resident registers are seeded at the joiner's ``avail_at`` for
        resident-register consumers; ``schedule()`` itself passes the
        handles' availability explicitly every event."""
        self.replicas.append(handle)
        if self.fabric is not None:
            self.fabric.grow(len(self.replicas), avail=handle.avail_at)
        self._sync_mask()

    def remove_replica(self, name: str) -> ReplicaHandle:
        """Retire a replica by name (in-flight work finishes; no new
        assignments).  The fabric shrinks keeping the survivors' registers."""
        idx = next((i for i, r in enumerate(self.replicas) if r.name == name),
                   None)
        if idx is None:
            raise KeyError(f"no replica named {name!r} in "
                           f"{[r.name for r in self.replicas]}")
        handle = self.replicas.pop(idx)
        if self.fabric is not None:
            self.fabric.shrink([i for i in range(len(self.replicas) + 1)
                                if i != idx])
        self.unreachable.discard(name)
        self._sync_mask()
        return handle

    def set_unreachable(self, names) -> None:
        """Chaos-tier partition mask: replicas in ``names`` stop receiving
        *new* work (their Exec_TID columns dispatch as ``+inf``, and an
        attached fabric's PE mask follows) while in-flight generations and
        committed ``T_avail`` registers stay intact for recovery.  Pass an
        empty iterable to clear.  Names not in the roster are ignored —
        a partition can outlive the replicas behind it."""
        self.unreachable = set(names)
        self._sync_mask()

    def _sync_mask(self) -> None:
        # Fabric resizes clear the lane mask (indices change meaning), so
        # every roster/mask change re-derives it from replica names.
        if self.fabric is None:
            return
        mask = np.array([r.name in self.unreachable for r in self.replicas],
                        dtype=bool)
        self.fabric.set_pe_mask(mask if mask.any() else None)

    def estimate_s(self, prompt_len: int, new_tokens: int,
                   replica: ReplicaHandle) -> float:
        return _host_scale_s(prompt_len, new_tokens) / replica.speed

    def exec_estimates(self, requests: list[tuple[np.ndarray, int]]
                       ) -> np.ndarray:
        """(n, P) Exec_TID matrix: cost-model columns where the registry
        covers a replica, host-scale roofline fallback elsewhere."""
        pf = np.array([len(pr) for pr, _ in requests], dtype=np.float64)
        dc = np.array([nt for _, nt in requests], dtype=np.float64)
        cols = []
        for r in self.replicas:
            if r.name in self.unreachable:
                cols.append(np.full(len(requests), np.inf))
                continue
            col = (self.cost_registry.column_s(r, pf, dc)
                   if self.cost_registry is not None else None)
            if col is None:
                col = _host_scale_s(pf, dc) / r.speed
            cols.append(col)
        return np.stack(cols, axis=1)

    def schedule(self, requests: list[tuple[np.ndarray, int]]):
        """requests: [(prompt, new_tokens)] → list of (req_idx, replica_idx)."""
        n, p = len(requests), len(self.replicas)
        if self.tracer is not None:
            self.tracer.counter("frontend.queue_depth", depth=n)
        t0 = time.perf_counter()
        ex = self.exec_estimates(requests)
        avg = ex.mean(axis=1)
        avail = np.array([r.avail_at for r in self.replicas])
        if self.fabric is not None:
            order, assignment, start, finish, new_avail = self.fabric.map_event(
                avg, ex, avail, update=False)
        else:
            order, assignment, start, finish, new_avail = heft_rt_numpy(
                avg, ex, avail)
        dt = time.perf_counter() - t0
        if self.tracer is not None:
            self.tracer.complete("frontend.schedule", t0, dt, n=n, p=p)
        if self.metrics is not None:
            # Per-decision scheduler latency: one measured batched event
            # amortized over its n decisions (weight n keeps counts honest).
            self.metrics.histogram("frontend.decision_s").record(
                dt / max(n, 1), n=max(n, 1))
        # One host materialization for the whole register file, not one
        # blocking float() per replica (host-sync-in-hot-path design rule).
        new_avail = np.asarray(new_avail)
        for i, r in enumerate(self.replicas):
            r.avail_at = float(new_avail[i])
        return [(int(order[i]), int(assignment[i])) for i in range(n)]

    # -- fused-scheduler helpers (docs/scheduling.md) -----------------------

    def _fused_enabled(self, fused: bool | None) -> bool:
        """Resolve ``run_continuous``'s ``fused`` knob: None follows the
        attached fabric's backend; True demands a fused-backend fabric."""
        is_fused = (self.fabric is not None
                    and getattr(self.fabric, "backend", None) == "fused")
        if fused is None:
            return is_fused
        if fused and not is_fused:
            raise ValueError(
                "fused=True requires a MappingFabric(backend='fused') "
                f"front-end fabric, got "
                f"{getattr(self.fabric, 'backend', None)!r}")
        return bool(fused)

    def _stage_event(self, requests: list[tuple[np.ndarray, int]]):
        """(avg, exec_times) for one mapping event — the operand half of
        :meth:`schedule`, reused by the fused tick path."""
        ex = self.exec_estimates(requests)
        return ex.mean(axis=1), ex

    def _adopt_decision(self, n: int, decision):
        """Turn a mapping-event 5-tuple into a plan, mirroring the fabric's
        resident ``new_avail`` registers into the replica handles (the
        fused-path twin of :meth:`schedule`'s bookkeeping)."""
        order, assignment, _, _, new_avail = decision
        new_avail = np.asarray(new_avail)
        for i, r in enumerate(self.replicas):
            r.avail_at = float(new_avail[i])
        if self.tracer is not None:
            self.tracer.counter("frontend.queue_depth", depth=n)
        return [(int(order[i]), int(assignment[i])) for i in range(n)]

    def run_batch(self, requests: list[tuple[np.ndarray, int]]):
        """Schedule + execute, returning (outputs, per-replica counts)."""
        plan = self.schedule(requests)
        outputs: dict[int, np.ndarray] = {}
        gen_hist = (self.metrics.histogram("engine.generate_s")
                    if self.metrics is not None else None)
        for req_idx, rep_idx in plan:
            prompt, new_tokens = requests[req_idx]
            rep = self.replicas[rep_idx]
            with Stopwatch(gen_hist) as sw:
                outputs[req_idx] = rep.engine.generate(prompt[None, :],
                                                       new_tokens)
            if self.tracer is not None:
                self.tracer.complete("frontend.generate", sw.start_s,
                                     sw.elapsed_s, replica=rep.name,
                                     new_tokens=new_tokens)
            rep.processed += 1
        return [outputs[i] for i in range(len(requests))], \
            {r.name: r.processed for r in self.replicas}

    def run_continuous(self, requests: list[tuple[np.ndarray, int]], *,
                       arrival_ticks: list[int] | None = None,
                       max_batch: int = 8, page_size: int = 16,
                       num_pages: int | None = None,
                       fused: bool | None = None):
        """Continuous batching: the admission tick the paper's scheduler
        needs to pay off on dynamic arrivals.

        Each tick, requests that have arrived are mapped to replicas with
        HEFT_RT (:meth:`schedule` — one sticky decision per request), each
        replica drains its mapped queue head-first into free batch slots
        (``admit``; a refusal re-queues, FIFO order preserved — pool
        exhaustion *queues*, never drops), then every replica runs one
        ``decode_tick`` and retires finished slots.  Requests join and leave
        the running batch without stopping it, and each request's tokens are
        bit-identical to ``engine.generate`` run alone — under any
        interleaving (the paged-oracle contract, property-tested).

        ``arrival_ticks[i]`` (default all 0) is the decode tick at which
        request ``i`` becomes visible — the open-loop workload hook the
        paged-serve benchmark drives.

        ``fused`` selects the zero-host-round-trip scheduling fast path
        (default: on exactly when the attached fabric is
        ``backend="fused"``): arrivals' HEFT_RT decisions run *inside* a
        replica's decode-tick program against the fabric's device-resident
        registers, riding the token transfer the tick already makes
        (docs/scheduling.md).  Mapped requests join their queues one tick
        later than the host path — a pipeline delay, not a drop; when no
        replica has active slots to ride (cold start, idle fleet) the
        decision takes the host path against the same resident registers.
        Token streams stay bit-identical to ``generate`` either way.

        Returns ``(outputs, stats)``: outputs in request order, and stats
        with ``ticks``, per-replica ``processed``, the pools' cumulative
        ``allocated`` / ``freed`` page counters (equal at drain), and the
        ``fused_decisions`` / ``host_decisions`` split.
        """
        arrivals = arrival_ticks or [0] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrival_ticks must match requests")
        fused = self._fused_enabled(fused)
        fused_decisions = host_decisions = 0
        if fused:
            # The fabric's register file becomes the source of truth for
            # T_avail during the run; seed it from the handles once, then
            # every decision (fused tick or idle-time host fallback) updates
            # the resident registers and mirrors them back.
            self.fabric.reset(np.array([r.avail_at for r in self.replicas],
                                       dtype=np.float64))
        for r in self.replicas:
            if r.engine.paged is None:
                r.engine.start_paged(max_batch=max_batch,
                                     page_size=page_size,
                                     num_pages=num_pages)
            pool = r.engine.paged.pool
            for prompt, nt in requests:
                need = pool.pages_needed(len(prompt) + nt)
                if need > pool.num_pages:
                    raise ValueError(
                        f"request needs {need} pages but the pool holds "
                        f"{pool.num_pages} — it could never be admitted")
        order = sorted(range(len(requests)), key=lambda i: (arrivals[i], i))
        queues: list[list[int]] = [[] for _ in self.replicas]   # req idx FIFO
        slot_of: dict[tuple[int, int], int] = {}    # (rep, slot) → req idx
        outputs: dict[int, np.ndarray] = {}
        pending: list[int] = []     # fused path: arrived, not yet mapped
        tick = 0
        next_arrival = 0
        while len(outputs) < len(requests):
            # 1. HEFT_RT-map the newly arrived requests (sticky decisions).
            batch = []
            while (next_arrival < len(order)
                   and arrivals[order[next_arrival]] <= tick):
                batch.append(order[next_arrival])
                next_arrival += 1
            carrier = None
            if not fused:
                if batch:
                    plan = self.schedule([requests[i] for i in batch])
                    for req_i, rep_i in plan:
                        queues[rep_i].append(batch[req_i])
            else:
                pending.extend(batch)
                if pending:
                    # The decision rides the first replica that will run a
                    # decode tick this round; with nothing in flight there
                    # is no tick to ride — take the host path now (against
                    # the same resident registers) so this tick admits.
                    carrier = next(
                        (i for i, r in enumerate(self.replicas)
                         if r.engine.paged is not None
                         and r.engine.paged.active_slots()), None)
                    if carrier is None:
                        avg, ex = self._stage_event(
                            [requests[i] for i in pending])
                        decision = self.fabric.map_event(avg, ex)
                        plan = self._adopt_decision(len(pending), decision)
                        host_decisions += len(pending)
                        for req_i, rep_i in plan:
                            queues[rep_i].append(pending[req_i])
                        pending = []
            # 2. Admission tick: drain each mapped queue into free slots.
            for rep_i, r in enumerate(self.replicas):
                while queues[rep_i]:
                    idx = queues[rep_i][0]
                    prompt, nt = requests[idx]
                    slot = r.engine.admit(prompt, nt)
                    if slot is None:       # exhausted: stays queued (FIFO)
                        break
                    queues[rep_i].pop(0)
                    slot_of[(rep_i, slot)] = idx
            # 3. Decode tick + retire finished slots.  On the fused path the
            # carrier's tick also computes the pending arrivals' mapping
            # inside its compiled program; the mapped requests reach their
            # queues for the NEXT admission tick (a one-tick pipeline
            # delay — the steady-state cost of zero host round-trips).
            for rep_i, r in enumerate(self.replicas):
                if fused and pending and rep_i == carrier:
                    avg, ex = self._stage_event(
                        [requests[i] for i in pending])
                    _, decision = r.engine.decode_tick((avg, ex, self.fabric))
                    plan = self._adopt_decision(len(pending), decision)
                    fused_decisions += len(pending)
                    for req_i, rep_to in plan:
                        queues[rep_to].append(pending[req_i])
                    pending = []
                else:
                    r.engine.decode_tick()
                for slot in r.engine.finished_slots():
                    idx = slot_of.pop((rep_i, slot))
                    outputs[idx] = r.engine.retire(slot)
                    r.processed += 1
            tick += 1
        stats = {
            "ticks": tick,
            "processed": {r.name: r.processed for r in self.replicas},
            "allocated": sum(r.engine.paged.pool.allocated
                             for r in self.replicas),
            "freed": sum(r.engine.paged.pool.freed for r in self.replicas),
            "fused_decisions": fused_decisions,
            "host_decisions": host_decisions,
        }
        return [outputs[i] for i in range(len(requests))], stats


def mesh_backed_fleet(cfg: ModelConfig, params: dict, mesh_shapes,
                      *, max_len: int = 128, arch: str | None = None,
                      axes: MeshAxes | None = None, devices=None,
                      chip_tflops: float = 1.0, chip_hbm_gbps: float = 1.0,
                      ici_gbps: float = 0.0, return_spare: bool = False):
    """Carve the device pool into mesh slices and build one engine each.

    The heterogeneous serve fleet in one call: ``mesh_shapes`` like
    ``[(1, 1), (2, 1), (2, 2)]`` produce replicas of mixed parallelism whose
    aggregate rates (and HEFT_RT speed fallback) scale with slice size.
    ``return_spare=True`` additionally returns the pool's uncarved devices
    (``slice_device_pool``'s remainder) — the spare budget elastic resize
    events re-carve later.
    """
    from repro.launch.mesh import slice_device_pool

    ax = axes or MeshAxes()
    meshes, spare = slice_device_pool(mesh_shapes, (ax.data, ax.model),
                                      devices=devices, return_remainder=True)
    fleet = []
    for i, mesh in enumerate(meshes):
        shape = tuple(mesh.devices.shape)
        n = math.prod(shape)
        eng = ServeEngine(cfg, params, max_len=max_len, mesh=mesh, axes=ax)
        fleet.append(ReplicaHandle(
            f"{cfg.name}@{'x'.join(map(str, shape))}#{i}", eng,
            speed=float(n), arch=arch or cfg.name,
            compute_tflops=n * chip_tflops, hbm_gbps=n * chip_hbm_gbps,
            ici_gbps=ici_gbps))
    if return_spare:
        return fleet, spare
    return fleet
