from repro.serve.engine import (
    HeftFrontEnd,
    ReplicaHandle,
    ServeEngine,
    mesh_backed_fleet,
)

__all__ = ["HeftFrontEnd", "ReplicaHandle", "ServeEngine", "mesh_backed_fleet"]
