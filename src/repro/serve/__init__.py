from repro.serve.engine import HeftFrontEnd, ReplicaHandle, ServeEngine

__all__ = ["HeftFrontEnd", "ReplicaHandle", "ServeEngine"]
