from repro.serve.engine import (
    HeftFrontEnd,
    ReplicaHandle,
    ServeEngine,
    mesh_backed_fleet,
)
from repro.serve.paging import PagePool, PagedRuntime

__all__ = ["HeftFrontEnd", "PagePool", "PagedRuntime", "ReplicaHandle",
           "ServeEngine", "mesh_backed_fleet"]
