"""Block-paged KV cache pool for continuous batching (see docs/serving.md).

The dense ``ServeEngine`` path allocates one ``(B, Smax, ...)`` cache tree
per generation, so a new request can only start when a whole generation
ends.  This module stores the caches of *all* in-flight requests in one
device-resident pool of fixed-size pages and lets requests join and leave
the running batch between decode steps — the admission path the paper's
HEFT_RT scheduler needs to pay off on dynamic arrivals.

Layout
------
Per paged cache leaf (names ``k``/``v``/``ckv``/``kr`` — the same name-based
classification ``dist.sharding._cache_rule`` uses), the dense leaf's batch
axis becomes ``num_pages + 1`` and its ``Smax`` axis becomes ``page_size``:

    dense  (B, Smax, KV, hd)   →  pool (num_pages + 1, page_size, KV, hd)

The final page (index ``num_pages``) is the *scratch page*: padded batch
lanes and unreserved page-table tail entries point at it, so every tick runs
with fully static shapes and stray writes land somewhere harmless.  State
leaves (``conv``/``ssm`` — no sequence axis) live in a parallel *state pool*
with ``max_batch + 1`` slots, the last being the scratch state slot.  Leaves
stacked under ``stages`` keep their leading ``num_stages`` axis.  Pool
leaves therefore have the same rank as their dense counterparts, which is
why ``dist.sharding.page_pspecs`` can reuse the cache sharding rule
structurally (page dim replicated like batch, ``page_size`` like ``Smax``).

A per-slot page table (``max_batch + 1`` rows × ``pages_per_slot`` int32
page ids; row ``max_batch`` is all-scratch) maps each sequence onto its
pages.  All pages a request will ever need are reserved at admission
(``ceil((S0 + new_tokens) / page_size)``), so decode can never run out of
pages mid-flight: exhaustion only gates *admission*, and callers queue —
never drop — rejected requests.

Decode tick
-----------
Each tick gathers the active slots' pages into a dense-shaped
``(B, Smax, ...)`` view, runs the standard ``decode_step`` with a per-row
position vector, and scatters only the newly written token back to its
page.  Rows are independent in every einsum/softmax of the model, stale
garbage beyond a row's position is masked to ``-inf`` before softmax (pool
values are always finite), and RoPE sees the same per-row positions — so
each request's tokens are **bit-identical** to the dense single-request
oracle (``ServeEngine.generate``), under any admission interleaving.  The
active-lane count is padded to a power-of-two bucket (same idiom as
``MappingFabric``; ``sched_integration.fabric.pow2_bucket``), so joins and
leaves retrace at most ``log2(max_batch) + 1`` decode variants.

Pages are also the migration and recovery unit: :meth:`PagedRuntime
.snapshot_slot` captures one request's page set (plus its host-side decode
state) as numpy, and :meth:`PagedRuntime.restore_slot` re-admits it on any
engine with free capacity — the continuous-batching analogue of the chaos
tier's whole-cache snapshot/restore.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import tree_map_with_path

from repro.kernels.fused_decision import decision_ref, pack_tick_outputs
from repro.obs.device import accumulate_counters
from repro.sched_integration.fabric import pow2_bucket

# Leaf classification by name — the same convention _cache_rule uses.
PAGED_LEAVES = frozenset({"k", "v", "ckv", "kr"})
STATE_LEAVES = frozenset({"conv", "ssm"})


def _leaf_kind(path) -> tuple[bool, bool]:
    """(is_paged, is_stacked) for one cache-tree leaf path."""
    keys = []
    for k in path:
        keys.append(str(getattr(k, "key", getattr(k, "idx", k))))
    name = keys[-1]
    if name in PAGED_LEAVES:
        return True, "stages" in keys
    if name in STATE_LEAVES:
        return False, "stages" in keys
    raise ValueError(f"unknown cache leaf {'/'.join(keys)!r}")


@dataclass
class _Slot:
    """Host-side decode state of one in-flight request."""

    prompt: np.ndarray            # (S0,) int32
    new_tokens: int
    pages: list[int]              # reserved page ids (freed at retire)
    tokens: list[int] = field(default_factory=list)   # generated so far

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.new_tokens

    @property
    def write_pos(self) -> int:
        """Cache position the *next* decode tick writes this slot's current
        token at (= S0 + steps already decoded)."""
        return len(self.prompt) + len(self.tokens) - 1


class PagePool:
    """Device-resident page pool + host-side page table and free lists.

    Pure allocation bookkeeping — no model math.  ``num_pages`` defaults to
    full occupancy (``max_batch * pages_per_slot``); configure it lower to
    exercise exhaustion (admission then queues).  The ``allocated`` /
    ``freed`` counters are cumulative page counts; at drain (no slots in
    flight) they must match — the invariant tests assert.
    """

    def __init__(self, cfg, max_batch: int, page_size: int, max_len: int,
                 num_pages: int | None = None):
        if max_len % page_size != 0:
            raise ValueError(
                f"max_len={max_len} must be a multiple of page_size={page_size}")
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.pages_per_slot = max_len // page_size
        self.num_pages = int(num_pages if num_pages is not None
                             else max_batch * self.pages_per_slot)
        if self.num_pages < self.pages_per_slot:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold even one full "
                f"sequence ({self.pages_per_slot} pages)")
        self.scratch_page = self.num_pages          # index of the scratch page
        self.scratch_slot = self.max_batch          # index of the scratch row
        # Page table: scratch row at the end stays all-scratch forever.
        self.table = np.full((self.max_batch + 1, self.pages_per_slot),
                             self.scratch_page, dtype=np.int32)
        self.free_page_ids: deque[int] = deque(range(self.num_pages))
        self.free_slot_ids: deque[int] = deque(range(self.max_batch))
        self.allocated = 0
        self.freed = 0
        self.pools = self._init_pools()

    def _init_pools(self):
        """Zero pool tree mirroring ``model.cache_specs`` leaf-for-leaf."""
        from repro.models.model import cache_specs

        specs = cache_specs(self.cfg, 1, self.max_len)

        def pool_spec(path, leaf):
            paged, stacked = _leaf_kind(path)
            shape = list(leaf.shape)
            b_ax, s_ax = (1, 2) if stacked else (0, 1)
            if paged:
                shape[b_ax] = self.num_pages + 1
                shape[s_ax] = self.page_size
            else:
                shape[b_ax] = self.max_batch + 1
            return jnp.zeros(tuple(shape), leaf.dtype)

        return tree_map_with_path(pool_spec, specs)

    # -- allocation ---------------------------------------------------------

    def pages_needed(self, total_len: int) -> int:
        return math.ceil(total_len / self.page_size)

    def can_admit(self, total_len: int) -> bool:
        return (len(self.free_slot_ids) > 0
                and len(self.free_page_ids) >= self.pages_needed(total_len))

    def reserve(self, total_len: int) -> tuple[int, list[int]]:
        """Claim a slot and ALL pages ``total_len`` will need.  Caller must
        check :meth:`can_admit` first; raises RuntimeError otherwise."""
        n = self.pages_needed(total_len)
        if not self.can_admit(total_len):
            raise RuntimeError(
                f"pool exhausted: need {n} pages / 1 slot, have "
                f"{len(self.free_page_ids)} pages / "
                f"{len(self.free_slot_ids)} slots")
        slot = self.free_slot_ids.popleft()
        pages = [self.free_page_ids.popleft() for _ in range(n)]
        self.allocated += n
        row = np.full(self.pages_per_slot, self.scratch_page, dtype=np.int32)
        row[:n] = pages
        self.table[slot] = row
        return slot, pages

    def release(self, slot: int, pages: list[int]) -> None:
        self.table[slot] = self.scratch_page
        self.free_page_ids.extend(pages)
        self.free_slot_ids.append(slot)
        self.freed += len(pages)

    @property
    def free_pages(self) -> int:
        return len(self.free_page_ids)

    @property
    def free_slots(self) -> int:
        return len(self.free_slot_ids)


class PagedRuntime:
    """Continuous-batching decode runtime bound to one ``ServeEngine``.

    Built by :meth:`ServeEngine.start_paged`; the engine's ``admit`` /
    ``decode_tick`` / ``retire`` / ``free_pages`` delegate here.  Holds the
    :class:`PagePool`, the per-slot host decode state, and the compiled
    gather→decode→scatter tick (one variant per power-of-two lane bucket).
    Decode is greedy (the bitwise-oracle contract is argmax-per-row).
    """

    def __init__(self, engine, max_batch: int, page_size: int,
                 num_pages: int | None = None):
        self.engine = engine
        self.pool = PagePool(engine.cfg, max_batch, page_size, engine.max_len,
                             num_pages=num_pages)
        self.slots: dict[int, _Slot] = {}
        self._bind()

    # -- compiled steps (rebuilt on reshard) --------------------------------

    def _bind(self) -> None:
        """(Re)build the jitted tick/admit-scatter for the engine's current
        mesh slice.  Mirrors ``ServeEngine._build``: pool leaves take the
        ``page_pspecs`` layouts, everything else replicates."""
        from repro.dist.sharding import (named, page_pspecs, replica_pspecs,
                                         reshard_tree)
        from repro.models.model import decode_step

        eng = self.engine
        cfg = eng.cfg
        pp, ps = self.pool.pages_per_slot, self.pool.page_size
        scratch_page = self.pool.scratch_page

        def gather(pools, table, slot_ids):
            """pools + (B, pp) table + (B,) slot ids → dense (B, Smax, ...)
            cache view."""
            B = table.shape[0]

            def g(path, pool):
                paged, stacked = _leaf_kind(path)
                if paged:
                    if stacked:
                        v = pool[:, table]          # (L, B, pp, ps, ...)
                        return v.reshape(v.shape[0], B, pp * ps,
                                         *v.shape[4:])
                    v = pool[table]                 # (B, pp, ps, ...)
                    return v.reshape(B, pp * ps, *v.shape[3:])
                return pool[:, slot_ids] if stacked else pool[slot_ids]

            return tree_map_with_path(g, pools)

        def scatter_token(pools, new_caches, table, slot_ids, pos):
            """Write back only what the tick changed: the one token each lane
            wrote at ``pos`` (paged leaves) and the rolled state rows."""
            B = table.shape[0]
            rows = jnp.arange(B)
            page = table[rows, pos // ps]           # (B,) target page ids
            off = pos % ps

            def s(path, pool, new):
                paged, stacked = _leaf_kind(path)
                if paged:
                    if stacked:
                        return pool.at[:, page, off].set(new[:, rows, pos])
                    return pool.at[page, off].set(new[rows, pos])
                if stacked:
                    return pool.at[:, slot_ids].set(new)
                return pool.at[slot_ids].set(new)

            return tree_map_with_path(s, pools, new_caches)

        def tick(params, pools, table, slot_ids, pos, tok):
            dense = gather(pools, table, slot_ids)
            logits, new_caches = decode_step(params, dense, tok, pos, cfg)
            pools = scatter_token(pools, new_caches, table, slot_ids, pos)
            # Greedy selection INSIDE the jitted program: the host only ever
            # transfers the (B,) winning tokens, never the (B, V) logits —
            # same argmax the dense oracle computes, one op earlier
            # (host-sync-in-hot-path design rule; see repro.analysis).
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), pools

        # The fused-scheduler tick: the HEFT_RT decision for the next
        # admission batch runs INSIDE the same compiled program as the
        # decode step, against the fabric's device-resident T_avail/mask
        # registers (docs/scheduling.md).  Decode math is byte-for-byte the
        # plain tick's; the decision outputs ride the token transfer the
        # tick already makes, so steady-state serving schedules with zero
        # extra host round-trips.
        def tick_sched(params, pools, table, slot_ids, pos, tok,
                       a_p, ex_p, valid, avail, mask):
            toks, pools = tick(params, pools, table, slot_ids, pos, tok)
            res = decision_ref(a_p, ex_p, avail, valid, mask)
            # Tokens + decision leave the device as ONE packed int32 buffer
            # (see pack_tick_outputs): per-output host syncs would cost more
            # than the decision itself.  new_avail additionally rides out as
            # the live register (donated buffer), never materialized.
            return pack_tick_outputs(toks, res), pools, res.new_avail

        def tick_sched_counted(params, pools, table, slot_ids, pos, tok,
                               a_p, ex_p, valid, avail, mask, counters,
                               p_valid):
            toks, pools = tick(params, pools, table, slot_ids, pos, tok)
            res = decision_ref(a_p, ex_p, avail, valid, mask)
            counters = accumulate_counters(counters, res.assignment,
                                           res.new_avail, valid, p_valid)
            return pack_tick_outputs(toks, res), pools, res.new_avail, counters

        def admit_scatter(pools, dense, table_row, slot):
            """Place one request's freshly prefilled (B=1) dense cache into
            its reserved pages / state slot.  Tail table entries are the
            scratch page, so over-length writes land there harmlessly."""

            def s(path, pool, d):
                paged, stacked = _leaf_kind(path)
                if paged:
                    if stacked:
                        v = d[:, 0].reshape(d.shape[0], pp, ps, *d.shape[3:])
                        return pool.at[:, table_row].set(v)
                    v = d[0].reshape(pp, ps, *d.shape[2:])
                    return pool.at[table_row].set(v)
                if stacked:
                    return pool.at[:, slot].set(d[:, 0])
                return pool.at[slot].set(d[0])

            return tree_map_with_path(s, pools, dense)

        def restore_scatter(pools, vals, table_row, slot):
            """Place a snapshotted page set (already page-shaped) back."""

            def s(path, pool, v):
                paged, stacked = _leaf_kind(path)
                if paged:
                    if stacked:
                        return pool.at[:, table_row].set(v)
                    return pool.at[table_row].set(v)
                if stacked:
                    return pool.at[:, slot].set(v)
                return pool.at[slot].set(v)

            return tree_map_with_path(s, pools, vals)

        if eng.mesh is not None:
            ax = eng.axes
            pool_sh = named(eng.mesh, page_pspecs(cfg, ax))
            p_sh = named(eng.mesh,
                         replica_pspecs(cfg, ax, fsdp=eng.fsdp)["params"])
            with eng._ctx():
                self.pool.pools = reshard_tree(self.pool.pools, pool_sh)
            self._tick = jax.jit(
                tick,
                in_shardings=(p_sh, pool_sh, None, None, None, None),
                out_shardings=(None, pool_sh), donate_argnums=(1,))
            # Scheduler operands replicate; the fabric's T_avail register
            # file (arg 9) and counter file (arg 11) are donated so the
            # registers stay device-resident across ticks.
            self._tick_sched = jax.jit(
                tick_sched,
                in_shardings=(p_sh, pool_sh) + (None,) * 9,
                out_shardings=(None, pool_sh, None),
                donate_argnums=(1, 9))
            self._tick_sched_counted = jax.jit(
                tick_sched_counted,
                in_shardings=(p_sh, pool_sh) + (None,) * 11,
                out_shardings=(None, pool_sh, None, None),
                donate_argnums=(1, 9, 11))
            self._admit_scatter = jax.jit(
                admit_scatter,
                in_shardings=(pool_sh, eng._cache_sh, None, None),
                out_shardings=pool_sh, donate_argnums=(0,))
            self._restore_scatter = jax.jit(
                restore_scatter,
                in_shardings=(pool_sh, None, None, None),
                out_shardings=pool_sh, donate_argnums=(0,))
        else:
            self.pool.pools = jax.tree.map(jnp.asarray, self.pool.pools)
            self._tick = jax.jit(tick, donate_argnums=(1,))
            self._tick_sched = jax.jit(tick_sched, donate_argnums=(1, 9))
            self._tick_sched_counted = jax.jit(tick_sched_counted,
                                               donate_argnums=(1, 9, 11))
            self._admit_scatter = jax.jit(admit_scatter, donate_argnums=(0,))
            self._restore_scatter = jax.jit(restore_scatter,
                                            donate_argnums=(0,))
        # Scratch-page id, exposed for tests/introspection.
        self.scratch_page = scratch_page

    def rebind(self) -> None:
        """Re-place the pools and rebuild the tick after an engine reshard.

        The page set migrates as a unit through ``reshard_tree`` (or a host
        round-trip when moving off-mesh) — in-flight requests keep decoding
        token-identically on the new slice.
        """
        if self.engine.mesh is None:
            self.pool.pools = jax.tree.map(
                lambda x: jnp.asarray(np.asarray(x)), self.pool.pools)
        self._bind()

    # -- in-flight API ------------------------------------------------------

    def admit(self, prompt: np.ndarray, new_tokens: int) -> int | None:
        """Prefill + join the running batch.  Returns the slot id, or None
        when the pool cannot hold the request (caller queues — never drops).

        Reserves every page the request will need up front, so decode can
        never hit exhaustion mid-flight.  The first generated token comes
        from the prefill logits (argmax), exactly as the dense oracle's
        ``generate`` computes it.
        """
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        total = len(prompt) + int(new_tokens)
        if total > self.pool.max_len:
            raise ValueError(f"S0+new_tokens={total} exceeds "
                             f"max_len={self.pool.max_len}")
        if new_tokens < 1:
            raise ValueError("new_tokens must be >= 1")
        if not self.pool.can_admit(total):
            return None
        slot, pages = self.pool.reserve(total)
        eng = self.engine
        with eng._ctx():
            logits, dense = eng._prefill(eng.params, jnp.asarray(prompt[None]))
            self.pool.pools = self._admit_scatter(
                self.pool.pools, dense, jnp.asarray(self.pool.table[slot]),
                jnp.int32(slot))
            first = int(jnp.argmax(logits[0]))
        self.slots[slot] = _Slot(prompt=prompt, new_tokens=int(new_tokens),
                                 pages=pages, tokens=[first])
        return slot

    def active_slots(self) -> list[int]:
        """Slots that still need decode ticks (not yet done)."""
        return sorted(s for s, rec in self.slots.items() if not rec.done)

    def finished_slots(self) -> list[int]:
        """Slots whose generation is complete and awaiting :meth:`retire`."""
        return sorted(s for s, rec in self.slots.items() if rec.done)

    def decode_tick(self, sched=None):
        """One decode step for every active slot: gather pages → dense view
        → ``decode_step`` with per-row positions → scatter the written
        token.  Returns {slot: newly generated token}.  Lane count pads to
        the next power of two (scratch-slot lanes), so admissions change the
        compiled variant at most ``log2(max_batch)+1`` times.

        ``sched``: optional staged HEFT_RT mapping event ``(avg,
        exec_times, fabric)`` — a *fused-backend* :class:`repro.
        sched_integration.fabric.MappingFabric` whose device registers the
        tick consumes.  The decision runs inside the same compiled program
        as the decode step (zero extra host round-trips; its outputs ride
        the token transfer), and the return value becomes ``(tokens,
        decision)`` with ``decision`` the fabric's ``map_event`` 5-tuple.
        Decode math is byte-for-byte the plain tick's.
        """
        active = self.active_slots()
        if not active:
            return {} if sched is None else ({}, None)
        B = pow2_bucket(len(active), 1)
        scratch = self.pool.scratch_slot
        lanes = active + [scratch] * (B - len(active))
        slot_ids = np.asarray(lanes, dtype=np.int32)
        pos = np.zeros(B, dtype=np.int32)
        tok = np.zeros((B, 1), dtype=np.int32)
        for i, s in enumerate(active):
            rec = self.slots[s]
            pos[i] = rec.write_pos
            tok[i, 0] = rec.tokens[-1]
        eng = self.engine
        decision = None
        with eng._ctx():
            args = (eng.params, self.pool.pools,
                    jnp.asarray(self.pool.table[slot_ids]),
                    jnp.asarray(slot_ids), jnp.asarray(pos), jnp.asarray(tok))
            if sched is None:
                toks, self.pool.pools = self._tick(*args)
                nxt = np.asarray(toks)
            else:
                avg, exec_times, fab = sched
                n = len(avg)
                (a_p, ex_p, valid, avail, mask,
                 counters, p_valid) = fab.tick_decision_inputs(avg, exec_times)
                if counters is None:
                    packed, self.pool.pools, new_avail = self._tick_sched(
                        *args, a_p, ex_p, valid, avail, mask)
                    ctr = None
                else:
                    # Exclusive branch: only one tick variant dispatches, so
                    # the staged operands feed exactly one donated call.
                    (packed, self.pool.pools, new_avail,
                     ctr) = self._tick_sched_counted(
                        *args, a_p, ex_p, valid, avail, mask,  # repro: noqa[donation-after-use]
                        counters, p_valid)
                # The tick's single host sync: tokens and decision share one
                # packed buffer (pack_tick_outputs); new_avail/ctr stay
                # device-resident and are adopted back by the fabric.
                buf = np.asarray(packed)
                nxt = buf[:B]
                decision = fab.commit_tick_decision(n, buf[B:], new_avail,
                                                    ctr)
        out = {}
        for i, s in enumerate(active):
            t = int(nxt[i])
            self.slots[s].tokens.append(t)
            out[s] = t
        return out if sched is None else (out, decision)

    def retire(self, slot: int) -> np.ndarray:
        """Free the slot's pages and return the full (S0+new_tokens,) ids."""
        rec = self.slots.pop(slot)
        self.pool.release(slot, rec.pages)
        return np.concatenate([rec.prompt,
                               np.asarray(rec.tokens, dtype=np.int32)])

    # -- pages as the migration / recovery unit -----------------------------

    def snapshot_slot(self, slot: int) -> dict:
        """Host-side snapshot of ONE request: its page set (page-shaped, not
        the dense cache) + decode state.  O(request length), not O(pool)."""
        rec = self.slots[slot]
        row = self.pool.table[slot]

        def snap(path, pool):
            paged, stacked = _leaf_kind(path)
            a = np.asarray(pool)
            if paged:
                return a[:, row] if stacked else a[row]
            return a[:, slot] if stacked else a[slot]

        return {
            "pages": tree_map_with_path(snap, self.pool.pools),
            "prompt": rec.prompt.copy(),
            "new_tokens": rec.new_tokens,
            "tokens": list(rec.tokens),
        }

    def restore_slot(self, snap: dict) -> int | None:
        """Re-admit a :meth:`snapshot_slot` request into THIS pool (same or a
        different engine).  Returns the new slot id, or None if the pool
        cannot hold it right now (caller queues).  Decoding resumes
        token-identically from the last committed token."""
        total = len(snap["prompt"]) + int(snap["new_tokens"])
        if not self.pool.can_admit(total):
            return None
        slot, pages = self.pool.reserve(total)
        with self.engine._ctx():
            self.pool.pools = self._restore_scatter(
                self.pool.pools,
                jax.tree.map(jnp.asarray, snap["pages"]),
                jnp.asarray(self.pool.table[slot]), jnp.int32(slot))
        self.slots[slot] = _Slot(prompt=np.asarray(snap["prompt"],
                                                   dtype=np.int32),
                                 new_tokens=int(snap["new_tokens"]),
                                 pages=pages, tokens=list(snap["tokens"]))
        return slot
