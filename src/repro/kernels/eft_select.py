"""Pallas TPU kernel: PE Handlers + EFT Selector feedback loop.

The paper's assignment datapath (Fig. 1): for each task dequeued from the
priority queue, every PE Handler adds the task's execution time on its PE to
its availability register (``T_finish = T_avail + Exec``), the EFT Selector's
comparator min-tree picks the PE with the lowest finish time, and only the
selected handler latches the new availability.  The dependency of task *t+1*'s
decision on task *t*'s register update is the fundamental serial loop of HEFT —
in hardware it bounds the drain rate at one decision/cycle; here it is a
``fori_loop`` whose body is one P-wide VPU add + one min-tree reduction.

TPU mapping: PEs live on vector lanes (padded to 128 with +inf so padding can
never win the argmin); the per-task outputs are accumulated branchlessly into
(1, D) vectors with iota masks — no scalar stores in the loop body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INF = float("inf")


def _eft_kernel(exec_ref, avail_ref,
                pe_out_ref, st_out_ref, fin_out_ref, avail_out_ref,
                *, D: int, P_pad: int):
    lanes = lax.broadcasted_iota(jnp.int32, (1, P_pad), 1)
    dcol = lax.broadcasted_iota(jnp.int32, (1, D), 1)

    def body(t, carry):
        avail, pes, sts, fins = carry
        ex = exec_ref[pl.ds(t, 1), :]               # (1, P_pad) LUT-RAM read
        finish = avail + ex                          # PE handlers (adders)
        fmin = jnp.min(finish)                       # EFT selector min-tree
        pe = jnp.argmin(finish).astype(jnp.int32)    #   … and its index
        ok = fmin < INF
        sel = lanes == pe
        start = jnp.min(jnp.where(sel, avail, INF))  # avail[pe] before update
        # availability-register write-back of the selected PE handler
        avail = jnp.where(sel & ok, fmin, avail)
        here = dcol == t
        pes = jnp.where(here, jnp.where(ok, pe, -1), pes)
        sts = jnp.where(here, jnp.where(ok, start, INF), sts)
        fins = jnp.where(here, jnp.where(ok, fmin, INF), fins)
        return avail, pes, sts, fins

    init = (
        avail_ref[...],
        jnp.full((1, D), -1, dtype=jnp.int32),
        jnp.full((1, D), INF, dtype=jnp.float32),
        jnp.full((1, D), INF, dtype=jnp.float32),
    )
    avail, pes, sts, fins = lax.fori_loop(0, D, body, init)
    pe_out_ref[...] = pes
    st_out_ref[...] = sts
    fin_out_ref[...] = fins
    avail_out_ref[...] = avail


def eft_select_padded(exec_pad, avail_pad, *, interpret: bool):
    """exec_pad: f32[D, P_pad]; avail_pad: f32[1, P_pad]. P_pad multiple of 128."""
    D, P_pad = exec_pad.shape
    kernel = functools.partial(_eft_kernel, D=D, P_pad=P_pad)
    out_shape = [
        jax.ShapeDtypeStruct((1, D), jnp.int32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, P_pad), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[
            pl.BlockSpec((D, P_pad), lambda: (0, 0)),
            pl.BlockSpec((1, P_pad), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, P_pad), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(exec_pad, avail_pad)
