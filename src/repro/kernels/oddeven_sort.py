"""Pallas TPU kernel: the shift-register priority queue's sorting network.

The paper's priority queue (Fig. 2) sorts by odd–even transposition: each cell
compare-exchanges with its immediate neighbour, alternating even/odd phases.
On the FPGA this is a systolic network whose path delay is independent of the
queue depth D.

TPU mapping (hardware adaptation, see DESIGN.md §2): the queue lives in VMEM as
two *brick-wall planes* — even-indexed cells ``ke`` and odd-indexed cells
``ko``, each a (1, D/2) vector.

  * even phase  — compare pairs (2i, 2i+1)  = ``(ke[i], ko[i])``  → one
    full-width elementwise VPU select, no data movement;
  * odd phase   — compare pairs (2i+1, 2i+2) = ``(ko[i], ke[i+1])`` → one
    lane-shift by 1 (the "wire to the neighbour cell") plus the same select.

A fixed ``D`` compare phases (``D/2 + 1`` even+odd iterations) guarantee a
fully sorted queue — odd–even transposition sorts n elements in n phases worst
case.  The FPGA's early termination (2 swap-free cycles) is a *latency* trick
with no TPU analogue (data-dependent trip counts defeat vectorization); it is
modeled in :mod:`repro.core.queue_model` instead.

Strict compares (swap only when strictly out of order) make the sort *stable*,
which is what makes hardware and software mapping decisions bit-identical
(paper Fig. 3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _sort_kernel(ke_ref, ko_ref, pe_ref, po_ref,
                 oke_ref, oko_ref, ope_ref, opo_ref,
                 *, M: int, n_iters: int, sentinel):
    """One pallas program: sort 2M elements held as even/odd planes."""
    col = lax.broadcasted_iota(jnp.int32, (1, M), 1)
    is_last = col == (M - 1)
    is_first = col == 0

    def phase_pair(_, carry):
        ke, ko, pe_, po = carry
        # --- even phase: (ke[i], ko[i]) ---------------------------------
        m = ke < ko                      # descending: bigger key moves left
        ke, ko = jnp.where(m, ko, ke), jnp.where(m, ke, ko)
        pe_, po = jnp.where(m, po, pe_), jnp.where(m, pe_, po)
        # --- odd phase: (ko[i], ke[i+1]) --------------------------------
        b = jnp.where(is_last, sentinel, jnp.roll(ke, -1, axis=1))
        pb = jnp.roll(pe_, -1, axis=1)
        m = ko < b
        ko_new = jnp.where(m, b, ko)
        b_new = jnp.where(m, ko, b)
        po_new = jnp.where(m, pb, po)
        pb_new = jnp.where(m, po, pb)
        ke_new = jnp.where(is_first, ke, jnp.roll(b_new, 1, axis=1))
        pe_new = jnp.where(is_first, pe_, jnp.roll(pb_new, 1, axis=1))
        return ke_new, ko_new, pe_new, po_new

    init = (ke_ref[...], ko_ref[...], pe_ref[...], po_ref[...])
    ke, ko, pe_, po = lax.fori_loop(0, n_iters, phase_pair, init)
    oke_ref[...] = ke
    oko_ref[...] = ko
    ope_ref[...] = pe_
    opo_ref[...] = po


def oddeven_sort_planes(ke, ko, pe_, po, *, interpret: bool):
    """Sort even/odd planes (each (1, M)). Key dtype must be f32 or i32."""
    M = ke.shape[-1]
    sentinel = (jnp.finfo(ke.dtype).min if jnp.issubdtype(ke.dtype, jnp.floating)
                else jnp.iinfo(ke.dtype).min)
    kernel = functools.partial(_sort_kernel, M=M, n_iters=M + 1,
                               sentinel=ke.dtype.type(sentinel))
    out_shape = [
        jax.ShapeDtypeStruct((1, M), ke.dtype),
        jax.ShapeDtypeStruct((1, M), ko.dtype),
        jax.ShapeDtypeStruct((1, M), pe_.dtype),
        jax.ShapeDtypeStruct((1, M), po.dtype),
    ]
    specs = [pl.BlockSpec((1, M), lambda: (0, 0))] * 4
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=specs,
        out_specs=specs,
        interpret=interpret,
    )(ke, ko, pe_, po)
