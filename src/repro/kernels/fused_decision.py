"""The device-resident HEFT_RT decision, fusable into the decode tick.

The source paper's thesis is that the scheduler belongs in the same clock
domain as the PEs it feeds (9.144 ns/decision once HEFT_RT is an FPGA
overlay next to the workers).  The TPU-side analogue of "same clock domain"
is *same compiled program*: this module provides the decision as a pure
traceable function that ``serve.paging.PagedRuntime`` inlines into its
jitted gather→decode→scatter tick, so a steady-state serving loop makes
zero host scheduling round-trips — the decision's inputs (the ``T_avail``
register file, the PE partition mask, the observability counter registers)
stay device-resident between ticks and its outputs ride the token transfer
the tick already performs.

Two implementations, decision-for-decision identical:

* :func:`decision_ref` — pure ``jax.numpy`` on top of
  :func:`repro.core.heft_rt`, with the PE mask applied *inside the traced
  program* (no per-event host-side matrix copy, unlike
  ``MappingFabric._masked``).  This is the form fused into the decode tick
  and the ``fused`` fabric backend's standalone dispatch.
* :func:`decision_hw` — the Pallas overlay kernel
  (:mod:`repro.kernels.heft_fused` extended with an in-kernel additive PE
  mask), the non-interpreted lowering used when an accelerator backend is
  attached.  Off-accelerator it runs in interpret mode like every other
  kernel in this package.

Masking contract: ``pe_mask`` is a boolean lane vector; ``True`` lanes'
exec columns become ``+inf`` before the EFT selection, exactly the chaos
tier's partition semantics (``MappingFabric.set_pe_mask``) — so decisions
with a mask equal the ``heft_rt_numpy`` oracle on the masked matrix, and
with an all-``False`` mask the program is bit-identical to the unmasked
dispatch (``where(False, inf, x) == x``).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.heft_rt import ScheduleResult, heft_rt

INF = float("inf")
NEG_INF = float("-inf")


def decision_ref(avg, exec_times, avail, valid, pe_mask) -> ScheduleResult:
    """One HEFT_RT mapping event with an in-program PE mask (traceable).

    ``avg``: f32[D] priority keys; ``exec_times``: f32[D, P];
    ``avail``: f32[P] — the device-resident register file, typically passed
    as a donated argument so the buffer is reused for ``new_avail``;
    ``valid``: bool[D] real-slot mask; ``pe_mask``: bool[P], ``True`` lanes
    are masked out of dispatch (their committed registers stay resident).

    Pure jnp — safe to inline into any jitted program (the decode tick).
    """
    ex = jnp.where(pe_mask[None, :], jnp.float32(INF),
                   exec_times.astype(jnp.float32))
    return heft_rt(avg, ex, avail, valid)


def pack_tick_outputs(toks, res: ScheduleResult):
    """Pack a fused tick's host-bound outputs into ONE int32 buffer.

    Each separate device→host materialization of an in-flight program's
    output costs tens of µs of fixed sync overhead — transferring the
    tokens plus five decision arrays individually would dominate the fused
    decision's single-digit-µs budget.  Instead the compiled tick returns
    this single lane: ``tokens | order | assignment | start | finish |
    new_avail``, float lanes bitcast to int32 (``lax.bitcast_convert_type``
    is a bit-move, so the host's ``.view(np.float32)`` recovers them
    *bit-exactly* — no float↔int value round-trip is involved, ±inf and
    every mantissa bit survive).  The fused tick then pays exactly one
    transfer, the same count as the plain tick.

    The resident ``new_avail`` register (device buffer) is returned
    separately by the tick — the copy packed here is the host's read-only
    view for the ``map_event`` 5-tuple contract.
    """
    bits = lambda x: lax.bitcast_convert_type(x, jnp.int32)
    return jnp.concatenate([
        toks.reshape(-1).astype(jnp.int32),
        res.order.astype(jnp.int32),
        res.assignment.astype(jnp.int32),
        bits(res.start_time),
        bits(res.finish_time),
        bits(res.new_avail),
    ])


def unpack_decision(buf, num_pes: int):
    """Host-side inverse of :func:`pack_tick_outputs`' decision lanes.

    ``buf``: the int32 host buffer *after* the token prefix was sliced off
    (length ``4*D + P``); ``num_pes``: the padded PE lane count ``P``.
    Returns untrimmed ``(order, assignment, start, finish, new_avail)``
    numpy views — zero-copy reinterpretation, bit-identical to the arrays
    the program computed.
    """
    d = (buf.shape[0] - num_pes) // 4
    return (buf[:d], buf[d:2 * d],
            buf[2 * d:3 * d].view(np.float32),
            buf[3 * d:4 * d].view(np.float32),
            buf[4 * d:].view(np.float32))


# ---------------------------------------------------------------------------
# Pallas overlay variant: the fused kernel with an in-kernel additive mask
# ---------------------------------------------------------------------------


def _decision_kernel(ke_ref, ko_ref, qe_ref, qo_ref, exec_ref, mask_ref,
                     avail_ref, order_ref, pe_out_ref, st_out_ref,
                     fin_out_ref, avail_out_ref, *, M: int, D: int,
                     P_pad: int):
    """``heft_fused._fused_kernel`` + a (1, P_pad) additive mask row.

    The mask row carries ``0.0`` on dispatchable lanes and ``+inf`` on
    masked/padded lanes; adding it at the LUT-RAM read masks the lane for
    every dequeued task without touching the exec table in HBM (``finite +
    inf == inf``, ``inf + inf == inf`` — exec times live in ``[0, +inf]``).
    """
    col = lax.broadcasted_iota(jnp.int32, (1, M), 1)
    is_last = col == (M - 1)
    is_first = col == 0

    # ---- phase 1: odd–even transposition sort (priority queue) ----------
    def phase_pair(_, carry):
        ke, ko, qe, qo = carry
        m = ke < ko
        ke, ko = jnp.where(m, ko, ke), jnp.where(m, ke, ko)
        qe, qo = jnp.where(m, qo, qe), jnp.where(m, qe, qo)
        b = jnp.where(is_last, NEG_INF, jnp.roll(ke, -1, axis=1))
        qb = jnp.roll(qe, -1, axis=1)
        m = ko < b
        ko_new = jnp.where(m, b, ko)
        b_new = jnp.where(m, ko, b)
        qo_new = jnp.where(m, qb, qo)
        qb_new = jnp.where(m, qo, qb)
        ke = jnp.where(is_first, ke, jnp.roll(b_new, 1, axis=1))
        qe = jnp.where(is_first, qe, jnp.roll(qb_new, 1, axis=1))
        return ke, ko_new, qe, qo_new

    init = (ke_ref[...], ko_ref[...], qe_ref[...], qo_ref[...])
    _, _, qe, qo = lax.fori_loop(0, M + 1, phase_pair, init)

    # ---- phase 2: drain + masked EFT assignment -------------------------
    lanes = lax.broadcasted_iota(jnp.int32, (1, P_pad), 1)
    dcol = lax.broadcasted_iota(jnp.int32, (1, D), 1)
    mask_row = mask_ref[...]

    def body(t, carry):
        avail, orders, pes, sts, fins = carry
        i = t // 2
        sel_i = col == i
        q_even = jnp.sum(jnp.where(sel_i, qe, 0))
        q_odd = jnp.sum(jnp.where(sel_i, qo, 0))
        qid = jnp.where(t % 2 == 0, q_even, q_odd).astype(jnp.int32)
        ex = exec_ref[pl.ds(qid, 1), :] + mask_row   # masked LUT-RAM read
        finish = avail + ex
        fmin = jnp.min(finish)
        pe = jnp.argmin(finish).astype(jnp.int32)
        ok = fmin < INF
        sel = lanes == pe
        start = jnp.min(jnp.where(sel, avail, INF))
        avail = jnp.where(sel & ok, fmin, avail)
        here = dcol == t
        orders = jnp.where(here, qid, orders)
        pes = jnp.where(here, jnp.where(ok, pe, -1), pes)
        sts = jnp.where(here, jnp.where(ok, start, INF), sts)
        fins = jnp.where(here, jnp.where(ok, fmin, INF), fins)
        return avail, orders, pes, sts, fins

    init2 = (
        avail_ref[...],
        jnp.zeros((1, D), dtype=jnp.int32),
        jnp.full((1, D), -1, dtype=jnp.int32),
        jnp.full((1, D), INF, dtype=jnp.float32),
        jnp.full((1, D), INF, dtype=jnp.float32),
    )
    avail, orders, pes, sts, fins = lax.fori_loop(0, D, body, init2)
    order_ref[...] = orders
    pe_out_ref[...] = pes
    st_out_ref[...] = sts
    fin_out_ref[...] = fins
    avail_out_ref[...] = avail


def decision_fused_padded(ke, ko, qe, qo, exec_pad, mask_pad, avail_pad, *,
                          interpret: bool):
    """All-padded entry: planes (1, M), exec f32[D, P_pad], mask/avail
    f32[1, P_pad] (mask is additive: 0 on live lanes, +inf on masked)."""
    M = ke.shape[-1]
    D = 2 * M
    P_pad = exec_pad.shape[-1]
    kernel = functools.partial(_decision_kernel, M=M, D=D, P_pad=P_pad)
    out_shape = [
        jax.ShapeDtypeStruct((1, D), jnp.int32),
        jax.ShapeDtypeStruct((1, D), jnp.int32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, P_pad), jnp.float32),
    ]
    plane = pl.BlockSpec((1, M), lambda: (0, 0))
    row = pl.BlockSpec((1, P_pad), lambda: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[
            plane, plane, plane, plane,
            pl.BlockSpec((D, P_pad), lambda: (0, 0)),
            row, row,
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            row,
        ],
        interpret=interpret,
    )(ke, ko, qe, qo, exec_pad, mask_pad, avail_pad)
