# The paper's compute hot-spot IS a custom hardware datapath: the HEFT_RT
# overlay processor (priority queue + PE handlers + EFT selector).  These
# Pallas kernels are its TPU-native port (see DESIGN.md §2):
#   oddeven_sort   — shift-register priority queue (brick-wall compare-exchange)
#   eft_select     — PE-handler adders + EFT min-tree + availability feedback
#   heft_fused     — the full overlay: one pallas_call per mapping event
#   fused_decision — the overlay with a device-resident PE mask, fusable into
#                    the paged decode tick (zero host scheduling round-trips)
from repro.kernels.fused_decision import decision_ref
from repro.kernels.ops import (decision_hw, eft_select, heft_rt_hw,
                               interpret_default, oddeven_sort)

__all__ = [
    "decision_hw",
    "decision_ref",
    "eft_select",
    "heft_rt_hw",
    "interpret_default",
    "oddeven_sort",
]
