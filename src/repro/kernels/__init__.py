# The paper's compute hot-spot IS a custom hardware datapath: the HEFT_RT
# overlay processor (priority queue + PE handlers + EFT selector).  These
# Pallas kernels are its TPU-native port (see DESIGN.md §2):
#   oddeven_sort — shift-register priority queue (brick-wall compare-exchange)
#   eft_select   — PE-handler adders + EFT min-tree + availability feedback
#   heft_fused   — the full overlay: one pallas_call per mapping event
from repro.kernels.ops import eft_select, heft_rt_hw, oddeven_sort

__all__ = ["eft_select", "heft_rt_hw", "oddeven_sort"]
