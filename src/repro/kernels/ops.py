"""Public jit'd wrappers for the HEFT_RT hardware-dataplane kernels.

Handles padding to TPU-friendly shapes (queue depth → multiple of 256 so the
even/odd planes are 128-lane aligned; PE axis → 128 lanes), dtype promotion,
and interpret-mode selection (interpret=True on CPU, compiled on TPU).

Public API
----------
``oddeven_sort(keys, payload)``      — stable descending sort (priority queue)
``eft_select(exec_sorted, avail)``   — EFT assignment over a sorted queue
``heft_rt_hw(avg, exec, avail)``     — full fused mapping event (the overlay)
``decision_hw(avg, exec, avail, pe_mask)`` — mapping event with in-kernel mask
``interpret_default()``              — whether kernels lower or interpret here
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import eft_select as _eft
from repro.kernels import fused_decision as _decision
from repro.kernels import heft_fused as _fused
from repro.kernels import oddeven_sort as _sort

_LANES = 128
_QUEUE_ALIGN = 256  # two 128-lane planes

INF = float("inf")

# Backends with a real Mosaic/Triton pallas lowering; everywhere else the
# kernels run through the interpreter.  GPU was previously (wrongly) lumped
# with CPU, silently interpreting on machines that could compile.
_COMPILED_BACKENDS = ("tpu", "gpu")


def interpret_default() -> bool:
    """True when pallas kernels would run in interpret mode on this host."""
    return jax.default_backend() not in _COMPILED_BACKENDS


# Backwards-compat alias (pre-PR-10 internal name, used by fabric/tests).
_interpret_default = interpret_default


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _key_compute_dtype(dtype) -> jnp.dtype:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.dtype(jnp.float32)   # bf16/f16 ⊂ f32 exactly
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.dtype(jnp.int32)
    raise TypeError(f"unsupported key dtype {dtype}")


def _split_planes(x):
    """(D,) → even/odd planes (1, D//2)."""
    return x[0::2][None, :], x[1::2][None, :]


def _interleave(a, b):
    """even/odd planes (1, M) → (2M,)."""
    return jnp.stack([a[0], b[0]], axis=1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _oddeven_sort_impl(keys, payload, interpret: bool):
    orig_dtype = keys.dtype
    cdt = _key_compute_dtype(orig_dtype)
    D0 = keys.shape[-1]
    D = max(_round_up(D0, _QUEUE_ALIGN), _QUEUE_ALIGN)
    sentinel = (jnp.finfo(cdt).min if jnp.issubdtype(cdt, jnp.floating)
                else jnp.iinfo(cdt).min)
    k = jnp.full((D,), sentinel, dtype=cdt).at[:D0].set(keys.astype(cdt))
    p = jnp.full((D,), -1, dtype=jnp.int32).at[:D0].set(payload.astype(jnp.int32))
    ke, ko = _split_planes(k)
    pe_, po = _split_planes(p)
    oke, oko, ope, opo = _sort.oddeven_sort_planes(ke, ko, pe_, po, interpret=interpret)
    keys_out = _interleave(oke, oko)[:D0]
    payload_out = _interleave(ope, opo)[:D0]
    return keys_out.astype(orig_dtype), payload_out


def oddeven_sort(keys: jax.Array, payload: jax.Array, *, interpret: bool | None = None):
    """Stable descending sort of (keys, payload) via the priority-queue kernel."""
    if interpret is None:
        interpret = _interpret_default()
    return _oddeven_sort_impl(keys, payload, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _eft_select_impl(exec_sorted, avail, interpret: bool):
    D0, P0 = exec_sorted.shape
    P_pad = max(_round_up(P0, _LANES), _LANES)
    D = max(D0, 8)  # keep a sane minimum block
    ex = jnp.full((D, P_pad), INF, dtype=jnp.float32)
    ex = ex.at[:D0, :P0].set(exec_sorted.astype(jnp.float32))
    av = jnp.full((1, P_pad), INF, dtype=jnp.float32)
    av = av.at[0, :P0].set(avail.astype(jnp.float32))
    pes, sts, fins, new_avail = _eft.eft_select_padded(ex, av, interpret=interpret)
    return (pes[0, :D0], sts[0, :D0], fins[0, :D0], new_avail[0, :P0])


def eft_select(exec_sorted: jax.Array, avail: jax.Array, *, interpret: bool | None = None):
    """EFT assignment over an already-sorted ready queue.

    Returns (assignment i32[D], start f32[D], finish f32[D], new_avail f32[P]).
    """
    if interpret is None:
        interpret = _interpret_default()
    return _eft_select_impl(exec_sorted, avail, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _heft_rt_hw_impl(avg, exec_times, avail, interpret: bool):
    D0, P0 = exec_times.shape
    D = max(_round_up(D0, _QUEUE_ALIGN), _QUEUE_ALIGN)
    P_pad = max(_round_up(P0, _LANES), _LANES)
    k = jnp.full((D,), float("-inf"), dtype=jnp.float32)
    k = k.at[:D0].set(avg.astype(jnp.float32))
    q = jnp.arange(D, dtype=jnp.int32)  # QIDs; padded slots keep their index
    ex = jnp.full((D, P_pad), INF, dtype=jnp.float32)
    ex = ex.at[:D0, :P0].set(exec_times.astype(jnp.float32))
    av = jnp.full((1, P_pad), INF, dtype=jnp.float32)
    av = av.at[0, :P0].set(avail.astype(jnp.float32))
    ke, ko = _split_planes(k)
    qe, qo = _split_planes(q)
    order, pes, sts, fins, new_avail = _fused.heft_fused_padded(
        ke, ko, qe, qo, ex, av, interpret=interpret)
    return (order[0, :D0], pes[0, :D0], sts[0, :D0], fins[0, :D0],
            new_avail[0, :P0])


def heft_rt_hw(avg: jax.Array, exec_times: jax.Array, avail: jax.Array,
               *, interpret: bool | None = None):
    """One full HEFT_RT mapping event through the fused overlay kernel.

    Mirrors :func:`repro.core.heft_rt` exactly: returns (order, assignment,
    start, finish, new_avail), with padded slots (beyond the real queue) never
    influencing the availability registers.

    Note: padded queue slots sort *behind* all real tasks (key −inf, stable),
    so ``order[:n]`` over real slots matches the software scheduler's order.
    """
    if interpret is None:
        interpret = _interpret_default()
    return _heft_rt_hw_impl(avg, exec_times, avail, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decision_hw_impl(avg, exec_times, avail, pe_mask, interpret: bool):
    D0, P0 = exec_times.shape
    D = max(_round_up(D0, _QUEUE_ALIGN), _QUEUE_ALIGN)
    P_pad = max(_round_up(P0, _LANES), _LANES)
    k = jnp.full((D,), float("-inf"), dtype=jnp.float32)
    k = k.at[:D0].set(avg.astype(jnp.float32))
    q = jnp.arange(D, dtype=jnp.int32)
    ex = jnp.full((D, P_pad), INF, dtype=jnp.float32)
    ex = ex.at[:D0, :P0].set(exec_times.astype(jnp.float32))
    av = jnp.full((1, P_pad), INF, dtype=jnp.float32)
    av = av.at[0, :P0].set(avail.astype(jnp.float32))
    # Additive mask row: 0 on live lanes, +inf on masked lanes.  Padded
    # lanes are already +inf in both exec and avail, so 0 there is fine.
    mrow = jnp.zeros((1, P_pad), dtype=jnp.float32)
    mrow = mrow.at[0, :P0].set(
        jnp.where(pe_mask, jnp.float32(INF), jnp.float32(0.0)))
    ke, ko = _split_planes(k)
    qe, qo = _split_planes(q)
    order, pes, sts, fins, new_avail = _decision.decision_fused_padded(
        ke, ko, qe, qo, ex, mrow, av, interpret=interpret)
    return (order[0, :D0], pes[0, :D0], sts[0, :D0], fins[0, :D0],
            new_avail[0, :P0])


def decision_hw(avg: jax.Array, exec_times: jax.Array, avail: jax.Array,
                pe_mask: jax.Array, *, interpret: bool | None = None):
    """One HEFT_RT mapping event with the PE mask applied inside the kernel.

    Like :func:`heft_rt_hw` but takes a bool[P] ``pe_mask`` (True = lane
    withheld from dispatch) that is applied as an additive +inf row at the
    exec-LUT read — the device-resident masking contract of the ``fused``
    fabric backend.  With an all-False mask this is bit-identical to
    :func:`heft_rt_hw`.
    """
    if interpret is None:
        interpret = _interpret_default()
    return _decision_hw_impl(avg, exec_times, avail, pe_mask, interpret)
