"""Pallas TPU kernel: the complete HEFT_RT overlay processor, fused.

One ``pallas_call`` = one *mapping event*, exactly like the paper's overlay:
the priority queue sorts (odd–even transposition on the even/odd brick-wall
planes), then tasks drain in priority order — each dequeued QID indexes the
exec-time table (the LUT-RAM read), the PE handlers + EFT min-tree pick the PE,
and the selected availability register is updated.

Fusing matters on TPU for the same reason the paper built one overlay instead
of three IP blocks: the intermediate sorted queue never leaves VMEM (the FPGA
equivalent: the sorted cells never leave the shift register), so a mapping
event costs one kernel launch and zero HBM round-trips for intermediates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INF = float("inf")
NEG_INF = float("-inf")


def _fused_kernel(ke_ref, ko_ref, qe_ref, qo_ref, exec_ref, avail_ref,
                  order_ref, pe_out_ref, st_out_ref, fin_out_ref, avail_out_ref,
                  *, M: int, D: int, P_pad: int):
    col = lax.broadcasted_iota(jnp.int32, (1, M), 1)
    is_last = col == (M - 1)
    is_first = col == 0

    # ---- phase 1: odd–even transposition sort (priority queue) ----------
    def phase_pair(_, carry):
        ke, ko, qe, qo = carry
        m = ke < ko
        ke, ko = jnp.where(m, ko, ke), jnp.where(m, ke, ko)
        qe, qo = jnp.where(m, qo, qe), jnp.where(m, qe, qo)
        b = jnp.where(is_last, NEG_INF, jnp.roll(ke, -1, axis=1))
        qb = jnp.roll(qe, -1, axis=1)
        m = ko < b
        ko_new = jnp.where(m, b, ko)
        b_new = jnp.where(m, ko, b)
        qo_new = jnp.where(m, qb, qo)
        qb_new = jnp.where(m, qo, qb)
        ke = jnp.where(is_first, ke, jnp.roll(b_new, 1, axis=1))
        qe = jnp.where(is_first, qe, jnp.roll(qb_new, 1, axis=1))
        return ke, ko_new, qe, qo_new

    init = (ke_ref[...], ko_ref[...], qe_ref[...], qo_ref[...])
    _, _, qe, qo = lax.fori_loop(0, M + 1, phase_pair, init)

    # ---- phase 2: drain + EFT assignment (PE handlers / selector) -------
    lanes = lax.broadcasted_iota(jnp.int32, (1, P_pad), 1)
    dcol = lax.broadcasted_iota(jnp.int32, (1, D), 1)

    def body(t, carry):
        avail, orders, pes, sts, fins = carry
        # dequeue: position t lives in plane t%2 at index t//2
        i = t // 2
        sel_i = col == i
        q_even = jnp.sum(jnp.where(sel_i, qe, 0))
        q_odd = jnp.sum(jnp.where(sel_i, qo, 0))
        qid = jnp.where(t % 2 == 0, q_even, q_odd).astype(jnp.int32)
        ex = exec_ref[pl.ds(qid, 1), :]              # LUT-RAM read by QID
        finish = avail + ex
        fmin = jnp.min(finish)
        pe = jnp.argmin(finish).astype(jnp.int32)
        ok = fmin < INF
        sel = lanes == pe
        start = jnp.min(jnp.where(sel, avail, INF))
        avail = jnp.where(sel & ok, fmin, avail)
        here = dcol == t
        orders = jnp.where(here, qid, orders)
        pes = jnp.where(here, jnp.where(ok, pe, -1), pes)
        sts = jnp.where(here, jnp.where(ok, start, INF), sts)
        fins = jnp.where(here, jnp.where(ok, fmin, INF), fins)
        return avail, orders, pes, sts, fins

    init2 = (
        avail_ref[...],
        jnp.zeros((1, D), dtype=jnp.int32),
        jnp.full((1, D), -1, dtype=jnp.int32),
        jnp.full((1, D), INF, dtype=jnp.float32),
        jnp.full((1, D), INF, dtype=jnp.float32),
    )
    avail, orders, pes, sts, fins = lax.fori_loop(0, D, body, init2)
    order_ref[...] = orders
    pe_out_ref[...] = pes
    st_out_ref[...] = sts
    fin_out_ref[...] = fins
    avail_out_ref[...] = avail


def heft_fused_padded(ke, ko, qe, qo, exec_pad, avail_pad, *, interpret: bool):
    """All-padded entry: planes (1, M) f32/i32, exec f32[D, P_pad], avail f32[1, P_pad]."""
    M = ke.shape[-1]
    D = 2 * M
    P_pad = exec_pad.shape[-1]
    kernel = functools.partial(_fused_kernel, M=M, D=D, P_pad=P_pad)
    out_shape = [
        jax.ShapeDtypeStruct((1, D), jnp.int32),
        jax.ShapeDtypeStruct((1, D), jnp.int32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, D), jnp.float32),
        jax.ShapeDtypeStruct((1, P_pad), jnp.float32),
    ]
    plane = pl.BlockSpec((1, M), lambda: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[
            plane, plane, plane, plane,
            pl.BlockSpec((D, P_pad), lambda: (0, 0)),
            pl.BlockSpec((1, P_pad), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, D), lambda: (0, 0)),
            pl.BlockSpec((1, P_pad), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(ke, ko, qe, qo, exec_pad, avail_pad)
