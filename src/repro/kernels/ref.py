"""Pure-jnp oracles for the HEFT_RT hardware-dataplane kernels.

Every Pallas kernel in this package is validated (interpret mode on CPU,
compiled on TPU) against these references; the references themselves are
pinned against :mod:`repro.core.heft_rt` so kernel ⇔ software-scheduler
equivalence (the paper's Fig. 3 functional verification) is transitive.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = float("-inf")


def oddeven_sort_ref(keys: jax.Array, payload: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stable descending sort of (keys, payload) — what the shift-register
    priority queue computes.  Odd–even transposition with strict compares is
    stable, so a stable descending argsort is the exact oracle."""
    order = jnp.argsort(-keys.astype(jnp.float32), stable=True)
    return keys[order], payload[order]


def oddeven_sort_sim(keys: jax.Array, payload: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Step-by-step odd–even transposition (descending, strict swap), written
    with the same brick-wall even/odd-plane decomposition the Pallas kernel
    uses — an *executable spec* of the kernel's inner loop."""
    D = keys.shape[0]
    assert D % 2 == 0
    M = D // 2
    ke, ko = keys[0::2].astype(jnp.float32), keys[1::2].astype(jnp.float32)
    pe_, po = payload[0::2], payload[1::2]

    def phase_pair(carry, _):
        ke, ko, pe_, po = carry
        # even phase: compare (2i, 2i+1) == (ke[i], ko[i])
        m = ke < ko
        ke, ko = jnp.where(m, ko, ke), jnp.where(m, ke, ko)
        pe_, po = jnp.where(m, po, pe_), jnp.where(m, pe_, po)
        # odd phase: compare (2i+1, 2i+2) == (ko[i], ke[i+1])
        b = jnp.roll(ke, -1).at[M - 1].set(NEG_INF)      # right neighbours
        pb = jnp.roll(pe_, -1)
        m = ko < b
        ko_new = jnp.where(m, b, ko)
        b_new = jnp.where(m, ko, b)
        pb_new = jnp.where(m, po, pb)
        po_new = jnp.where(m, pb, po)
        ke = jnp.roll(b_new, 1).at[0].set(ke[0])
        pe_ = jnp.roll(pb_new, 1).at[0].set(pe_[0])
        return (ke, ko_new, pe_, po_new), None

    (ke, ko, pe_, po), _ = lax.scan(phase_pair, (ke, ko, pe_, po), None, length=M + 1)
    keys_out = jnp.stack([ke, ko], axis=1).reshape(D)
    payload_out = jnp.stack([pe_, po], axis=1).reshape(D)
    return keys_out.astype(keys.dtype), payload_out


def eft_select_ref(
    exec_sorted: jax.Array,  # f32[D, P] — exec times in priority order
    avail: jax.Array,        # f32[P]
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """PE-handler + EFT-selector feedback loop.

    Returns (assignment i32[D], start f32[D], finish f32[D], new_avail f32[P]).
    Rows whose every exec is +inf (padding / unsupported) get assignment -1
    and start/finish = +inf, and do not touch the availability registers.
    """
    P = avail.shape[-1]
    lanes = jnp.arange(P)

    def step(avail, ex):
        finish = avail + ex
        pe = jnp.argmin(finish).astype(jnp.int32)
        f = finish[pe]
        ok = jnp.isfinite(f)
        start = avail[pe]
        new_avail = jnp.where((lanes == pe) & ok, f, avail)
        return new_avail, (
            jnp.where(ok, pe, jnp.int32(-1)),
            jnp.where(ok, start, jnp.inf),
            jnp.where(ok, f, jnp.inf),
        )

    new_avail, (pes, starts, fins) = lax.scan(
        step, avail.astype(jnp.float32), exec_sorted.astype(jnp.float32)
    )
    return pes, starts, fins, new_avail


def heft_fused_ref(
    avg: jax.Array,         # f32[D]
    exec_times: jax.Array,  # f32[D, P] in QUEUE order (indexed by QID)
    avail: jax.Array,       # f32[P]
):
    """Full mapping event: sort by descending avg (stable), then EFT-assign.

    Returns (order i32[D], assignment i32[D], start f32[D], finish f32[D],
    new_avail f32[P]) — the oracle for the fused Pallas kernel and the exact
    mirror of ``repro.core.heft_rt``.
    """
    D = avg.shape[0]
    qids = jnp.arange(D, dtype=jnp.int32)
    _, order = oddeven_sort_ref(avg, qids)
    exec_sorted = jnp.take(exec_times, order, axis=0)
    pes, starts, fins, new_avail = eft_select_ref(exec_sorted, avail)
    return order, pes, starts, fins, new_avail
