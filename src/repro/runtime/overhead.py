"""Scheduling-overhead models for the software and hardware HEFT_RT paths.

The paper's measured behaviour (Section VI, Fig. 4) on the ZCU102:

  * software HEFT_RT on the A53 management core: O(n log n) growth,
  * hardware HEFT_RT: (3n+3) cycles at the 3.048 ns critical path, PLUS the
    AXI/DMA transfer of the ready queue into the overlay — which dominates and
    produces a *crossover at ready-queue size ≈ 5* below which software wins,
  * headline ratios at n = 1330: hardware is 183× faster on scheduling
    computation alone, 2.6× faster end-to-end including transfer.

The constants below are calibrated so the model reproduces those three
published anchors exactly (crossover n=5, 183×, 2.6× — see
``tests/test_runtime.py`` and ``benchmarks/bench_latency_vs_queue.py``).
The slightly super-linear transfer exponent models per-word uncached AXI
writes with increasing bus contention at long bursts, which the paper points
to as its outlier source ("data transfer overhead on the Zynq ZCU102").

A third, *measured* model wraps our actual software scheduler
(`heft_rt_numpy`) with a wall clock, for honest on-this-host numbers.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import heft_rt_numpy, worst_case_cycles
from repro.core.resource_model import PAPER_CRITICAL_PATH_NS
from repro.obs.metrics import time_s

# software HEFT_RT on the A53 (seconds)
SW_BASE_S = 1.8e-6           # runtime entry/exit, queue marshalling
SW_PER_NLOGN_S = 0.161e-6    # sort + EFT loop per n·log2(n)

# hardware HEFT_RT (seconds)
HW_XFER_BASE_S = 1.79e-6     # DMA descriptor setup + doorbell + drain sync
HW_XFER_PER_TASK_S = 0.31e-6  # per-task AXI-S payload (Avg + Exec[P] words)
HW_XFER_EXPONENT = 1.1       # mild superlinearity: bus contention at long bursts
HW_CLOCK_S = PAPER_CRITICAL_PATH_NS * 1e-9  # D=512/P=4 design point


def sw_overhead_s(n: int) -> float:
    """Modeled software scheduling overhead for a ready queue of size n."""
    if n <= 0:
        return 0.0
    return SW_BASE_S + SW_PER_NLOGN_S * n * np.log2(max(n, 2))


def hw_compute_s(n: int) -> float:
    """Hardware scheduling time excluding transfer: (3n+3) × T_clk."""
    if n <= 0:
        return 0.0
    return worst_case_cycles(n) * HW_CLOCK_S


def hw_transfer_s(n: int) -> float:
    if n <= 0:
        return 0.0
    return HW_XFER_BASE_S + HW_XFER_PER_TASK_S * float(n) ** HW_XFER_EXPONENT


def hw_overhead_s(n: int) -> float:
    """End-to-end hardware scheduling overhead (transfer + compute)."""
    return hw_transfer_s(n) + hw_compute_s(n)


@dataclass
class OverheadModel:
    """Maps ready-queue size → scheduling overhead in seconds."""

    kind: str  # 'sw' | 'hw' | 'measured' | 'none'

    def __call__(self, n: int, avg=None, exec_times=None, avail=None) -> float:
        if self.kind == "sw":
            return sw_overhead_s(n)
        if self.kind == "hw":
            return hw_overhead_s(n)
        if self.kind == "none":
            return 0.0
        if self.kind == "measured":
            _, dt = time_s(heft_rt_numpy, avg, exec_times, avail)
            return dt
        raise ValueError(self.kind)


SW_MODEL = OverheadModel("sw")
HW_MODEL = OverheadModel("hw")
ZERO_MODEL = OverheadModel("none")
