"""Application task DAGs — the paper's four CEDR signal-processing workloads.

The paper (Section V) evaluates with four real-world applications shipped with
CEDR: Radar Correlator (RC), Temporal Interference Mitigation (TM) — the *low
latency* pair — and Pulse Doppler (PD), WiFi TX (TX) — the *high latency*
pair.  The SoC is 3× ARM Cortex-A53 cores + 1× FFT accelerator on the ZCU102.

We model each application as a task DAG whose tasks are typed (FFT vs.
general-purpose DSP); per-PE execution times come from a PE-type table:
ARM cores run everything; the FFT accelerator runs only FFT-type tasks, ~11×
faster than an A53 (typical for the Xilinx FFT IP at these sizes).  Exec-time
magnitudes are calibrated so the high-latency workload saturates near the
paper's operating range (~200 frames/s on 4 PEs ⇒ ≈20 PE-milliseconds per
frame across both apps); the *relative* structure (fan-out, FFT fraction,
chain depth) follows each application's published signal chain.

These tables play the role of CEDR's profiled per-PE execution times — the
inputs the runtime hands the scheduler at every mapping event.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

# PE types
ARM = "arm"
FFT_ACC = "fft"

#: execution-time table (milliseconds): task_type -> {pe_type: time}
#: np.inf marks unsupported placements (accelerator can't run scalar DSP).
#: Magnitudes calibrated so the 4-PE SoC saturates near the paper's operating
#: point (~200-230 frames/s on the high-latency workload before scheduling
#: overhead; see bench_frame_rate.py).
EXEC_TABLE_MS: dict[str, dict[str, float]] = {
    # FFT-type tasks — supported everywhere, much faster on the accelerator.
    "fft_small":  {ARM: 0.083, FFT_ACC: 0.0083},
    "fft_large":  {ARM: 0.348, FFT_ACC: 0.0348},
    # general DSP tasks — ARM only.
    "mult":       {ARM: 0.139, FFT_ACC: np.inf},
    "detect":     {ARM: 0.083, FFT_ACC: np.inf},
    "modulate":   {ARM: 0.139, FFT_ACC: np.inf},
    "encode":     {ARM: 0.209, FFT_ACC: np.inf},
    "interleave": {ARM: 0.070, FFT_ACC: np.inf},
    "crc":        {ARM: 0.056, FFT_ACC: np.inf},
    "filter":     {ARM: 0.167, FFT_ACC: np.inf},
}


@dataclass
class AppTask:
    name: str
    task_type: str
    deps: list[int] = field(default_factory=list)   # indices within the app


@dataclass
class AppDAG:
    """An application instance template (the paper's "Frame" granularity)."""

    app_name: str
    tasks: list[AppTask]
    frame_kb: float          # input data size per frame (paper: 1280 / 1037 Kb)

    def exec_matrix(self, pe_types: list[str],
                    noise: np.random.Generator | None = None) -> np.ndarray:
        """(T, P) execution-time matrix in ms for a concrete SoC config."""
        mat = np.empty((len(self.tasks), len(pe_types)))
        for ti, t in enumerate(self.tasks):
            row = EXEC_TABLE_MS[t.task_type]
            for pi, pt in enumerate(pe_types):
                mat[ti, pi] = row[pt]
        if noise is not None:
            jitter = noise.normal(1.0, 0.03, mat.shape)  # profiling noise
            mat = np.where(np.isfinite(mat), mat * np.clip(jitter, 0.8, 1.2), mat)
        return mat

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    def successors(self) -> dict[int, list[int]]:
        succ: dict[int, list[int]] = {i: [] for i in range(self.num_tasks)}
        for i, t in enumerate(self.tasks):
            for d in t.deps:
                succ[d].append(i)
        return succ


def radar_correlator() -> AppDAG:
    """RC: FFT(x), FFT(ref) → spectral multiply (conj) → IFFT → peak detect."""
    tasks = [
        AppTask("fft_x", "fft_small"),
        AppTask("fft_ref", "fft_small"),
        AppTask("xcorr_mult", "mult", deps=[0, 1]),
        AppTask("ifft", "fft_small", deps=[2]),
        AppTask("peak_detect", "detect", deps=[3]),
    ]
    return AppDAG("RC", tasks, frame_kb=1280.0)


def temporal_mitigation() -> AppDAG:
    """TM: split signal, filter both arms, correlate, subtract, detect."""
    tasks = [
        AppTask("fft_sig", "fft_small"),
        AppTask("filter_a", "filter", deps=[0]),
        AppTask("filter_b", "filter", deps=[0]),
        AppTask("corr_mult", "mult", deps=[1, 2]),
        AppTask("ifft", "fft_small", deps=[3]),
        AppTask("subtract", "mult", deps=[4]),
        AppTask("detect", "detect", deps=[5]),
    ]
    return AppDAG("TM", tasks, frame_kb=1280.0)


def pulse_doppler(num_pulses: int = 64) -> AppDAG:
    """PD: range FFT per pulse → corner turn → Doppler FFT bank → CFAR detect.

    The classic pulse-Doppler cube: wide FFT fan-out (this is what makes it a
    *high-latency* app that floods the ready queue — the regime where the
    paper's hardware scheduler wins).
    """
    tasks: list[AppTask] = []
    for p in range(num_pulses):
        tasks.append(AppTask(f"range_fft_{p}", "fft_large"))
    ct = len(tasks)
    tasks.append(AppTask("corner_turn", "mult", deps=list(range(num_pulses))))
    for d in range(num_pulses):
        tasks.append(AppTask(f"doppler_fft_{d}", "fft_large", deps=[ct]))
    cfar_deps = list(range(ct + 1, ct + 1 + num_pulses))
    tasks.append(AppTask("cfar_detect", "detect", deps=cfar_deps))
    return AppDAG("PD", tasks, frame_kb=1037.0)


def wifi_tx(num_symbols: int = 16) -> AppDAG:
    """TX: scramble→encode→interleave→modulate per OFDM symbol, IFFT, CRC."""
    tasks: list[AppTask] = [AppTask("crc_scramble", "crc")]
    prev_chain_heads = []
    for s in range(num_symbols):
        e = len(tasks)
        tasks.append(AppTask(f"encode_{s}", "encode", deps=[0]))
        tasks.append(AppTask(f"interleave_{s}", "interleave", deps=[e]))
        tasks.append(AppTask(f"modulate_{s}", "modulate", deps=[e + 1]))
        tasks.append(AppTask(f"ifft_{s}", "fft_small", deps=[e + 2]))
        prev_chain_heads.append(e + 3)
    tasks.append(AppTask("frame_assemble", "mult", deps=prev_chain_heads))
    return AppDAG("TX", tasks, frame_kb=1037.0)


APPS: dict[str, AppDAG] = {}


def get_app(name: str) -> AppDAG:
    if name not in APPS:
        APPS.update({
            "RC": radar_correlator(),
            "TM": temporal_mitigation(),
            "PD": pulse_doppler(),
            "TX": wifi_tx(),
        })
    return APPS[name]


def paper_soc_pe_types() -> list[str]:
    """The paper's emulated SoC: 3× ARM Cortex-A53 + 1× FFT accelerator."""
    return [ARM, ARM, ARM, FFT_ACC]


def make_soc(num_arm: int, num_fft: int) -> list[str]:
    return list(itertools.chain([ARM] * num_arm, [FFT_ACC] * num_fft))


def low_latency_workload() -> list[str]:
    """Paper §V: twenty frames each of RC and TM."""
    return ["RC", "TM"] * 20


def high_latency_workload() -> list[str]:
    """Paper §V: ten instances each of PD and TX."""
    return ["PD", "TX"] * 10
