"""Discrete-event simulator of the CEDR runtime on a heterogeneous SoC.

Mirrors the paper's runtime environment (Section III-A): applications arrive
dynamically as DAG instances; the CEDR *management thread* (a single daemon
loop) parses incoming DAGs, performs task-completion bookkeeping, maintains
the ready queue, and — at each *mapping event* — invokes the scheduler over
the whole ready queue together with per-PE availability estimates.

Two modeling choices carry the paper's dynamics:

1. **The management thread is serial.** DAG parsing, dependency bookkeeping
   and scheduling compete for one loop.  Expensive mapping events delay
   everything behind them.

2. **Tasks stay in the ready queue until they begin execution.**  Every
   mapping event re-maps the *entire* backlog (this is what makes dynamic
   scheduling responsive — late-arriving high-priority tasks can jump ahead —
   and it is why the paper observes ready queues up to 1330 entries).  A PE
   that falls idle can only receive work at a mapping-event boundary, so the
   mapping-event latency directly gates PE utilization: with the software
   scheduler at large n this is milliseconds per event and throughput
   collapses; the hardware scheduler keeps events cheap.  This is the 26.7%
   achieved-frame-rate mechanism of Fig. 6.

The scheduler decision function is pluggable (HEFT_RT, round-robin,
earliest-idle-PE, random) and its overhead is modeled separately
(:mod:`repro.runtime.overhead`).  The dispatch fast path uses an early-exit
EFT loop that is prefix-identical to the full HEFT_RT assignment (it stops
once every idle PE has been claimed — later iterations cannot dispatch), so
simulated decisions are bit-identical to ``heft_rt_numpy`` / the Pallas
kernels while keeping multi-thousand-event sweeps fast.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass

import numpy as np

from repro.core import heft_rt_numpy  # noqa: F401 — re-exported oracle
from repro.runtime.apps import AppDAG, get_app
from repro.runtime.overhead import OverheadModel, ZERO_MODEL
from repro.sched_integration.fabric import MappingFabric, eft_dispatch_numpy

# event kinds
ARRIVAL, TASK_DONE, MGMT_DONE = 0, 1, 2

# management-thread costs (seconds) — CEDR bookkeeping on the A53
PARSE_COST_PER_TASK_S = 2.0e-6    # DAG parse/instantiate, per task
COMPLETION_COST_S = 8.0e-6        # per-completion dependency bookkeeping


# ---------------------------------------------------------------------------
# Dispatch policies.  Signature:
#   dispatch(avg[n], exec[n,P], avail[P], idle[P] bool) -> list[(i, pe)]
# returning ready-queue positions to start NOW on which idle PE.  Each idle PE
# may receive at most one task (it is busy afterwards).
# ---------------------------------------------------------------------------

def dispatch_heft_rt(avg, exec_times, avail, capacity):
    """Early-exit HEFT_RT: follow priority order + EFT chain, commit tasks to
    PEs with free worker-queue capacity, stop once no capacity remains.

    Identical to running the full ``heft_rt_numpy`` and committing, for each
    PE, the first ``capacity[pe]`` tasks assigned to it: the EFT availability
    chain is computed exactly as in the full algorithm, so committed
    decisions are bit-identical to the full scheduler / Pallas kernels.

    Implemented by the mapping fabric's host fast path
    (:func:`repro.sched_integration.fabric.eft_dispatch_numpy`); use
    :func:`make_dispatch_fabric` to route mapping events through the jitted
    or Pallas fabric backends instead.
    """
    return eft_dispatch_numpy(avg, exec_times, avail, capacity)


def make_dispatch_fabric(backend: str = "auto", **fabric_kw):
    """Dispatch factory routing mapping events through a
    :class:`~repro.sched_integration.fabric.MappingFabric` backend
    (``"numpy"``, ``"jit"``, or ``"pallas"``), batched/bucketed through the
    device pipeline for fleet-scale event streams.

    Fidelity caveat: the ``"numpy"`` backend is bit-identical to
    :func:`dispatch_heft_rt` for any float64 inputs; the device backends
    compute in float32, so their decisions match the oracle only when
    exec/avail values are exactly representable in f32 (EFT gaps below f32
    resolution can resolve differently).  Continuous-valued simulator
    workloads that need exact oracle decisions should keep
    ``backend="numpy"``."""
    fab: MappingFabric | None = None

    def dispatch(avg, exec_times, avail, capacity):
        nonlocal fab
        P = exec_times.shape[1]
        if fab is None:
            fab = MappingFabric(P, backend=backend, **fabric_kw)
        elif fab.num_pes != P:
            # elastic PE pool: resize in place (avail is explicit here, so
            # only the compiled-dispatch cache is worth preserving)
            fab.resize(P)
        return fab.dispatch(avg, exec_times, avail, capacity)

    return dispatch


def make_dispatch_round_robin():
    counter = itertools.count()

    def dispatch(avg, exec_times, avail, capacity):
        n, P = exec_times.shape
        out = []
        cap = capacity.copy()
        for i in range(n):
            if cap.sum() == 0:
                break
            for _ in range(P):
                pe = next(counter) % P
                if cap[pe] > 0 and np.isfinite(exec_times[i, pe]):
                    out.append((i, pe))
                    cap[pe] -= 1
                    break
        return out

    return dispatch


def dispatch_earliest_idle(avg, exec_times, avail, capacity):
    """FIFO ready queue onto free PEs, fastest-available first (no sort, no
    heterogeneity-aware EFT chain) — a baseline 'naive dynamic' scheduler."""
    out = []
    cap = capacity.copy()
    for i in range(exec_times.shape[0]):
        if cap.sum() == 0:
            break
        free = cap > 0
        cand = np.where(free & np.isfinite(exec_times[i]), exec_times[i], np.inf)
        pe = int(np.argmin(cand))
        if np.isfinite(cand[pe]):
            out.append((i, pe))
            cap[pe] -= 1
    return out


def make_dispatch_random(seed: int = 0):
    rng = np.random.default_rng(seed)

    def dispatch(avg, exec_times, avail, capacity):
        out = []
        cap = capacity.copy()
        for i in range(exec_times.shape[0]):
            if cap.sum() == 0:
                break
            sup = np.flatnonzero((cap > 0) & np.isfinite(exec_times[i]))
            if sup.size:
                pe = int(rng.choice(sup))
                out.append((i, pe))
                cap[pe] -= 1
        return out

    return dispatch


DISPATCHERS = {
    "heft_rt": lambda: dispatch_heft_rt,
    "heft_rt_fabric": make_dispatch_fabric,
    "round_robin": make_dispatch_round_robin,
    "earliest_idle": lambda: dispatch_earliest_idle,
    "random": make_dispatch_random,
}

# Backwards-compatible aliases used by tests/benchmarks.
DECIDERS = DISPATCHERS


@dataclass
class AppInstance:
    inst_id: int
    dag: AppDAG
    arrival: float
    exec_matrix: np.ndarray            # (T, P) seconds
    remaining_deps: np.ndarray         # (T,) int
    succ: dict[int, list[int]]
    first_start: float = np.inf
    last_finish: float = -np.inf
    cumulative_exec: float = 0.0
    tasks_done: int = 0

    @property
    def complete(self) -> bool:
        return self.tasks_done == self.dag.num_tasks


@dataclass
class SimResult:
    num_apps: int
    completed_apps: int
    app_exec_times: list[float]          # last-task-end − first-task-start
    app_latencies: list[float]           # completion − arrival
    cumulative_exec_times: list[float]   # Σ task exec on assigned PEs
    mapping_events: list[tuple[float, int, float]]  # (time, queue size, overhead)
    makespan: float
    first_arrival: float
    last_completion: float
    pe_busy_time: np.ndarray             # (P,) seconds of actual execution

    @property
    def achieved_frame_rate(self) -> float:
        span = self.last_completion - self.first_arrival
        return self.completed_apps / span if span > 0 else 0.0

    @property
    def avg_app_exec_time(self) -> float:
        return float(np.mean(self.app_exec_times)) if self.app_exec_times else np.nan

    @property
    def avg_cumulative_exec_time(self) -> float:
        return float(np.mean(self.cumulative_exec_times)) if self.cumulative_exec_times else np.nan

    @property
    def total_scheduling_overhead(self) -> float:
        return float(sum(o for _, _, o in self.mapping_events))

    @property
    def avg_queue_size(self) -> float:
        return float(np.mean([n for _, n, _ in self.mapping_events]))

    @property
    def max_queue_size(self) -> int:
        return max((n for _, n, _ in self.mapping_events), default=0)

    def pe_utilization(self) -> np.ndarray:
        span = max(self.makespan - self.first_arrival, 1e-12)
        return self.pe_busy_time / span


class CedrSimulator:
    """Event-driven model of CEDR's daemon (management thread) + workers."""

    def __init__(
        self,
        pe_types: list[str],
        dispatch=dispatch_heft_rt,
        overhead: OverheadModel = ZERO_MODEL,
        exec_noise: float | None = 0.03,
        seed: int = 0,
        worker_queue_depth: int = 1,
    ):
        self.pe_types = pe_types
        self.P = len(pe_types)
        self.dispatch = dispatch
        self.overhead = overhead
        self.rng = np.random.default_rng(seed)
        self.exec_noise = exec_noise
        # committed-but-unfinished tasks a worker may hold (running + queued).
        # Small in CEDR: workers pull from short to-do queues; everything not
        # yet committed stays in the ready queue and is re-mapped each event.
        self.worker_queue_depth = worker_queue_depth

    def run(self, arrivals: list[tuple[float, str]]) -> SimResult:
        P = self.P
        heap: list[tuple[float, int, int, object]] = []
        seq = itertools.count()

        def push(t, kind, payload=None):
            heapq.heappush(heap, (t, next(seq), kind, payload))

        for t, name in arrivals:
            push(t, ARRIVAL, name)

        instances: dict[int, AppInstance] = {}
        inst_counter = itertools.count()
        ready: list[tuple[int, int]] = []          # backlog until COMMITTED
        mgmt_queue: list[tuple[str, object]] = []  # serialized daemon work
        mgmt_busy = False
        dirty = False                              # re-map warranted?
        pe_running: list[tuple[int, int] | None] = [None] * P
        pe_fifo: list[list[tuple[int, int]]] = [[] for _ in range(P)]
        pe_busy_until = np.zeros(P)          # availability estimate (incl. FIFO)
        pe_busy_until_running = np.zeros(P)  # end time of the running task
        pe_busy_time = np.zeros(P)
        mapping_log: list[tuple[float, int, float]] = []
        depth = self.worker_queue_depth
        now = 0.0

        def start_task(iid: int, ti: int, pe: int, t: float) -> None:
            inst = instances[iid]
            dur = inst.exec_matrix[ti, pe]
            pe_running[pe] = (iid, ti)
            inst.first_start = min(inst.first_start, t)
            inst.cumulative_exec += dur
            pe_busy_time[pe] += dur
            push(t + dur, TASK_DONE, (iid, ti, pe))

        def refresh_estimate(pe: int, t: float) -> None:
            """T_avail estimate: running task's end + queued FIFO durations."""
            est = t
            run = pe_running[pe]
            if run is not None:
                est = max(est, pe_busy_until_running[pe])
            for iid, ti in pe_fifo[pe]:
                est += instances[iid].exec_matrix[ti, pe]
            pe_busy_until[pe] = est

        def commit_task(iid: int, ti: int, pe: int, t: float) -> None:
            """Worker-queue commit: start now if idle, else join the short FIFO."""
            if pe_running[pe] is None:
                start_task(iid, ti, pe, t)
                pe_busy_until_running[pe] = t + instances[iid].exec_matrix[ti, pe]
            else:
                pe_fifo[pe].append((iid, ti))
            refresh_estimate(pe, t)

        def mgmt_kick(t: float) -> None:
            nonlocal mgmt_busy, dirty
            if mgmt_busy:
                return
            if mgmt_queue:
                kind, payload = mgmt_queue.pop(0)
                if kind == "arrival":
                    dur = PARSE_COST_PER_TASK_S * get_app(payload).num_tasks
                else:  # completion
                    dur = COMPLETION_COST_S
                mgmt_busy = True
                push(t + dur, MGMT_DONE, (kind, payload))
            elif ready and dirty:
                # mapping event: the scheduler sees the whole ready queue
                n = len(ready)
                ex = np.stack([instances[i].exec_matrix[ti] for i, ti in ready])
                with np.errstate(invalid="ignore"):
                    avg = np.nanmean(np.where(np.isfinite(ex), ex, np.nan), axis=1)
                ov = self.overhead(n, avg, ex,
                                   np.maximum(pe_busy_until, t))
                mapping_log.append((t, n, ov))
                mgmt_busy = True
                dirty = False
                push(t + ov, MGMT_DONE, ("mapping", (avg, ex)))

        while heap:
            now, _, kind, payload = heapq.heappop(heap)

            if kind == ARRIVAL:
                mgmt_queue.append(("arrival", payload))
                mgmt_kick(now)

            elif kind == TASK_DONE:
                iid, ti, pe = payload
                inst = instances[iid]
                inst.tasks_done += 1
                inst.last_finish = max(inst.last_finish, now)
                pe_running[pe] = None
                if pe_fifo[pe]:  # workers drain their own short queue
                    niid, nti = pe_fifo[pe].pop(0)
                    start_task(niid, nti, pe, now)
                    pe_busy_until_running[pe] = now + instances[niid].exec_matrix[nti, pe]
                refresh_estimate(pe, now)
                dirty = True          # freed worker capacity warrants a re-map
                mgmt_queue.append(("completion", (iid, ti)))
                mgmt_kick(now)

            elif kind == MGMT_DONE:
                wkind, wpayload = payload
                mgmt_busy = False
                if wkind == "arrival":
                    dag = get_app(wpayload)
                    iid = next(inst_counter)
                    noise = self.rng if self.exec_noise else None
                    ex_ms = dag.exec_matrix(self.pe_types, noise=noise)
                    inst = AppInstance(
                        inst_id=iid, dag=dag, arrival=now,
                        exec_matrix=ex_ms * 1e-3,  # ms → seconds
                        remaining_deps=np.array([len(t.deps) for t in dag.tasks]),
                        succ=dag.successors(),
                    )
                    instances[iid] = inst
                    for ti in np.flatnonzero(inst.remaining_deps == 0):
                        ready.append((iid, int(ti)))
                        dirty = True
                elif wkind == "completion":
                    iid, ti = wpayload
                    inst = instances[iid]
                    for s in inst.succ[ti]:
                        inst.remaining_deps[s] -= 1
                        if inst.remaining_deps[s] == 0:
                            ready.append((iid, s))
                            dirty = True
                elif wkind == "mapping":
                    avg, ex = wpayload
                    # the queue may have grown since the snapshot; map the
                    # snapshot prefix (positions align: ready is append-only
                    # between snapshot and now)
                    n = ex.shape[0]
                    capacity = np.array([
                        depth - len(pe_fifo[p]) - (pe_running[p] is not None)
                        for p in range(P)
                    ], dtype=np.int64).clip(min=0)
                    avail = np.maximum(pe_busy_until, now)
                    committed = self.dispatch(avg, ex, avail, capacity)
                    for i, pe in sorted(committed, reverse=True):
                        iid, ti = ready[i]
                        commit_task(iid, ti, pe, now)
                        del ready[i]
                    if len(ready) > n - len(committed):
                        dirty = True  # new tasks appeared during mapping
                    if committed:
                        dirty = True  # chain: capacity may remain elsewhere
                mgmt_kick(now)

        completed = [i for i in instances.values() if i.complete]
        return SimResult(
            num_apps=len(instances),
            completed_apps=len(completed),
            app_exec_times=[i.last_finish - i.first_start for i in completed],
            app_latencies=[i.last_finish - i.arrival for i in completed],
            cumulative_exec_times=[i.cumulative_exec for i in completed],
            mapping_events=mapping_log,
            makespan=now,
            first_arrival=min((i.arrival for i in instances.values()), default=0.0),
            last_completion=max((i.last_finish for i in completed), default=0.0),
            pe_busy_time=pe_busy_time,
        )
