"""Workload generation — the paper's frame-based injection-rate methodology.

Section V: a *workload* is a sequence of application frames; the *injection
rate* (Mbps of input data entering the runtime) together with the per-frame
input size (Kb) fixes the frame arrival rate (frames/s).  The paper sweeps 29
injection rates and repeats each configuration 25 times.

  low-latency workload : 20 frames each of RC and TM, 1280 Kb/frame
  high-latency workload: 10 instances each of PD and TX, 1037 Kb/frame
"""

from __future__ import annotations

import numpy as np

from repro.runtime.apps import get_app, high_latency_workload, low_latency_workload


def frames_per_second(injection_mbps: float, frame_kb: float) -> float:
    """rate [Mb/s] × 1000 [Kb/Mb] ÷ frame size [Kb] = frames/s."""
    return injection_mbps * 1000.0 / frame_kb


def injection_mbps(frame_rate: float, frame_kb: float) -> float:
    return frame_rate * frame_kb / 1000.0


def make_arrivals(
    app_names: list[str],
    frame_rate: float,
    seed: int = 0,
    jitter: float = 0.1,
    repeats: int = 1,
) -> list[tuple[float, str]]:
    """Evenly spaced arrivals at ``frame_rate`` frames/s with mild jitter.

    ``repeats`` replays the workload back-to-back (steady-state statistics at
    a given rate, standing in for the paper's 25 repetitions per point).
    """
    rng = np.random.default_rng(seed)
    names = list(app_names) * repeats
    inter = 1.0 / frame_rate
    times = np.arange(len(names)) * inter
    if jitter > 0:
        times = times + rng.uniform(0, jitter * inter, len(names))
    return sorted(zip(times.tolist(), names), key=lambda x: x[0])


def low_latency_arrivals(frame_rate: float, seed: int = 0, repeats: int = 1):
    return make_arrivals(low_latency_workload(), frame_rate, seed, repeats=repeats)


def high_latency_arrivals(frame_rate: float, seed: int = 0, repeats: int = 1):
    return make_arrivals(high_latency_workload(), frame_rate, seed, repeats=repeats)


def paper_injection_sweep_mbps(n: int = 29, lo: float = 25.0, hi: float = 700.0) -> np.ndarray:
    """29 injection rates spanning under- to over-subscription (paper §V)."""
    return np.linspace(lo, hi, n)


def workload_frame_kb(kind: str) -> float:
    names = {"low": "RC", "high": "PD"}
    return get_app(names[kind]).frame_kb
