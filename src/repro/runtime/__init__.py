# CEDR-equivalent runtime environment: application DAGs, the discrete-event
# SoC simulator (daemon + worker threads + mapping events), workload
# generation, and the calibrated scheduling-overhead models.
from repro.runtime.apps import (
    AppDAG,
    get_app,
    high_latency_workload,
    low_latency_workload,
    make_soc,
    paper_soc_pe_types,
)
from repro.runtime.overhead import (
    HW_MODEL,
    SW_MODEL,
    ZERO_MODEL,
    OverheadModel,
    hw_compute_s,
    hw_overhead_s,
    hw_transfer_s,
    sw_overhead_s,
)
from repro.runtime.simulator import (
    DISPATCHERS,
    CedrSimulator,
    SimResult,
    dispatch_earliest_idle,
    dispatch_heft_rt,
    make_dispatch_fabric,
)
from repro.runtime.workload import (
    frames_per_second,
    high_latency_arrivals,
    injection_mbps,
    low_latency_arrivals,
    make_arrivals,
    paper_injection_sweep_mbps,
)

__all__ = [
    "AppDAG", "get_app", "high_latency_workload", "low_latency_workload",
    "make_soc", "paper_soc_pe_types",
    "HW_MODEL", "SW_MODEL", "ZERO_MODEL", "OverheadModel",
    "hw_compute_s", "hw_overhead_s", "hw_transfer_s", "sw_overhead_s",
    "DISPATCHERS", "CedrSimulator", "SimResult", "dispatch_earliest_idle",
    "dispatch_heft_rt", "make_dispatch_fabric",
    "frames_per_second", "high_latency_arrivals", "injection_mbps",
    "low_latency_arrivals", "make_arrivals", "paper_injection_sweep_mbps",
]
