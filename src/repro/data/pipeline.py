"""Deterministic, resumable, sharding-aware synthetic token pipeline.

Every batch is a pure function of (seed, step) — restart at step k reproduces
the exact stream (the checkpoint/restart invariant), and any host can
materialize any shard independently (multi-host readiness).  Tokens follow a
Zipf-like marginal with a Markov twist so MoE routers see realistic skewed
expert loads (feeding the HEFT_RT expert-placement integration) and the loss
actually decreases during the example training runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # stationary Zipf-ish distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self._probs = p / p.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """(tokens, labels) for ``step`` — pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xC0FFEE]))
        seq = rng.choice(cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1),
                         p=self._probs)
        # Markov twist: with prob .5 repeat-shift the previous token (+1 mod V)
        # so there is learnable next-token structure.
        rep = rng.random((cfg.global_batch, cfg.seq_len)) < 0.5
        nxt = (seq[:, :-1] + 1) % cfg.vocab_size
        seq[:, 1:] = np.where(rep, nxt, seq[:, 1:])
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def shard_at(self, step: int, shard: int, num_shards: int) -> dict[str, np.ndarray]:
        """Per-host slice of the global batch (batch-major contiguous)."""
        b = self.batch_at(step)
        n = self.cfg.global_batch
        lo, hi = shard * n // num_shards, (shard + 1) * n // num_shards
        return {k: v[lo:hi] for k, v in b.items()}
