"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init — the dry-run
sets XLA_FLAGS before any import for exactly this reason).
"""

from __future__ import annotations

import math

import numpy as np

import jax
from jax.sharding import Mesh

from repro.dist.sharding import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(*, multi_pod: bool = False) -> MeshAxes:
    return MeshAxes(pod="pod" if multi_pod else None)


def make_debug_mesh(shape=(2, 2), axes=("data", "model"), devices=None):
    """Small mesh for unit tests (requires enough local devices).

    ``devices`` pins an explicit device list (len == prod(shape)) — the
    building block for carving one host's pool into disjoint replica slices.
    """
    if devices is None:
        return jax.make_mesh(shape, axes)
    devs = np.asarray(devices, dtype=object).reshape(shape)
    return Mesh(devs, axes)


def slice_device_pool(shapes, axes=("data", "model"), devices=None, *,
                      allow_remainder: bool = True,
                      return_remainder: bool = False):
    """Partition a device pool into disjoint mesh slices, one per shape.

    The heterogeneous-fleet constructor: ``shapes=[(1, 1), (2, 1), (2, 2)]``
    carves 7 of the pool's devices into three replicas of mixed size (the
    paper's non-uniform PEs).  Slices never share devices; a pool too small
    for the requested shapes raises with the exact shortfall.

    Shapes that don't tile the pool leave devices over; those are no longer
    dropped silently: ``return_remainder=True`` returns ``(meshes,
    remainder)`` so the caller can re-carve the spare devices on a later
    resize event, and ``allow_remainder=False`` raises when any device would
    go unused (the strict fleet-spec contract).
    """
    pool = list(jax.devices()) if devices is None else list(devices)
    need = sum(math.prod(s) for s in shapes)
    if need > len(pool):
        raise ValueError(
            f"device pool oversubscribed: shapes {list(shapes)} need {need} "
            f"devices but the pool has only {len(pool)} ({need - len(pool)} "
            f"short) — drop a slice, shrink a shape, or grow the pool")
    meshes, off = [], 0
    for shape in shapes:
        n = math.prod(shape)
        meshes.append(make_debug_mesh(tuple(shape), axes, pool[off:off + n]))
        off += n
    remainder = pool[off:]
    if remainder and not allow_remainder:
        raise ValueError(
            f"shapes {list(shapes)} use {off} of {len(pool)} devices, "
            f"leaving {len(remainder)} unused — pass allow_remainder=True "
            f"to keep the spares (return_remainder=True hands them back "
            f"for re-carving)")
    if return_remainder:
        return meshes, remainder
    return meshes
