"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init — the dry-run
sets XLA_FLAGS before any import for exactly this reason).
"""

from __future__ import annotations

import jax

from repro.dist.sharding import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(*, multi_pod: bool = False) -> MeshAxes:
    return MeshAxes(pod="pod" if multi_pod else None)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires enough local devices)."""
    return jax.make_mesh(shape, axes)
