"""Training launcher — end-to-end driver (deliverable (b)).

CPU-scale run of any smoke config with full substrate (data pipeline, AdamW,
checkpointing/restart, deterministic resume):

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --steps 200
  PYTHONPATH=src python -m repro.launch.train --trace /tmp/train_trace.json

On a real multi-host TPU deployment, the same trainer runs under
``jax.distributed.initialize()`` with the production mesh from launch/mesh.py
and the sharding rules from dist/sharding.py (see launch/dryrun.py for the
exact pjit wiring proven by the 512-device dry-run).

``--trace OUT.json`` attaches a ``repro.obs`` Tracer + MetricsRegistry to
the Trainer (per-step spans, step-time histogram, cross-pod wire-byte
counters on pod meshes) and exports a Perfetto-loadable Chrome trace.
Verbosity is the ``REPRO_LOG`` env knob.
"""

from __future__ import annotations

import argparse

from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.obs import MetricsRegistry, Tracer, get_logger
from repro.obs.metrics import time_s
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import Trainer, TrainerConfig

log = get_logger("train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None,
                    help="raise after N steps to demo checkpoint/restart")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient accumulation factor")
    ap.add_argument("--mesh-shape", default=None,
                    help="comma-separated mesh over (pod,data,model) axes, "
                         "e.g. '2,2' — leading axis is the pod axis")
    ap.add_argument("--compress-pods", action="store_true",
                    help="int8 error-feedback cross-pod gradient reduction "
                         "(residual is checkpointed train-step state)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export a Chrome trace (Perfetto) of the run, with "
                         "the step-time/wire-byte metrics snapshot embedded")
    args = ap.parse_args()

    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)
    cfg = get_smoke_config(args.arch)
    log.info(f"arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
             f"mesh={mesh_shape} compress={args.compress_pods} "
             f"microbatches={args.microbatches}")
    tracer, metrics = ((Tracer(), MetricsRegistry()) if args.trace
                       else (None, None))
    trainer = Trainer(
        cfg,
        AdamWConfig(learning_rate=warmup_cosine(args.lr, 10, args.steps),
                    weight_decay=0.1),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        TrainerConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                      checkpoint_dir=args.ckpt_dir, log_every=10,
                      microbatches=args.microbatches, mesh_shape=mesh_shape,
                      compress_pods=args.compress_pods),
        tracer=tracer, metrics=metrics,
    )
    (_, _, history), dt = time_s(trainer.run,
                                 inject_failure_at=args.inject_failure_at)
    for step, loss in history:
        log.info(f"step {step:5d} loss {loss:.4f}")
    tok_s = args.steps * args.batch * args.seq / dt
    log.info(f"done: {dt:.1f}s, {tok_s:.0f} tok/s on CPU")
    if args.trace:
        tracer.export(args.trace, metrics=metrics)
        log.info(f"trace: {args.trace} ({len(tracer)} events, "
                 f"{len(metrics)} metrics)")


if __name__ == "__main__":
    main()
