"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices form the production meshes, every
cell's step function must lower AND compile, and the compiled artifact yields
the memory analysis (fits?) + cost analysis (FLOPs/bytes) + collective
schedule that feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, cached
"""

# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first backend initialization.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA hoists dtype converts of loop-invariant stacked buffers (saved
    # residuals, int8 optimizer moments) out of while loops, materializing
    # full f32 copies; disable those passes for honest memory analysis.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,convert-mover "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import all_arch_names, get_config          # noqa: E402
from repro.dist.hints import sharding_policy                  # noqa: E402
from repro.dist.sharding import (                             # noqa: E402
    activation_hint_policy,
    batch_pspec,
    cache_pspecs,
    named,
    opt_pspecs,
    param_pspecs,
)
from repro.launch.hlo_analysis import (                          # noqa: E402
    collective_stats,
    summarize_compiled,
)
from repro.launch.mesh import make_production_mesh, mesh_axes  # noqa: E402
from repro.launch.specs import (                               # noqa: E402
    build_step,
    input_specs,
    opt_config_for,
    runnable_shapes,
)
from repro.models.config import SHAPES                         # noqa: E402
from repro.models.model import param_specs as model_param_specs  # noqa: E402
from repro.obs import get_logger                               # noqa: E402
from repro.optim.adamw import init_opt_state                   # noqa: E402

log = get_logger("dryrun")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "experiments", "artifacts", "dryrun")

# collective_stats / summarize_compiled live in hlo_analysis (import-light:
# no XLA_FLAGS side effects) and are re-exported here for compatibility.

__all__ = ["collective_stats", "summarize_compiled", "dryrun_cell",
           "cell_path", "run_all"]


# ---------------------------------------------------------------------------


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                *, policy_override=None, fsdp: bool = True,
                fsdp_experts_only: bool = False,
                opt_2d: bool = False, cache_seq_shard: bool = False,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = mesh_axes(multi_pod=multi_pod)
    t0 = time.time()

    p_specs = model_param_specs(cfg)
    p_sh = named(mesh, param_pspecs(cfg, ax, fsdp=fsdp,
                                    fsdp_experts_only=fsdp_experts_only))
    ins = input_specs(cfg, shape)
    policy = dict(policy_override if policy_override is not None else
                  activation_hint_policy(cfg, ax, shape))
    policy["__mesh__"] = mesh

    step = build_step(cfg, shape)

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        o_specs = jax.eval_shape(lambda: init_opt_state(p_specs, opt_cfg))
        opt_param_specs = param_pspecs(cfg, ax, fsdp=fsdp,
                                       fsdp_experts_only=fsdp_experts_only)
        if opt_2d:
            # moments may shard on MORE axes than params (one reshard per
            # step vs per-layer weight gathers): fill the first free dim
            # with 'data' when the param spec doesn't use it.
            from jax.sharding import PartitionSpec as P

            def densify(spec, shape_leaf):
                shape = shape_leaf.shape
                entries = list(spec) + [None] * (len(shape) - len(tuple(spec)))
                used = set()
                for e in entries:
                    for a in (e if isinstance(e, tuple) else (e,)):
                        if a:
                            used.add(a)
                if ax.data in used:
                    return spec
                for i, e in enumerate(entries):
                    if e is None and shape[i] % 16 == 0:
                        entries[i] = ax.data
                        return P(*entries)
                return spec

            opt_param_specs = jax.tree.map(
                densify, opt_param_specs, p_specs,
                is_leaf=lambda x: isinstance(x, P))
        o_sh = named(mesh, opt_pspecs(opt_param_specs,
                                      opt_cfg.moment_dtype, ax,
                                      param_shapes=p_specs))
        b_sh = named(mesh, batch_pspec(ax, shape))
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        args = (p_specs, o_specs, ins["tokens"], ins["labels"])
    elif shape.kind == "prefill":
        c_sh = named(mesh, cache_pspecs(cfg, ax, shape))
        b_sh = named(mesh, batch_pspec(ax, shape))
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(None, c_sh))
        args = (p_specs, ins["tokens"])
    else:  # decode
        c_sh = named(mesh, cache_pspecs(cfg, ax, shape,
                                        seq_shard=cache_seq_shard))
        b_sh = named(mesh, batch_pspec(ax, shape))
        jitted = jax.jit(step,
                         in_shardings=(p_sh, c_sh, b_sh, None),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
        args = (p_specs, ins["caches"], ins["tokens"], ins["pos"])

    with jax.set_mesh(mesh), sharding_policy(policy):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    summary = summarize_compiled(compiled)   # XLA cost + collectives + roofline

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": mesh.devices.size,
        "fsdp": fsdp,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        **summary,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if verbose:
        weighted, mem_info = summary["weighted"], summary["memory"]
        log.info(f"{arch} × {shape_name} × {out['mesh']}: "
                 f"compile OK ({t_compile:.1f}s) "
                 f"wflops/dev={weighted['dot_flops_per_device']:.3e} "
                 f"argbytes/dev={mem_info.get('argument_size_in_bytes')} "
                 f"temp/dev={mem_info.get('temp_size_in_bytes')} "
                 f"wwire/dev={weighted['total_wire_bytes_per_device']:.3e}")
        # per-cell memory analyses are diagnostics: REPRO_LOG=debug only
        log.debug("%s", compiled.memory_analysis())
    return out


def cell_path(arch: str, shape_name: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "multi" if multi_pod else "single"
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}_{shape_name}_{mesh}{suffix}.json")


def run_all(archs=None, shapes=None, meshes=("single", "multi"),
            force: bool = False) -> list[dict]:
    results = []
    for arch in (archs or all_arch_names()):
        cfg = get_config(arch)
        for shape_name in (shapes or runnable_shapes(cfg)):
            if shape_name not in runnable_shapes(cfg):
                continue
            for mesh_kind in meshes:
                multi = mesh_kind == "multi"
                path = cell_path(arch, shape_name, multi)
                if os.path.exists(path) and not force:
                    with open(path) as f:
                        results.append(json.load(f))
                    continue
                try:
                    res = dryrun_cell(arch, shape_name, multi)
                except Exception as e:  # a failing cell is a bug — record it
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    log.error(f"FAILED {arch} × {shape_name} × {mesh_kind}: {e}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                results.append(res)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    if args.all:
        meshes = ("single", "multi") if args.mesh == "both" else \
            (args.mesh,) if args.mesh != "both" else ("single", "multi")
        run_all(archs=[args.arch] if args.arch else None,
                shapes=[args.shape] if args.shape else None,
                meshes=("single", "multi"), force=args.force)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        res = dryrun_cell(args.arch, args.shape, mk == "multi",
                          fsdp=not args.no_fsdp)
        with open(cell_path(args.arch, args.shape, mk == "multi"), "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
