"""Serving launcher — batched-request demo with the HEFT_RT front end.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --requests 12

Builds a small heterogeneous "fleet" of replicas of a smoke-config model
(speed factors emulate mixed pods), maps dynamically arriving requests with
HEFT_RT, and reports per-replica distribution + wall time.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serve import HeftFrontEnd, ReplicaHandle, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.key(0), cfg)
    print(f"[serve] arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"replicas={args.replicas}")

    speeds = [1.0, 0.7, 1.4][: args.replicas] or [1.0]
    fleet = [ReplicaHandle(f"replica{i}(x{s})",
                           ServeEngine(cfg, params, max_len=128), speed=s)
             for i, s in enumerate(speeds)]
    front = HeftFrontEnd(fleet)

    rng = np.random.default_rng(0)
    requests = [
        (rng.integers(0, cfg.vocab_size, rng.integers(8, 48)).astype(np.int32),
         args.new_tokens)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs, counts = front.run_batch(requests)
    dt = time.time() - t0
    print(f"[serve] {len(outs)} requests in {dt:.2f}s "
          f"({sum(len(p)+args.new_tokens for p,_ in requests)/dt:.0f} tok/s)")
    print(f"[serve] request distribution (HEFT_RT): {counts}")
    print(f"[serve] sample output ids: {outs[0][0, -8:].tolist()}")


if __name__ == "__main__":
    main()
