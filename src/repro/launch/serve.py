"""Serving launcher — batched-request demo with the HEFT_RT front end.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --requests 12
  PYTHONPATH=src python -m repro.launch.serve --paged      # continuous batching
  PYTHONPATH=src python -m repro.launch.serve --sharded    # mesh-backed fleet
  PYTHONPATH=src python -m repro.launch.serve --trace /tmp/serve_trace.json

``--paged`` serves through the block-paged KV pool (``serve/paging.py``):
requests are HEFT_RT-mapped and then *admitted into the running batch* at
each decode tick (``--max-batch`` slots, ``--page-size``-token pages;
``--num-pages`` below full occupancy exercises admission queueing), and
request 0 is verified token-identical to the dense oracle.  See
docs/serving.md for the design.

Default mode builds a small heterogeneous "fleet" of replicas of a
smoke-config model (speed factors emulate mixed pods).  ``--sharded`` carves
the local device pool into mesh slices instead (``--mesh-shapes 1x1,2x1,2x2``
with enough devices, e.g. under ``XLA_FLAGS=--xla_force_host_platform_
device_count=8``): each replica is a real ``repro.dist`` substrate and the
HEFT_RT front end maps requests across the heterogeneous slices.

``--reshard-to 2x2`` (with ``--sharded``) demonstrates the elastic path:
after the first batch, replica 0 migrates *live* onto a new slice carved
from the pool's leftover devices (``ServeEngine.reshard`` — params move in
memory, no checkpoint), then serves the same requests again; outputs are
verified token-identical across the migration.

``--chaos TRACE.json`` replays a schema-validated failure timeline
(``replica_loss`` / ``straggler`` / ``link_degrade`` / ``link_partition``;
see ``repro.sched_integration.fleet.validate_failure_timeline``) against a
simulator twin of the fleet, reports goodput (requests served inside the
SLO) as a percentage of the failure-free run, and demonstrates live
failover: the first lost replica is removed from the front end and the same
requests re-serve token-identically on the survivors.  Goodput below
``--min-goodput`` (or a failover mismatch) exits non-zero.  Replica targets
in the trace may be unique name *prefixes* of fleet replicas.

``--trace OUT.json`` turns on the full observability stack — a
``repro.obs`` Tracer + MetricsRegistry attached to the front end and every
engine, with the HEFT_RT mapping routed through an instrumented
``MappingFabric`` (decision spans, per-decision latency histogram,
device-resident scheduler counters) — and exports a Perfetto-loadable
Chrome trace with the metrics snapshot embedded.  Output verbosity is the
``REPRO_LOG`` env knob (debug/info/warning/error/silent).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.obs import MetricsRegistry, Tracer, get_logger
from repro.obs.metrics import time_s
from repro.serve import HeftFrontEnd, ReplicaHandle, ServeEngine, mesh_backed_fleet

log = get_logger("serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching: serve through the block-paged "
                         "KV pool (ServeEngine.admit/decode_tick/retire; "
                         "see docs/serving.md), verifying request 0 "
                         "token-identical to the dense oracle")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="with --paged: concurrent batch slots per replica")
    ap.add_argument("--page-size", type=int, default=16,
                    help="with --paged: KV page size in tokens (must divide "
                         "the engine max_len)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="with --paged: pool pages per replica (default: "
                         "full occupancy; lower exercises admission "
                         "queueing)")
    ap.add_argument("--fused-scheduler", action="store_true",
                    help="with --paged: run the HEFT_RT admission decision "
                         "inside the decode tick's compiled program "
                         "(MappingFabric backend='fused'; zero host "
                         "scheduling round-trips at steady state — "
                         "docs/scheduling.md)")
    ap.add_argument("--sharded", action="store_true",
                    help="back replicas with mesh slices of the device pool")
    ap.add_argument("--mesh-shapes", default="1x1",
                    help="comma-separated slice shapes for --sharded, "
                         "e.g. 1x1,2x1,2x2")
    ap.add_argument("--reshard-to", default=None, metavar="AxB",
                    help="with --sharded: after serving, migrate replica 0 "
                         "live onto a slice of this shape carved from the "
                         "leftover devices, and re-verify outputs")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export a Chrome trace (Perfetto) of the run, with "
                         "the metrics snapshot and drained device counters "
                         "embedded")
    ap.add_argument("--chaos", default=None, metavar="TRACE.json",
                    help="replay a schema-validated failure timeline against "
                         "a simulator twin of the fleet and demo live "
                         "failover; exits non-zero below --min-goodput")
    ap.add_argument("--min-goodput", type=float, default=90.0,
                    help="minimum chaos goodput as percent of the "
                         "failure-free run (default 90)")
    ap.add_argument("--slo-s", type=float, default=2.0,
                    help="per-request latency SLO for the goodput metric")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.key(0), cfg)
    log.info(f"arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
             f"devices={jax.device_count()}")

    tracer, metrics = (Tracer(), MetricsRegistry()) if args.trace else (None, None)

    spare = []
    if args.sharded:
        shapes = [tuple(int(d) for d in s.split("x"))
                  for s in args.mesh_shapes.split(",")]
        fleet, spare = mesh_backed_fleet(cfg, params, shapes, max_len=128,
                                         return_spare=True)
        log.info(f"mesh-backed fleet: {[r.mesh_shape for r in fleet]} slices "
                 f"({len(spare)} spare devices)")
    else:
        speeds = [1.0, 0.7, 1.4][: args.replicas] or [1.0]
        fleet = [ReplicaHandle(f"replica{i}(x{s})",
                               ServeEngine(cfg, params, max_len=128), speed=s)
                 for i, s in enumerate(speeds)]

    fabric = None
    if args.fused_scheduler and not args.paged:
        raise SystemExit("--fused-scheduler requires --paged")
    if args.trace or args.fused_scheduler:
        # Route mapping events through a fabric: with --trace, decision
        # spans + the per-decision latency histogram + device-resident
        # counters (the numpy backend's decisions are bit-identical to the
        # heft_rt_numpy path this launcher uses untraced); with
        # --fused-scheduler, the fused backend whose registers the paged
        # decode tick consumes in-program (docs/scheduling.md).
        from repro.sched_integration.fabric import MappingFabric

        backend = "fused" if args.fused_scheduler else "numpy"
        fabric = MappingFabric(len(fleet), backend=backend, tracer=tracer,
                               metrics=metrics, device_counters=True)
        if args.fused_scheduler:
            log.info(f"fused scheduler: fabric backend={backend} "
                     f"(effective {fabric.backend_effective})")
        if args.trace:
            for r in fleet:
                r.engine.tracer = tracer
    front = HeftFrontEnd(fleet, fabric=fabric, tracer=tracer, metrics=metrics)

    rng = np.random.default_rng(0)
    requests = [
        (rng.integers(0, cfg.vocab_size, rng.integers(8, 48)).astype(np.int32),
         args.new_tokens)
        for _ in range(args.requests)
    ]
    if args.paged:
        # Continuous batching: requests join/leave the running batch at the
        # admission tick instead of queueing behind whole generations.
        # Stagger arrivals so later requests land while decode ticks are in
        # flight — the steady-state case the fused scheduler exists for
        # (tick-0 arrivals are cold-start and take the host path).
        arrivals = [min(i, 2 * args.new_tokens // 3)
                    for i in range(len(requests))]
        (seqs, stats), dt = time_s(
            front.run_continuous, requests, arrival_ticks=arrivals,
            max_batch=args.max_batch,
            page_size=args.page_size, num_pages=args.num_pages)
        outs = [s[None, :] for s in seqs]      # run_batch-shaped, for demos
        counts = stats["processed"]
        log.info(f"{len(outs)} requests in {dt:.2f}s paged "
                 f"({sum(len(p)+args.new_tokens for p,_ in requests)/dt:.0f} "
                 f"tok/s, {stats['ticks']} ticks, "
                 f"{stats['allocated']} pages allocated == "
                 f"{stats['freed']} freed)")
        if args.fused_scheduler:
            log.info(f"scheduling decisions: {stats['fused_decisions']} "
                     f"fused in-tick, {stats['host_decisions']} host "
                     f"(cold-start/idle)")
        oracle = front.replicas[0].engine.generate(requests[0][0][None, :],
                                                   requests[0][1])
        if not np.array_equal(outs[0], oracle):
            raise SystemExit("paged output diverged from the dense oracle")
        log.info("request 0 verified token-identical to the dense oracle")
    else:
        (outs, counts), dt = time_s(front.run_batch, requests)
        log.info(f"{len(outs)} requests in {dt:.2f}s "
                 f"({sum(len(p)+args.new_tokens for p,_ in requests)/dt:.0f} tok/s)")
    log.info(f"request distribution (HEFT_RT): {counts}")
    log.info(f"sample output ids: {outs[0][0, -8:].tolist()}")

    if args.reshard_to:
        if not args.sharded:
            raise SystemExit("--reshard-to requires --sharded")
        from repro.launch.mesh import make_debug_mesh

        shape = tuple(int(d) for d in args.reshard_to.split("x"))
        need = int(np.prod(shape))
        if len(spare) < need:
            raise SystemExit(
                f"--reshard-to {args.reshard_to} needs {need} spare devices, "
                f"pool has {len(spare)} left after the fleet slices")
        target = make_debug_mesh(shape, devices=spare[:need])
        old = fleet[0].mesh_shape
        fleet[0].engine.reshard(target)
        fleet[0].sync_mesh_identity()     # speed/rates follow the new slice
        log.info(f"replica 0 resharded live: {old} -> "
                 f"{fleet[0].mesh_shape} (speed x{fleet[0].speed:.0f})")
        outs2, _ = front.run_batch(requests)
        same = all(np.array_equal(a, b) for a, b in zip(outs, outs2))
        log.info(f"post-reshard outputs "
                 f"{'token-identical' if same else 'MISMATCH'}")
        if not same:
            raise SystemExit(1)     # the verification must fail loudly

    if args.chaos:
        _run_chaos(args, front, requests, outs, tracer, metrics)

    if args.trace:
        # Drained device counters land in the metrics snapshot next to the
        # latency histograms, so one artifact carries the whole picture.
        for name, value in fabric.drain_counters().items():
            metrics.gauge("fabric.device", counter=name).set(value)
        tracer.export(args.trace, metrics=metrics)
        log.info(f"trace: {args.trace} ({len(tracer)} events, "
                 f"{len(metrics)} metrics)")


def _resolve_targets(timeline, names):
    """Resolve replica-kind targets against the fleet, accepting unique name
    prefixes (so a generic trace says ``replica1`` and matches
    ``replica1(x0.7)``).  Link targets pass through untouched."""
    from repro.sched_integration import FailureEvent

    out = []
    for e in timeline:
        if e.kind in ("replica_loss", "straggler"):
            hits = [n for n in names
                    if n == e.target or n.startswith(e.target)]
            if len(hits) != 1:
                raise SystemExit(
                    f"chaos target {e.target!r} matches "
                    f"{hits or 'no replicas'} in {names}")
            if hits[0] != e.target:
                e = FailureEvent(e.t, e.kind, hits[0], e.duration_s,
                                 e.factor, e.reason)
        out.append(e)
    return out


def _run_chaos(args, front, requests, outs, tracer, metrics) -> None:
    """The --chaos path: simulator-twin goodput gate + live failover demo."""
    from repro.sched_integration import (
        POLICIES, Replica, goodput, load_failure_timeline, make_requests,
        simulate_serving, spine_topology)

    timeline = load_failure_timeline(args.chaos)
    names = [r.name for r in front.replicas]
    timeline = _resolve_targets(timeline, names)

    # Simulator twin: aggregate rates follow each handle's speed, scaled to
    # pod-class capacity (a speed-1.0 replica ≈ a 256-chip v5e slice at 50%
    # MFU), so the timeline replays against the live fleet's relative
    # capacities at serving-realistic service times.  The offered load sits
    # at ~60% of fleet capacity — the N+1 headroom a production fleet
    # carries — so the goodput gate measures *recovery*, not the bare
    # arithmetic of lost capacity.
    twin = [Replica(r.name, 25000.0 * r.speed, 126000.0 * r.speed)
            for r in front.replicas]
    rate = 24.0 * sum(r.speed for r in front.replicas)
    topo = None
    if any(e.kind in ("link_degrade", "link_partition") for e in timeline):
        # One pod per replica behind a shared spine — the maximally
        # contended fabric; link targets address "podI:spine".
        pod_of = {r.name: f"pod{i}" for i, r in enumerate(twin)}
        topo = spine_topology(["gw"] + sorted(set(pod_of.values())), 100.0,
                              pod_of=pod_of, gateway="gw")
    load = make_requests(rate, 2.0, seed=0)
    clean = simulate_serving(twin, load, POLICIES["heft_rt"](),
                             active_params=7e9)
    chaos = simulate_serving(twin, load, POLICIES["heft_rt"](),
                             active_params=7e9, failure_events=timeline,
                             topology=topo, tracer=tracer, metrics=metrics)
    g_clean = goodput(clean, load, args.slo_s)
    g_chaos = goodput(chaos, load, args.slo_s)
    pct = 100.0 * g_chaos / max(g_clean, 1)
    requeued = int(chaos.requeued.sum())
    unserved = int((~chaos.served_mask).sum())
    log.info(f"chaos: {len(timeline)} failures, goodput {g_chaos}/{g_clean} "
             f"({pct:.1f}% of failure-free), {requeued} re-queued, "
             f"{unserved} unserved")

    # Live failover: kill the first lost replica on the real front end and
    # re-serve the same requests — token-identical on the survivors proves
    # no request depends on the dead engine.
    losses = [e for e in timeline if e.kind == "replica_loss"]
    if losses and len(front.replicas) > 1:
        gone = front.remove_replica(losses[0].target)
        outs2, _ = front.run_batch(requests)
        same = all(np.array_equal(a, b) for a, b in zip(outs, outs2))
        log.info(f"failover: lost {gone.name}, re-served "
                 f"{len(outs2)} requests on {len(front.replicas)} survivors "
                 f"({'token-identical' if same else 'MISMATCH'})")
        if not same:
            raise SystemExit(1)

    if pct < args.min_goodput:
        raise SystemExit(
            f"chaos goodput {pct:.1f}% below --min-goodput "
            f"{args.min_goodput}%")


if __name__ == "__main__":
    main()
