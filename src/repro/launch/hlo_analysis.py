"""Trip-weighted HLO analysis for the roofline (§Roofline methodology).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified empirically — a scan of 10 matmuls reports the FLOPs of
one), so per-op metrics must be weighted by execution counts.  All loops in
this codebase lower from ``lax.scan``/static ``fori_loop``, so every while
condition compares the induction variable against a CONSTANT bound that we can
parse from the HLO text.

The analyzer:
  1. splits the partitioned module into computations;
  2. builds the call graph (while body/condition, fusion/call `calls=`,
     conditional branches);
  3. assigns each computation an execution count = Σ over callers of
     caller_count × (trip count for while bodies, 1 otherwise);
  4. counts, with weights:
       * dot FLOPs: 2 × prod(output dims) × prod(lhs contracting dims),
       * dot memory traffic: operand + result bytes (the matmul-stream
         proxy for the roofline memory term),
       * collective wire bytes by op kind (all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute).

Shapes in the partitioned module are PER-DEVICE, so all outputs are
per-device quantities.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE = re.compile(r"while\(.*?\)"
                    r".*?condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BRANCH = re.compile(r"(?:true_computation|false_computation|"
                          r"branch_computations=\{)[^,}]*%?([\w\.\-]+)")
_CONST_BOUND = re.compile(r"s32\[\]\S*\s+constant\((\d+)\)")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DOT = re.compile(r"=\s+(\w+)\[([\d,]*)\]\S*\s+dot\((.*?)\),")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int | None:
    bounds = []
    for line in cond_lines:
        for m in _CONST_BOUND.finditer(line):
            bounds.append(int(m.group(1)))
    return max(bounds) if bounds else None


def analyze_hlo(hlo: str, unknown_trip: int = 1) -> dict:
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k]))

    # call edges: (caller, callee, multiplier)
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    unknown_trips = 0
    for name, lines in comps.items():
        for line in lines:
            mw = _WHILE.search(line)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                trip = _trip_count(comps.get(cond, []))
                if trip is None:
                    trip = unknown_trip
                    unknown_trips += 1
                edges[name].append((body, float(max(trip, 1))))
                edges[name].append((cond, float(max(trip, 1))))
                continue
            mc = _CALLS.search(line)
            if mc and mc.group(1) in comps:
                edges[name].append((mc.group(1), 1.0))
            for mb in _COND_BRANCH.finditer(line):
                if mb.group(1) in comps:
                    edges[name].append((mb.group(1), 1.0))

    # propagate execution counts (call graph is a DAG)
    count: dict[str, float] = defaultdict(float)
    count[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):  # BFS in call order; DAG ⇒ revisit-safe accumulation
        i += 1
    # topological accumulation via repeated relaxation (small graphs)
    for _ in range(len(comps)):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for caller, callees in edges.items():
            if count.get(caller, 0) <= 0:
                continue
            for callee, mult in callees:
                new[callee] += count[caller] * mult
        new[entry] = 1.0
        if dict(new) != dict(count):
            count = new
            changed = True
        if not changed:
            break

    # definition map: op name → (dtype, dims); HLO op names are unique
    # module-wide in practice (suffix counters), so one global map suffices.
    defs: dict[str, tuple[str, str]] = {}
    _DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
    for lines in comps.values():
        for line in lines:
            m = _DEF.match(line)
            if m:
                defs[m.group(1)] = (m.group(2), m.group(3))

    _OPERANDS = re.compile(r"%([\w\.\-]+)")
    flops = 0.0
    dot_bytes = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, int] = defaultdict(int)
    for name, lines in comps.items():
        w = count.get(name, 0.0)
        if w <= 0:
            continue
        for line in lines:
            md = _DOT.search(line)
            if md:
                out_elems = 1
                for d in md.group(2).split(","):
                    if d:
                        out_elems *= int(d)
                op_names = _OPERANDS.findall(md.group(3))
                mc = _CONTRACT.search(line)
                k = 1
                if mc and op_names and op_names[0] in defs:
                    lhs_dims = [int(d) for d in defs[op_names[0]][1].split(",")
                                if d]
                    for ci in mc.group(1).split(","):
                        if ci:
                            k *= lhs_dims[int(ci)]
                flops += w * 2.0 * out_elems * k
                operand_bytes = sum(
                    _bytes_of(*defs[n]) for n in op_names[:2] if n in defs)
                dot_bytes += w * (_bytes_of(md.group(1), md.group(2))
                                  + operand_bytes)
                continue
            mcoll = _COLL.search(line)
            if mcoll:
                tuple_part, single, op = mcoll.groups()
                text = tuple_part if tuple_part else single
                size = sum(_bytes_of(dt, dd)
                           for dt, dd in _SHAPE.findall(text))
                coll_bytes[op] += w * size * _WIRE_FACTOR[op]
                coll_count[op] += 1

    return {
        "dot_flops_per_device": flops,
        "dot_bytes_per_device": dot_bytes,
        "collective_bytes_by_op": dict(coll_bytes),
        "collective_op_defs": dict(coll_count),
        "total_wire_bytes_per_device": sum(coll_bytes.values()),
        "num_computations": len(comps),
        "unknown_trip_whiles": unknown_trips,
    }


# ---------------------------------------------------------------------------
# unweighted collective inventory + compiled-step summary
# ---------------------------------------------------------------------------

def collective_stats(hlo_text: str) -> dict:
    """Unweighted collective inventory: wire bytes + op counts, body-once.

    The companion to :func:`analyze_hlo` (which trip-weights): one entry per
    collective *definition* in the partitioned module, using the same ring
    wire-byte conventions.  Import-light (pure regex) so tests and the
    serve cost-model can use it without the dry-run's XLA_FLAGS side
    effects.
    """
    per_op: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL.finditer(hlo_text):
        tuple_part, single, op = m.group(1), m.group(2), m.group(3)
        text = tuple_part if tuple_part else single
        size = sum(_bytes_of(d, dims) for d, dims in _SHAPE.findall(text))
        per_op[op] = per_op.get(op, 0.0) + size * _WIRE_FACTOR[op]
        count[op] = count.get(op, 0) + 1
    return {"bytes_by_op": per_op,
            "count_by_op": count,
            "total_wire_bytes_per_device": sum(per_op.values())}


_MEM_FIELDS = ("temp_size_in_bytes", "argument_size_in_bytes",
               "output_size_in_bytes", "alias_size_in_bytes",
               "generated_code_size_in_bytes")


def summarize_compiled(compiled) -> dict:
    """Cost summary of one compiled step: XLA memory/cost analyses plus the
    collective inventory and trip-weighted roofline terms.

    The shared back-end of ``dryrun_cell`` and the tiny-mesh tests: the
    returned ``flops_per_device`` / ``bytes_accessed_per_device`` /
    ``collectives`` keys are exactly what
    :meth:`repro.sched_integration.cost_model.CostCell.from_dryrun` consumes.
    """
    mem = compiled.memory_analysis()
    mem_info = {k: getattr(mem, k, None) for k in _MEM_FIELDS}

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    cost = cost or {}

    hlo = compiled.as_text()
    return {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "weighted": analyze_hlo(hlo),
        "collectives": collective_stats(hlo),
        "memory": mem_info,
        "hlo_chars": len(hlo),
    }
