"""Input specs + step builders for every (arch × shape) dry-run cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for every
model input (no device allocation).  ``build_step`` returns the function that
each shape kind lowers:

  train_4k    → full train_step: loss + grad (remat) + AdamW update
  prefill_32k → prefill_step: forward + KV/state-cache fill + last logits
  decode_*    → serve_step: ONE new token against a seq_len cache

Modality note ([audio]/[vlm]): the frontend is a stub — specs feed token ids
(EnCodec/VQ codes); precomputed frame/patch embeddings would enter through
the same embedding-table path.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.model import cache_specs, decode_step, loss_fn, prefill_step
from repro.optim.adamw import AdamWConfig, adamw_update


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """Moment precision policy: int8 blockwise for ≥100B models (fits HBM at
    256 chips — see optim/adamw.py), f32 otherwise."""
    big = cfg.param_count() >= 100e9
    return AdamWConfig(learning_rate=1e-4, weight_decay=0.1,
                       moment_dtype="int8" if big else "float32")


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token with a KV/state cache of seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "caches": cache_specs(cfg, B, S),
    }


def build_step(cfg: ModelConfig, shape: ShapeConfig, opt_cfg: AdamWConfig | None = None):
    """Returns (step_fn, arg_order) where step_fn takes the input_specs fields
    (plus params/opt_state for train, params for serving) positionally."""
    if shape.kind == "train":
        opt_cfg = opt_cfg or opt_config_for(cfg)

        def train_step(params, opt_state, tokens, labels):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels, cfg)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {"loss": loss, **om}

        return train_step

    if shape.kind == "prefill":
        def prefill(params, tokens):
            # static-trip attention loops → analyzable HLO while bounds
            return prefill_step(params, tokens, cfg, differentiable=True)
        return prefill

    def serve_step(params, caches, tokens, pos):
        return decode_step(params, caches, tokens, pos, cfg)
    return serve_step


def runnable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k needs sub-quadratic attention: SSM/hybrid only (the 8
    full-attention skips are documented in DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    kinds = {cfg.layer_kind(i) for i in range(cfg.num_layers)}
    if kinds == {"mamba"} or "mamba" in kinds:
        out.append("long_500k")
    return out
