"""Training loop: jit'd train step (grad-accum, optional cross-pod int8
gradient compression), checkpoint/restart orchestration.

``make_train_step`` builds the pjit-able step used both by the CPU examples
and the 512-device dry-run; ``Trainer`` adds the fault-tolerance loop around
it (periodic async checkpoints, exact restart from the latest checkpoint, a
deterministic step-indexed data stream so restarts replay nothing).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.dist.compression import compressed_psum_mean, psum_mean
from repro.models.config import ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    pod_axis: str | None = None,
                    compress_pods: bool = False,
                    mesh=None):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    * ``microbatches > 1``: gradient accumulation via lax.scan over batch
      slices (sum of per-micro grads, normalized once).
    * ``pod_axis`` + ``compress_pods``: gradients are computed per-pod inside
      a shard_map manual over the pod axis (everything else stays GSPMD-auto)
      and mean-reduced cross-pod with the int8+error-feedback collective.
    """

    def grads_of(params, tokens, labels):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels, cfg)
            return loss, metrics, grads

        B = tokens.shape[0]
        assert B % microbatches == 0
        mb = B // microbatches
        tk = tokens.reshape(microbatches, mb, -1)
        lb = labels.reshape(microbatches, mb, -1)

        def micro(carry, xs):
            g_acc, l_acc = carry
            t, l = xs
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, t, l, cfg)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, ltot), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), (tk, lb))
        g = jax.tree.map(lambda x: x / microbatches, g)
        return ltot / microbatches, {}, g

    def plain_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch["tokens"], batch["labels"])
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}

    if pod_axis is None:
        return plain_step

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    reduce_fn = compressed_psum_mean if compress_pods else \
        (lambda t, ax, e=None: (psum_mean(t, ax), e))

    def pod_step(params, opt_state, batch):
        def body(params, opt_state, tokens, labels):
            loss, metrics, grads = grads_of(params, tokens, labels)
            grads, _ = reduce_fn(grads, pod_axis)
            loss = jax.lax.pmean(loss, pod_axis)
            # per-pod metrics (ce, MoE aux) must leave the manual region
            # replicated — the P() out_spec below asserts replication.
            metrics = jax.tree.map(lambda v: jax.lax.pmean(v, pod_axis),
                                   metrics)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {"loss": loss, **metrics, **om}

        pspec = jax.tree.map(lambda _: P(), params)
        ospec = jax.tree.map(lambda _: P(), opt_state)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(pspec, ospec, P(pod_axis, None), P(pod_axis, None)),
            # P() is a pytree *prefix*: it covers whatever metric keys the
            # model emits (ce, aux_loss, expert_load, ...), all replicated.
            out_specs=(pspec, ospec, P()),
            check_rep=False,
            auto=frozenset(ax for ax in mesh.axis_names if ax != pod_axis))
        return fn(params, opt_state, batch["tokens"], batch["labels"])

    return pod_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    """Single-process training driver with checkpoint/restart fault tolerance."""

    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.pipeline = TokenPipeline(data_cfg)
        self.ckpt = Checkpointer(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    def init_or_restore(self):
        params = init_params(jax.random.key(self.tcfg.seed), self.cfg)
        opt_state = init_opt_state(params, self.opt_cfg)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
        return params, opt_state, start

    def run(self, steps: int | None = None, inject_failure_at: int | None = None):
        """Run to total_steps (resuming if checkpoints exist).

        ``inject_failure_at``: raise after that many NEW steps — used by the
        fault-tolerance tests/examples to prove bitwise-exact restart.
        """
        params, opt_state, start = self.init_or_restore()
        total = steps if steps is not None else self.tcfg.total_steps
        history = []
        done = 0
        for step in range(start, total):
            batch = self.pipeline.batch_at(step)
            params, opt_state, metrics = self.step_fn(
                params, opt_state,
                {k: jnp.asarray(v) for k, v in batch.items()})
            if (step + 1) % self.tcfg.checkpoint_every == 0 or step + 1 == total:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
            if (step + 1) % self.tcfg.log_every == 0 or step + 1 == total:
                history.append((step + 1, float(metrics["loss"])))
            done += 1
            if inject_failure_at is not None and done >= inject_failure_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step + 1}")
        self.ckpt.wait()
        return params, opt_state, history
