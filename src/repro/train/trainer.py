"""Training loop: jit'd train step (grad-accum, optional cross-pod int8
gradient compression), checkpoint/restart orchestration.

``make_train_step`` builds the pjit-able step used both by the CPU examples
and the 512-device dry-run; ``Trainer`` adds the fault-tolerance loop around
it (periodic async checkpoints, exact restart from the latest checkpoint, a
deterministic step-indexed data stream so restarts replay nothing).

Every step path threads the int8 error-feedback residual as first-class
state — ``step(params, opt_state, residual, batch) → (params, opt_state,
residual, metrics)`` — with ``residual=None`` the valid steady state on
uncompressed paths.  On the compressed pod path the residual is the stacked
per-pod tree from ``dist.compression`` (leaf ``(num_pods, *grad.shape)``,
sharded ``P(pod)``), carried across steps and checkpointed next to
params/opt so restarts stay bit-exact; dropping it would re-bias the int8
collective every step after a crash (the exact failure mode error feedback
exists to prevent).
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.dist.compression import (
    EXACT_BYTES_PER_ELEM,
    WIRE_BYTES_PER_ELEM,
    WIRE_SCALE_BYTES_PER_LEAF,
    compressed_psum_mean,
    init_residual,
    reshard_residual,
)
from repro.dist.hints import sharding_policy
from repro.dist.sharding import MeshAxes, activation_hint_policy, reshard_tree
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.model import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


# Metrics that are COUNTS over the batch (extensive): reducers across
# microbatches and pods SUM these so totals stay comparable to a plain
# single-device step; everything else (ce, aux/z losses, ...) is a
# per-token mean (intensive) and is averaged.
EXTENSIVE_METRICS = frozenset({"expert_load"})


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    pod_axis: str | None = None,
                    compress_pods: bool = False,
                    mesh=None):
    """Returns train_step(params, opt_state, residual, batch)
    → (params, opt_state, residual, metrics).

    * ``microbatches > 1``: gradient accumulation via lax.scan over batch
      slices (sum of per-micro grads, normalized once; loss AND per-micro
      metrics — ce, MoE aux — are accumulated and meaned the same way).
    * ``pod_axis`` + ``compress_pods``: gradients are computed per-pod via
      vmap over a leading pod dim (intra-pod layout stays GSPMD-auto, and
      backward emits no implicit cross-pod reduce) and mean-reduced
      cross-pod with the int8+error-feedback collective inside a reduce-only
      shard_map manual region over the pod axis.

    ``residual`` is the error-feedback state.  Uncompressed paths pass it
    through untouched (``None`` is the steady state).  The compressed pod
    path consumes/produces the stacked per-pod tree (leaf ``(num_pods,
    *grad.shape)`` f32, sharded ``P(pod_axis)`` — each pod owns its own
    slice; it is per-pod local error and is never reduced).  ``None`` is
    accepted as a cold start there too and is promoted to zeros.
    """

    def grads_of(params, tokens, labels):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels, cfg)
            return loss, metrics, grads

        B = tokens.shape[0]
        assert B % microbatches == 0
        mb = B // microbatches
        tk = tokens.reshape(microbatches, mb, -1)
        lb = labels.reshape(microbatches, mb, -1)

        def micro(carry, xs):
            g_acc, l_acc, m_acc = carry
            t, l = xs
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, t, l, cfg)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss,
                    jax.tree.map(jnp.add, m_acc, m)), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # metrics structure (ce, MoE aux, ...) comes from an abstract trace —
        # the accumulator must exist before the scan body runs.
        _, m_shape = jax.eval_shape(
            lambda p, t, l: loss_fn(p, t, l, cfg), params, tk[0], lb[0])
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
        (g, ltot, mtot), _ = jax.lax.scan(
            micro, (g0, jnp.zeros(()), m0), (tk, lb))
        g = jax.tree.map(lambda x: x / microbatches, g)
        # intensive metrics mean across microbatches; extensive counts sum
        # (same global batch → same total whatever the accumulation factor,
        # which expert-placement consumers rely on)
        metrics = {k: (v if k in EXTENSIVE_METRICS else v / microbatches)
                   for k, v in mtot.items()}
        return ltot / microbatches, metrics, g

    def plain_step(params, opt_state, residual, batch):
        loss, metrics, grads = grads_of(params, batch["tokens"], batch["labels"])
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, residual, {"loss": loss, **metrics, **om}

    if pod_axis is None:
        return plain_step

    from jax.experimental.shard_map import shard_map

    # Everything but the pod axis stays GSPMD-auto.  The gradient compute is
    # vmapped over a leading pod dim (NOT run inside the manual region: the
    # model is scan-over-layers, and lax.scan inside a partially-auto
    # shard_map body breaks the SPMD partitioner on the pinned toolchain —
    # the seed's all-in-one manual pod_step could never compile on a
    # multi-axis mesh).  Only the cross-pod *reduction* is manual over
    # ``pod_axis``; that body is scan-free, and it is the one place wire
    # format matters.
    auto = frozenset(ax for ax in mesh.axis_names if ax != pod_axis)
    num_pods = mesh.shape[pod_axis]
    data_axis = "data" if "data" in mesh.axis_names else None

    def _pod_split(x):
        """(B, ...) → (num_pods, B/num_pods, ...), pod/data-sharded."""
        assert x.shape[0] % num_pods == 0, (x.shape, num_pods)
        x = x.reshape((num_pods, x.shape[0] // num_pods) + x.shape[1:])
        spec = P(pod_axis, data_axis, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    def _stack_spec(e):
        # full-rank P(pod, None, ...): dim 0 is the owning pod
        return P(pod_axis, *([None] * (e.ndim - 1)))

    def exact_pod_step(params, opt_state, residual, batch):
        tokens = _pod_split(batch["tokens"])
        labels = _pod_split(batch["labels"])
        loss, metrics, grads = jax.vmap(grads_of, in_axes=(None, 0, 0))(
            params, tokens, labels)
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        loss = jnp.mean(loss)
        metrics = _pod_metrics(metrics)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, residual, {"loss": loss, **metrics, **om}

    def _pod_metrics(metrics):
        # mean intensive metrics over pods; extensive counts are per-pod
        # partials whose global value is the SUM over pod slices (matches
        # the single-device count for the same global batch)
        return {k: (jnp.sum(v, axis=0) if k in EXTENSIVE_METRICS
                    else jnp.mean(v, axis=0)) for k, v in metrics.items()}

    def compressed_pod_step(params, opt_state, residual, batch):
        if residual is None:          # cold start: zero error feedback
            residual = init_residual(params, num_pods)
        tokens = _pod_split(batch["tokens"])
        labels = _pod_split(batch["labels"])
        # params broadcast over the vmapped pod dim: each pod's grads depend
        # only on its batch slice, so backward emits NO implicit cross-pod
        # reduce — the explicit int8 collective below is the only traffic
        # over the slow links.
        loss, metrics, grads = jax.vmap(grads_of, in_axes=(None, 0, 0))(
            params, tokens, labels)

        def reduce_body(grads, residual):
            # local slices are (1, *shape): squeeze the pod dim for the
            # collective, restack the new per-pod error on the way out.
            g = jax.tree.map(lambda x: x[0], grads)
            e = jax.tree.map(lambda x: x[0], residual)
            mean, new_err = compressed_psum_mean(g, pod_axis, e)
            return mean, jax.tree.map(lambda x: x[None], new_err)

        gspec = jax.tree.map(_stack_spec, grads)
        rspec = jax.tree.map(_stack_spec, residual)
        reduce_fn = shard_map(
            reduce_body, mesh=mesh, in_specs=(gspec, rspec),
            # the mean leaves replicated; the residual leaves P(pod)-sharded
            # (per-pod local error — never reduced)
            out_specs=(jax.tree.map(lambda _: P(), grads), rspec),
            check_rep=False, auto=auto)
        grads, residual = reduce_fn(grads, residual)
        loss = jnp.mean(loss)
        metrics = _pod_metrics(metrics)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, residual, {"loss": loss, **metrics, **om}

    return compressed_pod_step if compress_pods else exact_pod_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    # --- distribution / accumulation knobs -------------------------------
    # microbatches: gradient accumulation factor (1 = none).
    # mesh_shape: build a mesh over ("pod", "data", "model")[:len(shape)];
    #   None keeps the single-device fast path.  The leading axis is the
    #   pod axis (data parallelism over slow links).
    # compress_pods: int8 error-feedback cross-pod gradient reduction (the
    #   residual becomes checkpointed train-step state).
    microbatches: int = 1
    mesh_shape: tuple[int, ...] | None = None
    pod_axis: str = "pod"
    compress_pods: bool = False


class Trainer:
    """Single-process training driver with checkpoint/restart fault tolerance.

    With ``mesh_shape`` set the Trainer is mesh-aware: it constructs the
    multi-pod mesh and the activation sharding policy itself, runs the pod
    train step (optionally int8-compressed over the pod axis), and
    checkpoints the error-feedback residual next to params/opt.  Restarts
    are bit-exact at the same pod count; a restore onto a different pod
    count reshards the residual via ``dist.compression.reshard_residual``
    (mean-broadcast — preserves the applied correction Σe/n) and replaces
    every leaf on the new mesh.
    """

    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig, *,
                 tracer=None, metrics=None):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.tracer = tracer            # repro.obs.Tracer: per-step spans
        self.metrics = metrics          # repro.obs.MetricsRegistry
        self.pipeline = TokenPipeline(data_cfg)
        self.ckpt = Checkpointer(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)

        self.mesh = None
        self.policy = None
        pod_axis = None
        if tcfg.compress_pods and tcfg.mesh_shape is None:
            raise ValueError(
                "TrainerConfig(compress_pods=True) requires mesh_shape — "
                "without a pod axis the int8 collective would be silently "
                "skipped (use mesh_shape=(1,) for a single-pod mesh)")
        if tcfg.mesh_shape is not None:
            names = (tcfg.pod_axis, "data", "model")[:len(tcfg.mesh_shape)]
            self.mesh = jax.make_mesh(tuple(tcfg.mesh_shape), names)
            pod_axis = tcfg.pod_axis
            if "model" in names:
                # hint policy for the GSPMD-auto region *inside* the manual-
                # over-pod step: batch-like dims over data, TP over model
                # (pod is the manual axis, so hints never mention it).
                shape_cfg = ShapeConfig("train", "train", data_cfg.seq_len,
                                        data_cfg.global_batch)
                self.policy = activation_hint_policy(
                    cfg, MeshAxes(pod=None), shape_cfg)
        self.pod_axis = pod_axis
        self.num_pods = self.mesh.shape[pod_axis] if pod_axis else 1
        self.compressed = bool(pod_axis and tcfg.compress_pods)

        step = make_train_step(cfg, opt_cfg,
                               microbatches=tcfg.microbatches,
                               pod_axis=pod_axis,
                               compress_pods=tcfg.compress_pods,
                               mesh=self.mesh)
        self.step_fn = jax.jit(step, donate_argnums=(0, 1, 2))
        # final residual of the last COMPLETED run() (None before): mid-run
        # values are donated back into step_fn and must not be exposed
        self.last_residual = None

    # ---- state ------------------------------------------------------------

    def _zero_residual(self, params):
        return (init_residual(params, self.num_pods) if self.compressed
                else None)

    def _residual_shardings(self, residual):
        return jax.tree.map(
            lambda _: NamedSharding(self.mesh, P(self.pod_axis)), residual)

    def init_or_restore(self):
        params = init_params(jax.random.key(self.tcfg.seed), self.cfg)
        opt_state = init_opt_state(params, self.opt_cfg)
        residual = self._zero_residual(params)
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt_state, residual, 0
        if residual is None:
            state = self.ckpt.restore({"params": params, "opt": opt_state})
            return state["params"], state["opt"], None, latest

        # compressed path: ONE checkpoint read covers params+opt+residual
        try:
            saved_pods = int(self.ckpt.read_metadata().get("num_pods",
                                                           self.num_pods))
        except FileNotFoundError:
            saved_pods = self.num_pods
        template = {"params": params, "opt": opt_state, "residual": residual}
        try:
            if saved_pods == self.num_pods:
                # same pod count: residual leaves restore bit-exact, placed
                # P(pod) on this trainer's mesh (params/opt replicate)
                sh = {"params": jax.tree.map(
                          lambda _: NamedSharding(self.mesh, P()), params),
                      "opt": jax.tree.map(
                          lambda _: NamedSharding(self.mesh, P()), opt_state),
                      "residual": self._residual_shardings(residual)}
                state = self.ckpt.restore(template, shardings=sh)
                return (state["params"], state["opt"], state["residual"],
                        latest)
            state = self.ckpt.restore(template)
        except KeyError:
            # pre-residual checkpoint: cold-start the error feedback
            state = self.ckpt.restore({"params": params, "opt": opt_state})
            return state["params"], state["opt"], residual, latest
        # elastic pod-count change: rebuild the stack (Σe/n preserved) and
        # place each leaf on the new mesh
        res = reshard_residual(state["residual"], self.num_pods)
        res = reshard_tree(res, self._residual_shardings(res))
        return state["params"], state["opt"], res, latest

    def save(self, step: int, params, opt_state, residual) -> None:
        # residual=None flattens to nothing — uncompressed checkpoints keep
        # the pre-residual layout.
        self.ckpt.save(step, {"params": params, "opt": opt_state,
                              "residual": residual},
                       metadata={"num_pods": self.num_pods})

    # ---- loop --------------------------------------------------------------

    def run(self, steps: int | None = None, inject_failure_at: int | None = None):
        """Run to total_steps (resuming if checkpoints exist).

        ``inject_failure_at``: raise after that many NEW steps — used by the
        fault-tolerance tests/examples to prove bitwise-exact restart.
        """
        params, opt_state, residual, start = self.init_or_restore()
        total = steps if steps is not None else self.tcfg.total_steps
        history = []
        done = 0
        obs_on = self.tracer is not None or self.metrics is not None
        step_hist = (self.metrics.histogram("train.step_s")
                     if self.metrics is not None else None)
        # Cross-pod wire bytes per step, from the dist.compression payload
        # model: each pod ships every grad leaf over the slow links once —
        # int8 payload + f32 scales when compressed, f32 when exact.  Zero
        # with no pod axis (no slow links to account).
        wire_step = 0
        if obs_on and self.pod_axis is not None and self.num_pods > 1:
            leaves = jax.tree.leaves(params)
            n_elems = sum(x.size for x in leaves)
            wire_step = (
                WIRE_BYTES_PER_ELEM * n_elems
                + WIRE_SCALE_BYTES_PER_LEAF * len(leaves)
                if self.compressed else EXACT_BYTES_PER_ELEM * n_elems)
        with contextlib.ExitStack() as stack:
            if self.mesh is not None:
                stack.enter_context(jax.set_mesh(self.mesh))
                if self.policy is not None:
                    stack.enter_context(sharding_policy(self.policy))
            for step in range(start, total):
                batch = self.pipeline.batch_at(step)
                t0 = time.perf_counter() if obs_on else 0.0
                params, opt_state, residual, metrics = self.step_fn(
                    params, opt_state, residual,
                    {k: jnp.asarray(v) for k, v in batch.items()})
                if obs_on:
                    dt = time.perf_counter() - t0
                    if step_hist is not None:
                        step_hist.record(dt)
                    if self.metrics is not None and wire_step:
                        self.metrics.counter("train.wire_bytes").inc(wire_step)
                    if self.tracer is not None:
                        self.tracer.complete("train.step", t0, dt, step=step)
                if (step + 1) % self.tcfg.checkpoint_every == 0 or step + 1 == total:
                    self.save(step + 1, params, opt_state, residual)
                if (step + 1) % self.tcfg.log_every == 0 or step + 1 == total:
                    history.append((step + 1, float(metrics["loss"])))
                done += 1
                if inject_failure_at is not None and done >= inject_failure_at:
                    self.ckpt.wait()
                    raise RuntimeError(f"injected failure at step {step + 1}")
        self.ckpt.wait()
        self.last_residual = residual
        return params, opt_state, history
