"""Docs-consistency gate — thin shim over ``repro.analysis``.

The launcher-flag/knobs.md check now lives in the lint framework as the
``knob-doc-drift`` rule (src/repro/analysis/rules_repo.py), where it runs
alongside the other repo-scope rules under ``python -m repro.analysis``.
This entry point is kept so existing invocations keep working:

  PYTHONPATH=src python tools/check_docs.py

It runs ONLY the knob-doc-drift rule and keeps the old exit-code contract
(0 = every flag documented, 1 = drift, listed on stderr).
"""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.analysis import default_context, run_analysis

    ctx = default_context(ROOT, paths=[])
    res = run_analysis(ctx, rule_names=["knob-doc-drift"])
    for f in res.findings:
        print(f"[check_docs] {f.render()}", file=sys.stderr)
    if res.findings:
        print(f"[check_docs] {len(res.findings)} knob-doc-drift finding(s) — "
              f"document the flag in docs/knobs.md in the same PR",
              file=sys.stderr)
        return 1
    print("[check_docs] OK — launcher flags all documented in docs/knobs.md "
          "(via repro.analysis knob-doc-drift)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
