"""Docs-consistency gate: every launcher flag must appear in docs/knobs.md.

CI runs this after the test suite.  It parses every ``add_argument("--...")``
call in ``src/repro/launch/*.py`` (AST, not regex, so commented-out flags
don't count) and asserts each flag string occurs verbatim in
``docs/knobs.md``.  Exit 1 on drift, listing the undocumented flags — the
fix is to document the flag in the same PR that adds it.

  PYTHONPATH=src python tools/check_docs.py
"""

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LAUNCH = ROOT / "src" / "repro" / "launch"
KNOBS = ROOT / "docs" / "knobs.md"


def launcher_flags(path: pathlib.Path) -> list[str]:
    """All ``--flag`` option strings passed to ``add_argument`` in ``path``."""
    tree = ast.parse(path.read_text(), filename=str(path))
    flags = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    flags.append(arg.value)
    return flags


def main() -> int:
    if not KNOBS.exists():
        print(f"[check_docs] missing {KNOBS}", file=sys.stderr)
        return 1
    knobs = KNOBS.read_text()
    missing = []
    checked = 0
    for path in sorted(LAUNCH.glob("*.py")):
        for flag in launcher_flags(path):
            checked += 1
            if f"`{flag}`" not in knobs and flag not in knobs:
                missing.append(f"{path.relative_to(ROOT)}: {flag}")
    if not checked:
        print("[check_docs] found no launcher flags at all — wrong tree?",
              file=sys.stderr)
        return 1
    if missing:
        print(f"[check_docs] {len(missing)} launcher flag(s) undocumented in "
              f"docs/knobs.md:", file=sys.stderr)
        for m in missing:
            print(f"[check_docs]   {m}", file=sys.stderr)
        return 1
    print(f"[check_docs] OK — {checked} launcher flags all documented in "
          f"docs/knobs.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
